"""Roofline terms from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` on the SPMD-partitioned module is PER-DEVICE
(verified empirically), so chips never divides those two terms again.
collective_bytes comes from walking the optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weighted by the ``known_trip_count`` of any enclosing while loop (scan) and
by the collective's algorithmic byte multiplier on a ring.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple


# TPU v5e hardware constants (assignment-provided)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (~per-chip collective bandwidth)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,512]{1,0}' -> bytes."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _first_shape(line: str) -> int:
    """Bytes of the op's result shape (first shape token, incl. tuples)."""
    # result may be a tuple: (f32[...], f32[...])
    eq = line.find("=")
    rhs = line[eq + 1 :] if eq >= 0 else line
    shapes = re.findall(r"\w+\[[\d,]*\](?:\{[\d,]*\})?", rhs.split("(")[0])
    if not shapes:
        shapes = re.findall(r"\w+\[[\d,]*\]", rhs)[:1]
    return sum(_shape_bytes(s) for s in shapes)


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    ops: List[Tuple[str, float, int, int]] = dataclasses.field(default_factory=list)
    # (kind, bytes_weighted, group_size, trip_multiplier)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective traffic over one step execution."""
    # 1. split into computations
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"%?([\w\.\-]+)[^=]*\([^)]*\)\s*->.*\{\s*$", line.strip())
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # 2. while-op trip counts: body computation -> multiplier
    body_trips: Dict[str, int] = {}
    caller_of: Dict[str, str] = {}
    for cname, lines in comps.items():
        for line in lines:
            wm = re.search(r"while\(.*body=%?([\w\.\-]+)", line)
            if wm:
                body = wm.group(1)
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                body_trips[body] = int(tm.group(1)) if tm else 1
                caller_of[body] = cname
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            if cm:
                caller_of[cm.group(1)] = cname

    def multiplier(cname: str, depth: int = 0) -> int:
        if depth > 16:
            return 1
        mult = body_trips.get(cname, 1)
        parent = caller_of.get(cname)
        if parent and parent != cname:
            mult *= multiplier(parent, depth + 1)
        return mult

    # 3. collect collective ops weighted by ring-algorithm byte factors
    stats = CollectiveStats()
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for line in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"= [^=]*\b{kind}\(", line) or re.search(
                    rf"= [^=]*\b{kind}-start\(", line
                ):
                    out_b = _first_shape(line)
                    g = _group_size(line)
                    if kind == "all-reduce":
                        b = 2.0 * out_b * (g - 1) / g
                    elif kind == "all-gather":
                        b = out_b * (g - 1) / g
                    elif kind == "reduce-scatter":
                        b = out_b * (g - 1)  # input is g x output
                    elif kind == "all-to-all":
                        b = out_b * (g - 1) / g
                    else:  # collective-permute
                        b = out_b
                    stats.total_bytes += b * mult
                    stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + b * mult
                    stats.ops.append((kind, b * mult, g, mult))
                    break
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    collective_bytes: float   # per device
    model_flops: float        # analytic useful FLOPs (global)
    arg_bytes: int
    temp_bytes: int
    out_bytes: int

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips x HLO_FLOPs): remat/redundancy waste gauge."""
        return self.model_flops / max(self.hlo_flops * self.chips, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the dominant-roof peak this step achieves if it ran at
        the bound: useful-compute-time / bound-time."""
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        return t_useful / max(self.t_bound, 1e-30)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_flops_frac=self.useful_flops_frac,
            roofline_frac=self.roofline_frac,
        )
        return d


def _n_attn_layers(cfg) -> int:
    """Layers that actually run (self-)attention."""
    if cfg.family == "hybrid":
        return cfg.num_layers // max(cfg.attn_every, 1)
    if cfg.family == "ssm":
        return 0
    if cfg.family == "encdec":
        return cfg.num_layers + cfg.encoder_layers  # (+cross, folded in x2 below)
    return cfg.num_layers


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6*N*D train, 2*N*D forward-only
    (N = active params), plus attention term over attention-bearing layers."""
    n_active = cfg.active_param_count()
    n_attn = _n_attn_layers(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
        # attention scores+values: 12*B*H*S^2*hd (fwd+bwd), causal halves
        if cfg.num_heads:
            flops += 6.0 * shape.global_batch * n_attn * cfg.num_heads \
                * shape.seq_len ** 2 * cfg.head_dim
        return flops
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
        if cfg.num_heads:
            flops += 2.0 * shape.global_batch * n_attn * cfg.num_heads \
                * shape.seq_len ** 2 * cfg.head_dim / 2  # causal half x2 gemms
        return flops
    # decode/verify: K+1 tokens per row + attention over the whole cache
    k1 = shape.spec_len + 1
    tokens = shape.global_batch * k1
    flops = 2.0 * n_active * tokens
    if cfg.num_heads:
        flops += 4.0 * shape.global_batch * n_attn * cfg.num_heads \
            * k1 * shape.seq_len * cfg.head_dim
    return flops
