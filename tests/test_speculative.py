"""Core SLED algorithm: losslessness, acceptance math, dynamic drafting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine_loop import autoregressive_generate, sled_generate
from repro.core.speculative import PAD_TOKEN, speculative_verify
from repro.models.model_zoo import build_model

V = 128


def _models(draft_name="qwen2-1.5b", target_name="phi3-mini-3.8b"):
    dcfg = dataclasses.replace(get_config(draft_name).reduced(), vocab_size=V)
    tcfg = dataclasses.replace(get_config(target_name).reduced(),
                               name="tgt", vocab_size=V)
    dm, tm = build_model(dcfg), build_model(tcfg)
    return dm, dm.init_params(jax.random.key(1)), tm, tm.init_params(jax.random.key(2))


@pytest.mark.parametrize("pair", [
    # the attention pair (mamba2) stays in the fast tier; the cross-family
    # and hybrid pairs are the longest e2e runs in the suite
    pytest.param(("qwen2-1.5b", "phi3-mini-3.8b"), marks=pytest.mark.slow),
    ("mamba2-370m", "mamba2-370m"),
    pytest.param(("zamba2-1.2b", "zamba2-1.2b"), marks=pytest.mark.slow),
])
def test_greedy_sled_is_lossless(pair):
    """Greedy SLED output must EXACTLY equal greedy target-only decoding,
    across attention, SSM, and hybrid target families (validates the whole
    protocol: alignment invariant, cache rollback, state checkpoints)."""
    dm, dp, tm, tp = _models(*pair)
    prompts = jax.random.randint(jax.random.key(3), (2, 12), 0, V)
    ref = autoregressive_generate(tm, tp, prompts, max_new=20)
    out, stats, _ = sled_generate(dm, dp, tm, tp, prompts, max_new=20,
                                  k_max=4, greedy=True)
    np.testing.assert_array_equal(out, ref)


def test_self_draft_accepts_nearly_everything():
    """draft == target: acceptance ~1.0.  Not exactly 1.0: the draft scores
    tokens one-at-a-time while verification scores K+1 at once, and bf16
    matmul rounding differs between those batch shapes — random-weight
    logits have near-ties that occasionally flip argmax.  (Real outputs stay
    lossless either way: the verify pass defines the commit.)"""
    dm, dp, tm, tp = _models()
    prompts = jax.random.randint(jax.random.key(3), (2, 10), 0, V)
    out, stats, _ = sled_generate(tm, tp, tm, tp, prompts, max_new=16,
                                  k_max=4, greedy=True)
    assert stats.acceptance_rate > 0.85
    assert stats.tokens_per_round > 2 * 3


def test_dynamic_drafting_still_lossless():
    dm, dp, tm, tp = _models()
    prompts = jax.random.randint(jax.random.key(3), (2, 12), 0, V)
    ref = autoregressive_generate(tm, tp, prompts, max_new=16)
    out, stats, _ = sled_generate(dm, dp, tm, tp, prompts, max_new=16,
                                  k_max=6, c_th=0.5, greedy=True)
    np.testing.assert_array_equal(out, ref)


def test_verify_first_rejection_semantics():
    """Hand-built case: acceptance stops at the first failure."""
    B, K, Vv = 1, 4, 8
    drafts = jnp.array([[1, 2, 3, 4]], jnp.int32)
    # target argmax: 1, 2, 9999->7, anything: reject at index 2
    logits = jnp.full((B, K + 1, Vv), -10.0)
    logits = logits.at[0, 0, 1].set(10.0)
    logits = logits.at[0, 1, 2].set(10.0)
    logits = logits.at[0, 2, 7].set(10.0)  # disagrees with draft 3
    logits = logits.at[0, 3, 4].set(10.0)
    logits = logits.at[0, 4, 5].set(10.0)
    res = speculative_verify(drafts, logits, jax.random.key(0), greedy=True)
    assert int(res.n_accepted[0]) == 2
    assert int(res.extra_token[0]) == 7  # correction from target
    assert res.out_tokens[0].tolist()[:3] == [1, 2, 7]
    assert all(t == PAD_TOKEN for t in res.out_tokens[0].tolist()[3:])


def test_verify_all_accepted_gets_bonus():
    B, K, Vv = 1, 3, 8
    drafts = jnp.array([[1, 2, 3]], jnp.int32)
    logits = jnp.full((B, K + 1, Vv), -10.0)
    for i, t in enumerate([1, 2, 3, 6]):
        logits = logits.at[0, i, t].set(10.0)
    res = speculative_verify(drafts, logits, jax.random.key(0), greedy=True)
    assert int(res.n_accepted[0]) == 3
    assert not bool(res.rejected[0])
    assert int(res.extra_token[0]) == 6  # bonus token
    assert int(res.n_commit[0]) == 4


def test_verify_variable_lengths():
    B, K, Vv = 2, 4, 8
    drafts = jnp.array([[1, 2, 0, 0], [3, 3, 3, 3]], jnp.int32)
    lengths = jnp.array([2, 0], jnp.int32)
    logits = jnp.full((B, K + 1, Vv), 0.0)
    logits = logits.at[0, 0, 1].set(10.0)
    logits = logits.at[0, 1, 2].set(10.0)
    logits = logits.at[0, 2, 5].set(10.0)
    logits = logits.at[1, 0, 4].set(10.0)
    res = speculative_verify(drafts, logits, jax.random.key(0),
                             lengths=lengths, greedy=True)
    assert int(res.n_accepted[0]) == 2 and int(res.extra_token[0]) == 5
    assert int(res.n_accepted[1]) == 0 and int(res.extra_token[1]) == 4


def test_sampling_mode_statistically_lossless():
    """Rejection sampling with exact residuals reproduces the target
    distribution: chi-square-style check on a 1-step toy problem."""
    Vv, n = 16, 4000
    key = jax.random.key(0)
    t_logits = jax.random.normal(jax.random.key(1), (Vv,)) * 1.5
    d_logits = jax.random.normal(jax.random.key(2), (Vv,)) * 1.5
    p_t = jax.nn.softmax(t_logits)
    p_d = jax.nn.softmax(d_logits)

    def one(k):
        k1, k2 = jax.random.split(k)
        d_tok = jax.random.categorical(k1, d_logits)
        res = speculative_verify(
            d_tok[None, None], jnp.broadcast_to(t_logits, (1, 2, Vv)),
            k2, draft_q=p_d[d_tok][None, None],
            draft_q_full=p_d[None, None], greedy=False,
        )
        return res.out_tokens[0, 0]

    toks = jax.vmap(one)(jax.random.split(key, n))
    counts = np.bincount(np.asarray(toks), minlength=Vv)
    freq = counts / n
    # tolerance ~4 sigma of a multinomial
    tol = 4 * np.sqrt(np.asarray(p_t) * (1 - np.asarray(p_t)) / n)
    assert (np.abs(freq - np.asarray(p_t)) < tol + 0.01).all(), (freq, p_t)
