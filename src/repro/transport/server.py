"""Asyncio verification server: an engine (or replica cluster) behind the wire.

One ``TransportServer`` fronts either a single
:class:`~repro.core.server_engine.ServerEngine` or a
:class:`~repro.cluster.router.Router` of N replicas — both expose the same
admit/submit/step/retire surface, so the frame adapter below is identical
and "how many replicas serve this port" is purely a construction choice.
It serves any number of device channels (transport/links.py endpoints):

  * a per-connection task decodes frames and feeds the engine — ``Hello``
    admits (or queues the admission until a pool slot frees), ``DraftPacket``
    submits to the BatchPlanner, ``Close`` retires the stream;
  * one stepper task drives ``engine.step`` — concurrently-arriving requests
    batch under whichever policy the engine was built with (static /
    deadline / continuous), and the §III-A straggler timeout drops stalled
    requests out of the batch inside the planner;
  * the ``Fallback`` handler arbitrates the timeout race atomically: if the
    device's request is still queued (or never arrived) it is cancelled and
    the stream is force-extended with the locally-released tokens (lossy
    resync, paper §III-A); if it was already verified, the stored verdict is
    resent and remains authoritative.  Duplicate control frames are answered
    by replaying the last reply, so lossy links converge by retry.

Race discipline: verdicts are *recorded* (last-reply table) synchronously in
the same no-await stretch as ``engine.step``, so a Fallback frame processed
later can never force-extend a stream whose round was already verified.

Single-process, single event loop: engine steps and device drafting
interleave at await points rather than truly overlapping (documented limit;
real sockets across hosts are a ROADMAP item).
"""
from __future__ import annotations

import asyncio
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.cluster.router import Router
from repro.core.server_engine import EngineStats, ServerEngine
from repro.transport import codec
from repro.transport.links import Endpoint


class TransportServer:
    def __init__(self, engine: Union[ServerEngine, Router], *, idle_tick: float = 0.05):
        self.engine = engine  # single replica or a cluster router: same surface
        self.idle_tick = idle_tick
        self._conns: Dict[int, Endpoint] = {}
        self._endpoints: List[Endpoint] = []  # every endpoint ever attached
        self._req_seq: Dict[int, int] = {}  # device -> seq of in-flight round
        self._last_reply: Dict[int, bytes] = {}
        self._last_reply_seq: Dict[int, int] = {}
        self._pending_admits: Deque[Tuple[int, np.ndarray]] = deque()
        self._wake = asyncio.Event()
        self._tasks: List[asyncio.Task] = []
        self._stepper: Optional[asyncio.Task] = None
        self._t0: Optional[float] = None
        self.late_verdicts_resent = 0
        self.fallback_acks = 0

    # -- lifecycle -----------------------------------------------------------

    def now(self) -> float:
        loop = asyncio.get_running_loop()
        if self._t0 is None:
            self._t0 = loop.time()
        return loop.time() - self._t0

    def attach(self, endpoint: Endpoint) -> None:
        """Register a device channel; starts its connection task (and the
        engine stepper, on first attach)."""
        self._endpoints.append(endpoint)
        self._tasks.append(asyncio.get_running_loop().create_task(self._serve_conn(endpoint)))
        if self._stepper is None:
            self._stepper = asyncio.get_running_loop().create_task(self._step_loop())

    async def stop(self) -> None:
        tasks = [*self._tasks, *([self._stepper] if self._stepper else [])]
        for t in tasks:
            t.cancel()
        for t in tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        self._tasks, self._stepper = [], None

    # -- connection handling -------------------------------------------------

    async def _serve_conn(self, ep: Endpoint) -> None:
        device_id = None
        while True:
            frame = await ep.recv()
            if frame is None:
                break
            msg, _ = codec.decode_frame(frame)
            device_id = msg.device_id
            await self._dispatch(msg, ep)
        # peer vanished without a Close: reclaim the slot — unless the
        # device already redialed on a fresh endpoint (EdgeClient reconnect
        # maps the new conn via Hello before closing the dead one), in
        # which case this conn is just the corpse of the old link
        if (
            device_id is not None
            and device_id in self.engine.streams
            and self._conns.get(device_id) is ep
        ):
            await self._retire(device_id)

    def _record(self, device_id: int, frame: bytes, seq: int) -> None:
        """No-await bookkeeping: must happen before the frame hits the wire."""
        self._last_reply[device_id] = frame
        self._last_reply_seq[device_id] = seq

    async def _send(self, device_id: int, frame: bytes) -> None:
        ep = self._conns.get(device_id)
        if ep is None:
            return
        try:
            await ep.send(frame)
        except ConnectionError:
            # the device's link died under us: drop the frame rather than
            # crash the stepper.  The reply is already in the last-reply
            # table, so the client recovers it through Fallback arbitration
            # after it redials.
            pass

    async def _dispatch(self, msg, ep: Endpoint) -> None:
        dev = msg.device_id
        if isinstance(msg, codec.Hello):
            self._conns[dev] = ep
            if dev in self.engine.streams:
                # duplicate Hello: the Admit was lost — resend, don't re-admit
                slot = self.engine.streams[dev].slot
                await self._send(dev, codec.encode_frame(codec.Admit(dev, ok=True, slot=slot)))
                return
            if any(d == dev for d, _ in self._pending_admits):
                return  # already queued for a slot
            stream = self.engine.admit(dev, jnp.asarray(msg.prompt, jnp.int32), self.now())
            if stream is None:
                self._pending_admits.append((dev, np.asarray(msg.prompt, np.int32)))
                await self._send(dev, codec.encode_frame(codec.Admit(dev, ok=False)))
            else:
                await self._send(
                    dev, codec.encode_frame(codec.Admit(dev, ok=True, slot=stream.slot))
                )
        elif isinstance(msg, codec.DraftPacket):
            if dev not in self.engine.streams:
                return  # raced a retirement; the client is closing
            if self.engine.has_inflight(dev):
                return  # duplicate frame for the round already queued
            if self._last_reply_seq.get(dev, -1) >= msg.seq:
                return  # stale resend of a round that already resolved
            self._req_seq[dev] = msg.seq
            self.engine.submit(dev, msg.tokens, self.now(), draft_q=msg.draft_q)
            self._wake.set()
        elif isinstance(msg, codec.Fallback):
            await self._handle_fallback(msg)
        elif isinstance(msg, codec.Close):
            if dev in self.engine.streams:
                await self._retire(dev)
        else:
            raise codec.CodecError(f"server cannot handle {type(msg).__name__}")

    async def _handle_fallback(self, msg: codec.Fallback) -> None:
        dev = msg.device_id
        if dev not in self.engine.streams:
            return
        if self._last_reply_seq.get(dev, -1) >= msg.seq:
            # this round already resolved (verdict or earlier ack) — the
            # stored reply is authoritative; resend it, the device reconciles
            self.late_verdicts_resent += 1
            await self._send(dev, self._last_reply[dev])
            return
        # request still queued (cancel it) or lost on the wire (nothing to
        # cancel): either way the stream resyncs with the released tokens
        self.engine.cancel_request(dev)
        next_prev = self.engine.force_extend(dev, msg.tokens)
        self.fallback_acks += 1
        ack = codec.encode_frame(codec.FallbackAck(dev, msg.seq, next_prev))
        self._record(dev, ack, msg.seq)
        await self._send(dev, ack)

    async def _retire(self, device_id: int) -> None:
        self.engine.retire(device_id)
        self._req_seq.pop(device_id, None)
        self._last_reply.pop(device_id, None)
        self._last_reply_seq.pop(device_id, None)
        self._conns.pop(device_id, None)
        if self._pending_admits:
            dev, prompt = self._pending_admits.popleft()
            stream = self.engine.admit(dev, jnp.asarray(prompt, jnp.int32), self.now())
            if stream is None:  # still full (another admit raced us)
                self._pending_admits.appendleft((dev, prompt))
            else:
                await self._send(
                    dev, codec.encode_frame(codec.Admit(dev, ok=True, slot=stream.slot))
                )

    # -- the serving loop ----------------------------------------------------

    async def _step_loop(self) -> None:
        while True:
            now = self.now()
            verdicts = self.engine.step(now)
            if verdicts:
                # encode + record with NO awaits in between: once anything
                # else runs, every verdict of this round must be authoritative
                outgoing = []
                for v in verdicts:
                    seq = self._req_seq.get(v.device_id, 0)
                    frame = codec.encode_frame(
                        codec.Verdict(
                            device_id=v.device_id,
                            seq=seq,
                            n_accepted=v.n_accepted,
                            tokens=np.asarray(v.tokens, np.int32),
                            next_prev=v.next_prev,
                            accept_rate=v.accept_rate,
                            queue_depth=v.queue_depth,
                            queue_s=v.queue_s,
                            verify_s=v.verify_s,
                        )
                    )
                    self._record(v.device_id, frame, seq)
                    outgoing.append((v.device_id, frame))
                for dev, frame in outgoing:
                    await self._send(dev, frame)
                await asyncio.sleep(0)  # let replies land before re-stepping
                continue
            hint = self.engine.next_event_hint(now)
            timeout = self.idle_tick
            if self.engine.queue_depth:
                # work is queued but the policy hasn't fired: wake at the
                # planner's next deadline/straggler event (or quickly, for
                # policies that fire on arrival)
                timeout = max(hint - now, 0.0) + 1e-4 if hint is not None else 1e-3
            try:
                await asyncio.wait_for(self._wake.wait(), timeout)
            except asyncio.TimeoutError:
                pass
            self._wake.clear()

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        """EngineStats with the wire fields filled from this server's side of
        every link (tx = verdicts/control out, rx = drafts/control in)."""
        st = self.engine.stats(self.now() if now is None else now)
        for ep in self._endpoints:
            st.bytes_tx += ep.stats.bytes_tx
            st.bytes_rx += ep.stats.bytes_rx
            st.frames_tx += ep.stats.frames_tx
            st.frames_rx += ep.stats.frames_rx
            st.frames_dropped += ep.stats.frames_dropped
        return st
