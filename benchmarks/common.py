"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt


def emit(rows: List[dict], name: str) -> None:
    """Benchmark output contract: ``name,us_per_call,derived`` CSV rows.

    Nested records (spec / stats sub-dicts from the uniform ``to_json``
    surface) stay in the JSON artifact only — a flattened spec would drown
    the CSV line."""
    for r in rows:
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items() if not isinstance(v, dict))
        print(f"{name},{us},{derived}")
