"""Auto-tuner search (``repro tune``, steps 2-3 of 3).

From one profiled fleet spec (see :mod:`repro.tuning.profile`), sweep
``dataclasses.replace``d candidates per device class — spec length ``k``,
drafting confidence ``c_th``, quant ``bits`` / draft model size (priced by
the DeviceProfile rate table), placement — score each through the
CALIBRATED discrete-event simulator plus the Eq. 2 cost model, then
validate the top candidates on the real engine and emit the winner.

The objective is the paper's capacity question asked of a heterogeneous
fleet: how many admitted streams does a config sustain at a deadline-miss
rate under the cap?  In the simulator that is a binary search over an
integer multiplier on every class's device count (``sim_fleet_capacity``);
on the real engine it is a short measured serve whose per-round trace spans
give the observed miss rate (``measured_run``).

The per-class search is greedy coordinate descent: classes are re-optimised
one at a time against the full-fleet simulation (the server queue couples
them) for a few passes — a full cross product over classes would be
exponential for no extra signal.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.api import ServeSpec, System
from repro.serving.cost_model import fleet_cost_per_1k
from repro.serving.devices import DEVICES, SERVERS, ServerProfile
from repro.serving.simulator import ClassLoad, SimConfig, SimResult, simulate
from repro.tuning.profile import (
    class_commit_rate,
    FleetCalibration,
    make_prober,
    profile_fleet,
)

# reduced-model stand-ins for the paper's draft families: the device-side
# COST of a bigger draft comes from the DeviceProfile rate table (real
# llama.cpp numbers), while its acceptance ADVANTAGE is modelled as lower
# perturbation noise (a draft closer to the target) — measured, not assumed,
# because every candidate's noise goes through a reference probe
DRAFT_STANDINS = {
    "llama-1b-draft": 1.0,   # noise multiplier on the class's base noise
    "llama-3b-draft": 0.5,
}


@dataclasses.dataclass
class TuneConfig:
    server: str = "a100x4"       # ServerProfile for roofline + cost scoring
    target_params: float = 11e9  # paper-scale verifier the roofline prices
    deadline_s: float = 0.0      # 0: derived from the profiled round latency
    deadline_mult: float = 2.75  # derived deadline = mult * profiled p95
    miss_cap: float = 0.1        # matched deadline-miss rate across configs
    # per-stream goodput floor: a load only counts as admitted if every class
    # still commits >= this fraction of its PROFILED per-device rate (the
    # simulator capacity() "equal response-rate" requirement) — without it
    # the capacity objective degenerates to "pace every device to zero"
    rate_floor_frac: float = 0.5
    n_validate: int = 2          # top candidates re-measured on the engine
    # >1: rank gate-passing finalists by measured throughput with the fleet
    # (and verify pool) scaled by this factor — at the base deployment the
    # finalists are within noise of each other, under load they are not
    validate_mult: int = 1
    quick: bool = False          # smaller axes + shorter probes (CI smoke)
    probe_devices: int = 2
    probe_max_new: int = 12
    sim_time: float = 12.0
    m_max: int = 32              # capacity search: max class-count multiplier
    passes: int = 2              # coordinate-descent sweeps over the classes

    def resolved_server(self) -> ServerProfile:
        return SERVERS[self.server]

    def k_choices(self, k_max: int) -> Tuple[int, ...]:
        ks = (2, 4) if self.quick else (1, 2, 3, 4, 6)
        return tuple(k for k in ks if k <= k_max) or (k_max,)

    def c_th_choices(self) -> Tuple[float, ...]:
        # 0.0 (never cut a draft short) must stay in the palette: on toy
        # vocabularies the draft confidence tops out near 1/vocab, so any
        # higher bar silently truncates every draft to one token
        return (0.0, 0.1, 0.4) if self.quick else (0.0, 0.1, 0.3, 0.5)

    def bits_choices(self) -> Tuple[int, ...]:
        return (4,) if self.quick else (4, 8)

    def draft_models(self) -> Tuple[str, ...]:
        return ("llama-1b-draft",) if self.quick else tuple(DRAFT_STANDINS)


@dataclasses.dataclass
class TuneResult:
    winner: ServeSpec
    winner_row: dict
    baseline_row: dict
    deadline_s: float
    calibration: FleetCalibration
    rows: List[dict]             # every scored candidate, best first
    validated: List[dict]        # real-engine measurements of the top picks
    wall_s: float

    def to_json(self) -> dict:
        return {
            "winner_spec": self.winner.to_json(),
            "winner": self.winner_row,
            "baseline": self.baseline_row,
            "deadline_s": self.deadline_s,
            "calibration": self.calibration.to_json(),
            "rows": self.rows,
            "validated": self.validated,
            "wall_s": round(self.wall_s, 2),
        }


# ---------------------------------------------------------------------------
# spec surgery helpers (shared with benchmarks/fleet.py)
# ---------------------------------------------------------------------------


def with_class(spec: ServeSpec, index: int, **changes) -> ServeSpec:
    """The spec with class ``index`` replaced — one sweep move."""
    classes = list(spec.fleet.classes)
    classes[index] = dataclasses.replace(classes[index], **changes)
    return dataclasses.replace(
        spec, fleet=dataclasses.replace(spec.fleet, classes=tuple(classes))
    )


def scaled_fleet(spec: ServeSpec, m: float) -> ServeSpec:
    """Every class count multiplied by ``m`` (slots stay fixed, so load
    oversubscribes the pool) — the admitted-stream capacity axis.
    Fractional multipliers round per class (never below one device), so a
    capacity sweep can step in a few streams at a time instead of doubling
    the whole fleet."""
    classes = tuple(
        dataclasses.replace(c, count=max(1, int(round(c.count * m))))
        for c in spec.fleet.classes
    )
    return dataclasses.replace(
        spec, fleet=dataclasses.replace(spec.fleet, classes=classes)
    )


def at_multiplier(spec: ServeSpec, m: float) -> ServeSpec:
    """The fleet scaled by ``m`` with the verify pool provisioned to match
    (slots = fleet size), so what binds as the fleet grows is the SERVING
    deadline — batch width, verify latency, server queue — not an admission
    queue in front of a pinned pool."""
    scaled = scaled_fleet(spec, m)
    return dataclasses.replace(
        scaled,
        scheduler=dataclasses.replace(
            scaled.scheduler, slots=scaled.fleet.total
        ),
    )


# ---------------------------------------------------------------------------
# scoring: calibrated simulator + cost model
# ---------------------------------------------------------------------------


def sim_config_for(
    spec: ServeSpec,
    calib: FleetCalibration,
    tcfg: TuneConfig,
    probe: Callable[..., Tuple[float, float]],
    *,
    deadline_s: float,
) -> SimConfig:
    """The candidate's calibrated simulator config: measured acceptance and
    draft lengths per class (probed), measured draft rates scaled by the
    hardware table for counterfactual configs, measured class RTTs, and
    the profiled server latency scale — everything in the deployment's own
    clock so predicted capacities compare against real validation runs."""
    loads = []
    for rc in spec.resolved_classes():
        acc, mlen = probe(
            k=rc.k, c_th=rc.c_th,
            draft_layers=rc.draft_layers, draft_noise=rc.draft_noise,
        )
        cc = calib.classes[rc.index]  # candidates never reorder classes
        rate = cc.draft_rate * (rc.hardware_rate() / max(cc.hardware_rate, 1e-9))
        loads.append(ClassLoad(
            count=rc.count,
            device_rate=max(rate, 1e-6),
            spec_len=max(1, int(round(mlen))),
            acceptance=acc,
            rtt_mean=cc.rtt_mean,
        ))
    policy = spec.scheduler.policy
    return SimConfig(
        mode="sled",
        classes=tuple(loads),
        deadline_s=deadline_s,
        server_latency_scale=calib.server_latency_scale,
        target_params=tcfg.target_params,
        server_batch=max(spec.slots_per_replica * spec.cluster.n_replicas, 1),
        batch_policy=policy if policy in ("static", "deadline", "continuous") else "continuous",
        max_wait=spec.scheduler.max_wait,
        verify_timeout=spec.transport.verify_timeout,
        sim_time=tcfg.sim_time,
    )


def sim_fleet_capacity(
    cfg: SimConfig,
    server: ServerProfile,
    *,
    miss_cap: float,
    m_max: int,
    rate_floors: Tuple[float, ...] = (),
) -> Tuple[int, SimResult]:
    """Max class-count multiplier holding deadline misses under the cap AND
    every class's per-device commit rate over its goodput floor.

    Returns ``(m, result_at_m)`` — admitted-stream capacity is ``m`` times
    the base fleet size; ``m == 0`` means even the base config misses."""
    def at(m: int) -> SimResult:
        c = dataclasses.replace(cfg, classes=tuple(
            dataclasses.replace(cl, count=cl.count * m) for cl in cfg.classes
        ))
        return simulate(c, server)

    def admitted(r: SimResult) -> bool:
        if r.deadline_miss_rate > miss_cap:
            return False
        return all(
            rate >= floor
            for rate, floor in zip(r.class_device_rates, rate_floors)
        )

    r1 = at(1)
    if not admitted(r1):
        return 0, r1
    top = at(m_max)
    if admitted(top):
        return m_max, top
    lo, hi, best = 1, m_max, r1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        r = at(mid)
        if admitted(r):
            lo, best = mid, r
        else:
            hi = mid - 1
    return lo, best


def score_candidate(
    spec: ServeSpec,
    calib: FleetCalibration,
    tcfg: TuneConfig,
    probe,
    *,
    deadline_s: float,
) -> dict:
    """One candidate's predicted record: capacity at the miss cap + goodput
    floors (primary), throughput at that load (tiebreak), Eq. 2 $/1K tokens
    (reported)."""
    server = tcfg.resolved_server()
    cfg = sim_config_for(spec, calib, tcfg, probe, deadline_s=deadline_s)
    floors = tuple(
        tcfg.rate_floor_frac * cc.commit_rate for cc in calib.classes
    )
    m, r = sim_fleet_capacity(
        cfg, server, miss_cap=tcfg.miss_cap, m_max=tcfg.m_max,
        rate_floors=floors,
    )
    base = sum(cl.count for cl in cfg.classes)
    rcs = spec.resolved_classes()
    per_dev = r.per_device_rate
    cost = fleet_cost_per_1k(
        [(rc.count * max(m, 1), per_dev, DEVICES[rc.spec.profile]) for rc in rcs],
        server,
        server_busy_frac=max(r.server_busy_frac, 1e-3),
    )
    return {
        "classes": [
            {"profile": rc.spec.profile, "count": rc.count, "k": rc.k,
             "c_th": rc.c_th, "draft_model": rc.spec.draft_model,
             "bits": rc.spec.bits, "draft_noise": rc.draft_noise}
            for rc in rcs
        ],
        "placement": spec.cluster.placement,
        "capacity_streams": m * base,
        "capacity_mult": m,
        "sim_wstgr": round(r.wstgr, 3),
        "sim_miss_rate": round(r.deadline_miss_rate, 4),
        "sim_class_rates": [round(x, 3) for x in r.class_device_rates],
        "sim_busy_frac": round(r.server_busy_frac, 4),
        "cost_per_1k_usd": round(cost, 6),
        "score": (m * base, round(r.wstgr, 3), -cost),
    }


# ---------------------------------------------------------------------------
# candidate enumeration + coordinate descent
# ---------------------------------------------------------------------------


def class_options(spec: ServeSpec, index: int, tcfg: TuneConfig) -> List[dict]:
    """Every sweep move for one class: (k, c_th) x (draft model, bits)
    combos its hardware profile actually has rates for."""
    rc = spec.resolved_classes()[index]
    prof = DEVICES[rc.spec.profile]
    base_noise = rc.draft_noise
    combos = [
        (mdl, bits)
        for mdl in tcfg.draft_models()
        for bits in tcfg.bits_choices()
        if (mdl, bits) in prof.draft_rate
    ]
    opts = []
    for k in tcfg.k_choices(spec.k_max):
        for c_th in tcfg.c_th_choices():
            for mdl, bits in combos:
                opts.append(dict(
                    k=k, c_th=c_th, draft_model=mdl, bits=bits,
                    draft_noise=round(base_noise * DRAFT_STANDINS[mdl], 6),
                ))
    return opts


def tune(
    spec: ServeSpec,
    tcfg: Optional[TuneConfig] = None,
    *,
    models=None,
    kits=None,
    log: Callable[[str], None] = print,
) -> TuneResult:
    """The full profile -> sweep -> validate pipeline for one fleet spec."""
    tcfg = tcfg or TuneConfig()
    if not spec.fleet.active:
        raise ValueError("repro tune needs a ServeSpec with an active fleet "
                         "(fleet.classes non-empty) — see examples/specs/fleet.json")
    t0 = time.time()
    server = tcfg.resolved_server()

    # warm every jitted path first — verify buckets, per-class draft kits —
    # on a throwaway serve sharing the sweep's models/kits/steps, so the
    # profiled spans and validation runs measure serving, not compiles
    from repro.api import KitCache, build_models

    models = models or build_models(spec.model)
    kits = kits if kits is not None else KitCache()
    warm = System.build(spec, models=models, kits=kits)
    warm.warmup()
    warm.serve()
    steps = warm.steps

    log(f"[tune 1/3] profiling {spec.fleet.total} devices "
        f"({len(spec.fleet.classes)} classes) on the {spec.backend} backend")
    calib = profile_fleet(
        spec, server=server, target_params=tcfg.target_params,
        models=models, kits=kits, steps=steps,
    )
    # anchor the derived deadline on the profiled TAIL, not the mean: the
    # capacity objective admits a load only while ~all rounds make the
    # deadline, and a mean-anchored bound leaves even the unloaded fleet
    # straddling the miss cap — capacity becomes tail noise, not config
    deadline_s = tcfg.deadline_s or round(
        tcfg.deadline_mult
        * max(calib.round_latency_p95, calib.round_latency_mean, 1e-4), 4
    )
    log(f"[tune 1/3] round latency {calib.round_latency_mean*1e3:.1f} ms "
        f"(p95 {calib.round_latency_p95*1e3:.1f}) "
        f"-> deadline {deadline_s*1e3:.1f} ms, latency scale "
        f"{calib.server_latency_scale:.3g}, per-class acceptance "
        f"{[round(c.acceptance, 2) for c in calib.classes]}")

    probe = make_prober(
        spec, devices=tcfg.probe_devices, max_new=tcfg.probe_max_new
    )

    def score(s: ServeSpec) -> dict:
        return score_candidate(s, calib, tcfg, probe, deadline_s=deadline_s)

    def signature(s: ServeSpec) -> tuple:
        return tuple(
            (c.k, c.c_th, c.draft_model, c.bits, c.draft_noise)
            for c in s.fleet.classes
        ) + (s.cluster.placement,)

    baseline_row = score(spec)
    rows: List[dict] = [dict(baseline_row, move="baseline")]
    scored: List[Tuple[dict, ServeSpec]] = [(baseline_row, spec)]
    best, best_row = spec, baseline_row
    n_classes = len(spec.fleet.classes)
    log(f"[tune 2/3] coordinate descent: {n_classes} classes x "
        f"{len(class_options(spec, 0, tcfg))} options x {tcfg.passes} passes, "
        f"objective: admitted streams at miss <= {tcfg.miss_cap:.0%}")
    for p in range(tcfg.passes):
        improved = False
        for i in range(n_classes):
            for opt in class_options(best, i, tcfg):
                cand = with_class(best, i, **opt)
                row = score(cand)
                rows.append(dict(row, move=f"pass{p}.class{i}"))
                scored.append((row, cand))
                if row["score"] > best_row["score"]:
                    best, best_row, improved = cand, row, True
        if not improved:
            break
    # top DISTINCT candidates go to real-engine validation — a borderline
    # sim winner that fails the measured floors must not sink the whole
    # sweep when the runner-up would have held them
    scored.sort(key=lambda rc: rc[0]["score"], reverse=True)
    finalists: List[ServeSpec] = []
    seen = {signature(spec)}
    for row, cand in scored:
        if signature(cand) in seen:
            continue
        seen.add(signature(cand))
        finalists.append(cand)
    # placement is invisible to the single-server simulator: carry both
    # policies into real-engine validation when there is a replica set
    if spec.cluster.n_replicas > 1:
        flip = ("class-affinity" if best.cluster.placement != "class-affinity"
                else "least-loaded")
        finalists.insert(1, dataclasses.replace(
            best, cluster=dataclasses.replace(best.cluster, placement=flip)
        ))
    finalists = finalists[: max(tcfg.n_validate, 1)]
    rows.sort(key=lambda r: r["score"], reverse=True)

    log(f"[tune 2/3] best predicted: {best_row['capacity_streams']} streams "
        f"(x{best_row['capacity_mult']}), {best_row['sim_wstgr']} tok/s, "
        f"${best_row['cost_per_1k_usd']}/1K")

    log(f"[tune 3/3] validating {len(finalists)} finalist(s) + baseline on "
        f"the real {spec.backend} backend")
    labelled = [("baseline", spec)] + [
        (f"finalist{i}", f) for i, f in enumerate(finalists)
    ]
    validated = []
    for tag, s in labelled:
        meas = measured_run(
            s, deadline_s=deadline_s, models=models, kits=kits, steps=steps
        )
        validated.append(dict(meas, tag=tag, placement=s.cluster.placement))
        log(f"[tune 3/3] {tag}: {meas['wstgr']} tok/s, miss "
            f"{meas['deadline_miss_rate']:.1%}, acceptance {meas['acceptance']}")
    # the sim only PRUNES the combinatorial space; the winner is chosen by
    # MEASURED throughput among finalists that hold the deadline AND the
    # per-class goodput floors on the real engine (the calibrated sim is
    # good to ~15% — finalists are routinely within that of each other).
    # If every finalist fails the gates, fall back to the baseline rather
    # than ship a lie.
    base_rates = validated[0].get("class_rates") or []
    passers = []
    for (tag, s), v in zip(labelled, validated):
        if tag == "baseline" or v["deadline_miss_rate"] > tcfg.miss_cap:
            continue
        if any(
            rate < tcfg.rate_floor_frac * base
            for rate, base in zip(v.get("class_rates") or [], base_rates)
        ):
            continue
        passers.append((tag, s, v["wstgr"]))
    # at the base deployment the surviving finalists are within measurement
    # noise of each other; when asked (validate_mult > 1) re-measure each
    # under an oversubscribed fleet, where a config that wastes verify FLOPs
    # (long rejected drafts) visibly loses throughput to queueing.  Slot
    # shapes change with the fleet, so stress runs compile their own steps.
    if tcfg.validate_mult > 1 and len(passers) > 1:
        stressed = []
        for tag, s, _ in passers:
            sv = measured_run(
                at_multiplier(s, tcfg.validate_mult),
                deadline_s=deadline_s, models=models, kits=kits,
            )
            validated.append(dict(
                sv, tag=f"{tag}@x{tcfg.validate_mult}",
                placement=s.cluster.placement,
            ))
            log(f"[tune 3/3] {tag} @x{tcfg.validate_mult}: {sv['wstgr']} "
                f"tok/s, miss {sv['deadline_miss_rate']:.1%}")
            stressed.append((tag, s, sv["wstgr"]))
        passers = stressed
    winner, winner_tag = spec, "baseline"
    if passers:
        winner_tag, winner, _ = max(passers, key=lambda t: t[2])
    result = TuneResult(
        winner=winner,
        winner_row=best_row,
        baseline_row=baseline_row,
        deadline_s=deadline_s,
        calibration=calib,
        rows=rows,
        validated=validated,
        wall_s=time.time() - t0,
    )
    log(f"[tune] done in {result.wall_s:.1f}s — winner ({winner_tag}): "
        + ", ".join(
            f"{rc.spec.profile}x{rc.count}: k={rc.k} c_th={rc.c_th} "
            f"{rc.spec.draft_model}@{rc.spec.bits}b"
            for rc in winner.resolved_classes()
        ))
    return result


# ---------------------------------------------------------------------------
# real-engine measurement (shared with benchmarks/fleet.py)
# ---------------------------------------------------------------------------


def measured_run(
    spec: ServeSpec,
    *,
    deadline_s: float,
    models=None,
    kits=None,
    steps=None,
    max_new: Optional[int] = None,
) -> dict:
    """Serve the spec once with telemetry on and report the measured record:
    throughput, acceptance, and the deadline-miss rate over per-round
    service latencies (queue + verify + wire from the trace spans).

    The measured serve follows a throwaway one so kits the candidate spec
    introduced (new k / c_th / draft variant combos) pay their compile
    spikes off the clock — same discipline as the profiling pass."""
    vspec = dataclasses.replace(spec, telemetry=True)
    warm = System.build(vspec, models=models, kits=kits, steps=steps)
    try:
        warm.warmup()
        warm.serve(max_new=max_new)
        # the measured system MUST reuse the warm system's compiled step
        # bundle (they share the spec, so slot shapes match): otherwise the
        # measured serve lazily recompiles mid-run and every round latency
        # is compile time, not serving time
        steps = steps or warm.steps
    finally:
        warm.close()
    system = System.build(vspec, models=models, kits=kits, steps=steps)
    try:
        result = system.serve(max_new=max_new)
    finally:
        system.close()
    lats = [
        ev.queue_s + ev.verify_s + ev.wire_s
        for s in result.sessions
        for ev in (s.trace or [])
    ]
    misses = sum(1 for x in lats if x > deadline_s)
    st = result.engine
    wall = max(result.wall_seconds, 1e-9)
    class_rates = []
    if vspec.fleet.active:
        for rc in vspec.resolved_classes():
            rows = [s for s in result.sessions if rc.lo <= s.device_id < rc.hi]
            class_rates.append(round(class_commit_rate(rows, wall=wall), 3))
    return {
        "devices": vspec.devices,
        "streams_served": len(result.sessions),
        "wstgr": round(result.total_tokens / wall, 2),
        "acceptance": round(st.acceptance_rate, 3),
        "deadline_s": deadline_s,
        "deadline_miss_rate": round(misses / max(len(lats), 1), 4),
        "round_latency_mean": round(sum(lats) / max(len(lats), 1), 5),
        "class_rates": class_rates,  # committed tokens/s per device by class
        "rounds": st.rounds,
        "wall_s": round(result.wall_seconds, 2),
    }
