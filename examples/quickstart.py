"""Quickstart: SLED speculative decoding with real (tiny) JAX models.

    PYTHONPATH=src python examples/quickstart.py

Builds a small draft + target pair, runs the full SLED protocol
(dynamic drafting -> batched verification -> rollback), and checks the
output is exactly the target model's greedy output (losslessness).
"""
import dataclasses
import time

import jax

from repro.configs.base import get_config
from repro.core.engine_loop import autoregressive_generate, sled_generate
from repro.models.model_zoo import build_model

VOCAB = 512


def main() -> None:
    draft_cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), vocab_size=VOCAB)
    target_cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b").reduced(), name="target",
        vocab_size=VOCAB, d_model=128, num_layers=4, d_ff=256)
    draft = build_model(draft_cfg)
    target = build_model(target_cfg)
    dp = draft.init_params(jax.random.key(1))
    tp = target.init_params(jax.random.key(2))

    prompts = jax.random.randint(jax.random.key(3), (2, 16), 0, VOCAB)
    print(f"draft: {draft_cfg.name} | target: {target_cfg.name}")

    t0 = time.time()
    ref = autoregressive_generate(target, tp, prompts, max_new=32)
    t_ar = time.time() - t0

    t0 = time.time()
    out, stats, _ = sled_generate(
        draft, dp, target, tp, prompts,
        max_new=32, k_max=4, c_th=0.4,  # Eq. 1 dynamic drafting
        greedy=True,
    )
    t_sled = time.time() - t0

    print(f"target-only tokens : {ref[0][:16].tolist()} ...")
    print(f"SLED tokens        : {out[0][:16].tolist()} ...")
    print(f"lossless           : {bool((out == ref).all())}")
    print(f"acceptance rate    : {stats.acceptance_rate:.2f}")
    print(f"tokens/verify round: {stats.tokens_per_round:.2f}")
    print(f"verify rounds      : {stats.rounds} (vs {ref.shape[1]} target steps)")
    print(f"wall (CPU, toy)    : sled {t_sled:.1f}s vs target-only {t_ar:.1f}s")


if __name__ == "__main__":
    main()
