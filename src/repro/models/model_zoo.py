"""Dispatch: one uniform functional interface over every model family.

``build_model(cfg)`` returns a ``Model`` with:
  init_params(key, max_pos=0)          -> params pytree
  forward(params, tokens, ctx, **kw)   -> (hidden, aux)       [training]
  lm_head(params, hidden)              -> logits fp32
  make_cache(batch, max_len, ...)      -> cache pytree (or specs)
  prefill(params, tokens, cache, ctx)  -> (last logits, cache)
  decode_forward(params, cache, toks)  -> (hidden, ckpt_cache, aux)  [verify]
  commit(cache, n_commit)              -> cache  [speculative rollback]

The stub frontends ([audio]/[vlm]) enter via forward/prefill kwargs
(``enc_frames`` / ``embeds_prefix``) — precomputed embeddings per the
assignment ("the modality frontend is a STUB").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import hybrid as HY
from repro.models import mamba2 as M2
from repro.models import transformer as T
from repro.models.layers import NO_MESH


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init_params: Callable
    forward: Callable
    lm_head: Callable
    make_cache: Callable
    prefill: Callable
    decode_forward: Callable
    commit: Callable

    def init_params_spec(self, **kw):
        return jax.eval_shape(lambda k: self.init_params(k, **kw), jax.random.key(0))


def _attn_commit(cache, n_commit):
    return {
        k: v for k, v in cache.items() if not k.endswith("_ckpt")
    } | {"length": cache["length"] + n_commit.astype(jnp.int32)}


def _ssm_commit(select):
    def commit(cache, n_commit):
        return select(cache, n_commit)

    return commit


def build_model(cfg) -> Model:
    if cfg.family == "ssm":
        mod, commit = M2, M2.select_checkpoint
    elif cfg.family == "hybrid":
        mod, commit = HY, HY.select_checkpoint
    else:  # dense | moe | vlm | encdec
        mod, commit = T, _attn_commit

    return Model(
        cfg=cfg,
        init_params=lambda key, **kw: mod.init_params(cfg, key, **kw),
        forward=lambda params, tokens, ctx=NO_MESH, **kw: mod.forward(cfg, params, tokens, ctx, **kw),
        lm_head=lambda params, h: mod.lm_head(cfg, params, h),
        make_cache=lambda batch, max_len, **kw: mod.make_cache(cfg, batch, max_len, **kw),
        prefill=lambda params, tokens, cache, ctx=NO_MESH, **kw: mod.prefill(
            cfg, params, tokens, cache, ctx, **kw
        ),
        decode_forward=lambda params, cache, tokens, ctx=NO_MESH, **kw: mod.decode_forward(
            cfg, params, cache, tokens, ctx, **kw
        ),
        commit=commit,
    )


def frontend_stub(cfg, batch: int, key=None, *, spec_only: bool = False):
    """Precomputed modality embeddings for [audio]/[vlm] archs (stub frontend).

    whisper: (B, encoder_seq, d) frame embeddings.
    llava:   (B, num_patches, d) patch embeddings.
    """
    if cfg.family == "encdec":
        shape = (batch, cfg.encoder_seq, cfg.d_model)
    elif cfg.family == "vlm":
        shape = (batch, cfg.num_patches, cfg.d_model)
    else:
        return None
    if spec_only:
        return jax.ShapeDtypeStruct(shape, jnp.bfloat16)
    key = key if key is not None else jax.random.key(7)
    return (jax.random.normal(key, shape) * 0.02).astype(jnp.bfloat16)


def perturb_params(params, scale: float, seed: int = 7):
    """Gaussian-perturb matrix leaves (norms/scalars untouched).

    Two random-init reduced models tend to agree on greedy argmax, which
    makes speculative acceptance trivially 1.0; perturbing the draft params
    dials in realistic partial-acceptance rates for tests and benchmarks
    (scale ~0.02 gives ~0.9 acceptance on the reduced qwen2 pair).
    """
    if scale <= 0:
        return params
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.key(seed), max(len(leaves), 1))
    noisy = [
        leaf + scale * jax.random.normal(key, leaf.shape, leaf.dtype)
        if hasattr(leaf, "ndim") and leaf.ndim >= 2
        else leaf
        for leaf, key in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noisy)
