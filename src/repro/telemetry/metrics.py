"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

No external dependencies — the registry renders both a Prometheus-style text
exposition (``exposition()``) and a JSON snapshot (``snapshot()``), which is
what crosses process boundaries inside codec v3 ``ReplicaStats`` telemetry
payloads and lands in BENCH artifacts.

Everything here is observation-only and cheap: a metric update is a dict hit
plus a locked float add, and the :func:`span` context manager short-circuits
to a shared no-op object while telemetry is disabled, so instrumenting a
host-side boundary costs one global-flag check per round when off.  Nothing
in this module ever runs inside a jitted computation.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Sequence, Tuple, Union

# default span buckets: sub-millisecond device hops up through multi-second
# straggler rounds (seconds, ascending; +Inf is implicit)
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
# speculation-length buckets: k is small and integral
K_BUCKETS: Tuple[float, ...] = (1, 2, 3, 4, 6, 8, 12, 16)
# drafting-confidence buckets: c_th lives on [0, 1]
C_TH_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0,
)

_LabelArg = Optional[Dict[str, Union[str, int]]]
_Key = Tuple[str, Tuple[Tuple[str, str], ...]]

_LOCK = threading.Lock()  # shared by every metric: updates are rare (per
# round, host-side) and the critical section is a float add


def _label_items(labels: _LabelArg) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(items: Sequence[Tuple[str, str]]) -> str:
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def _prom_name(name: str) -> str:
    out = "".join(c if (c.isalnum() or c == "_") else "_" for c in name)
    return out if out.startswith("repro_") else f"repro_{out}"


class Counter:
    """Monotonically increasing count."""

    kind = "counter"
    __slots__ = ("name", "help", "labels", "_value")

    def __init__(self, name: str, help: str = "", labels: _LabelArg = None):
        self.name = name
        self.help = help
        self.labels = _label_items(labels)
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        with _LOCK:
            self._value += v

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value (set or adjusted)."""

    kind = "gauge"
    __slots__ = ("name", "help", "labels", "_value")

    def __init__(self, name: str, help: str = "", labels: _LabelArg = None):
        self.name = name
        self.help = help
        self.labels = _label_items(labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with _LOCK:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with _LOCK:
            self._value += v

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram (Prometheus-style cumulative exposition).

    Buckets are upper bounds in ascending order; observations above the last
    bound land in the implicit +Inf bucket.  ``quantile`` interpolates inside
    the winning bucket, which is as precise as a fixed-bucket histogram gets —
    good enough for a p50/p95 column in ``repro top``.
    """

    kind = "histogram"
    __slots__ = ("name", "help", "labels", "buckets", "counts", "sum", "count")

    def __init__(
        self,
        name: str,
        buckets: Sequence[float] = LATENCY_BUCKETS_S,
        help: str = "",
        labels: _LabelArg = None,
    ):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram buckets must be ascending, got {buckets!r}")
        self.name = name
        self.help = help
        self.labels = _label_items(labels)
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        n = len(self.buckets)
        while i < n and v > self.buckets[i]:
            i += 1
        with _LOCK:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0..1) by interpolating within buckets."""
        if not self.count:
            return 0.0
        target = q * self.count
        cum = 0
        lo = 0.0
        for i, ub in enumerate(self.buckets):
            prev = cum
            cum += self.counts[i]
            if cum >= target:
                frac = (target - prev) / max(self.counts[i], 1)
                return lo + frac * (ub - lo)
            lo = ub
        return self.buckets[-1]  # fell in +Inf: clamp to the last finite bound

    def to_json(self) -> dict:
        cum, rows = 0, []
        for i, ub in enumerate(self.buckets):
            cum += self.counts[i]
            rows.append([ub, cum])
        rows.append(["+Inf", cum + self.counts[-1]])
        return {
            "sum": self.sum,
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "buckets": rows,
        }


class MetricsRegistry:
    """Name+labels → metric, with get-or-create semantics.

    One registry per process (module-level default in
    :mod:`repro.telemetry`); workers ship their registry's ``snapshot()``
    back over the control plane inside ``ReplicaStats``.
    """

    def __init__(self) -> None:
        self._metrics: Dict[_Key, Union[Counter, Gauge, Histogram]] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: _LabelArg, **kw):
        key = (name, _label_items(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels=labels, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, not {cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "", labels: _LabelArg = None) -> Counter:
        return self._get(Counter, name, labels, help=help)

    def gauge(self, name: str, help: str = "", labels: _LabelArg = None) -> Gauge:
        return self._get(Gauge, name, labels, help=help)

    def histogram(
        self,
        name: str,
        buckets: Sequence[float] = LATENCY_BUCKETS_S,
        help: str = "",
        labels: _LabelArg = None,
    ) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets, help=help)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """JSON-shaped dump: {counters, gauges, histograms} keyed by
        ``name`` or ``name{label="v"}``."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            key = m.name + _fmt_labels(m.labels)
            if isinstance(m, Histogram):
                out["histograms"][key] = m.to_json()
            elif isinstance(m, Counter):
                out["counters"][key] = m.value
            else:
                out["gauges"][key] = m.value
        return out

    def exposition(self) -> str:
        """Prometheus text-format exposition of every registered metric."""
        with self._lock:
            metrics = sorted(
                self._metrics.values(), key=lambda m: (m.name, m.labels)
            )
        lines = []
        seen_header = set()
        for m in metrics:
            pname = _prom_name(m.name)
            if pname not in seen_header:
                seen_header.add(pname)
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for i, ub in enumerate(m.buckets):
                    cum += m.counts[i]
                    lbl = _fmt_labels(m.labels + (("le", repr(ub)),))
                    lines.append(f"{pname}_bucket{lbl} {cum}")
                lbl = _fmt_labels(m.labels + (("le", "+Inf"),))
                lines.append(f"{pname}_bucket{lbl} {cum + m.counts[-1]}")
                base = _fmt_labels(m.labels)
                lines.append(f"{pname}_sum{base} {m.sum}")
                lines.append(f"{pname}_count{base} {m.count}")
            else:
                lines.append(f"{pname}{_fmt_labels(m.labels)} {m.value}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# the process-global registry + enable switch, and the span primitive
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()
_ENABLED = False


def registry() -> MetricsRegistry:
    return _REGISTRY


def enable(on: bool = True) -> None:
    """Flip telemetry collection for this process (spans + traces).

    Off by default: instrumented call sites pay one flag check per round.
    ``System.build`` turns it on when the spec says ``telemetry: true`` (and
    a worker does the same when placed with such a spec); benchmarks flip it
    both ways to measure overhead.
    """
    global _ENABLED
    _ENABLED = bool(on)


def enabled() -> bool:
    return _ENABLED


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "labels", "t0", "seconds")

    def __init__(self, name: str, labels: _LabelArg):
        self.name = name
        self.labels = labels
        self.t0 = 0.0
        self.seconds = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        _REGISTRY.histogram(self.name, labels=self.labels).observe(self.seconds)
        return False


def span(name: str, labels: _LabelArg = None):
    """Monotonic-clock span → histogram ``name``; a shared no-op when
    telemetry is disabled.  Host-side boundaries only — never wrap jitted
    code with this (the span would time dispatch, not compute)."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, labels)


def observe(name: str, value: float, buckets: Sequence[float] = LATENCY_BUCKETS_S,
            labels: _LabelArg = None) -> None:
    """Record one histogram observation iff telemetry is enabled."""
    if _ENABLED:
        _REGISTRY.histogram(name, buckets=buckets, labels=labels).observe(value)


def count(name: str, v: float = 1.0, labels: _LabelArg = None) -> None:
    """Bump a counter iff telemetry is enabled."""
    if _ENABLED:
        _REGISTRY.counter(name, labels=labels).inc(v)
