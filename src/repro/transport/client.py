"""Asyncio edge-device client: pipelined drafting over a transport link.

``EdgeClient`` runs one device's §III-A loop against a TransportServer:

  admission   Hello -> Admit (retried on loss; waits out a full pool)
  round       DraftPacket(seq) -> [draft ahead while in flight] -> Verdict
  pipelining  after sending a round the client keeps drafting on the
              assumption of full acceptance (EdgeDevice.draft_ahead); a
              confirmed guess submits the pre-drafted round immediately —
              draft latency hides under the network round trip, which is
              where edge-assisted serving wins (SpecEdge)
  timeout     no verdict within ``verify_timeout`` -> the client releases
              its drafts locally (paper fallback) and sends a Fallback
              frame; the server's reply arbitrates the race — FallbackAck
              confirms the resync, a (late) Verdict overrides it.  The
              client never mutates draft-cache state until the server has
              arbitrated, so client and server token streams can never
              diverge.
  link loss   a ConnectionError mid-stream (peer closed, socket died) no
              longer kills the session coroutine: with a ``reconnect``
              hook installed the client redials under a bounded, seeded
              jittered backoff, re-Hellos (the server resends Admit for an
              admitted stream), and resyncs the open round through the
              SAME Fallback arbitration as a timeout — so a flapped link
              converges exactly like a slow one.
  adaptive k  with ``kctl="adaptive"`` the client feeds each Verdict's
              accept_rate/queue_depth feedback to a bounded AIMD controller
              (serving/speclen.py) and caps the next round's draft length
              at the controller's k — closed-loop spec-length control.
              ``kctl="fixed"`` (default) always drafts the kit's k_max and
              is bit-identical to the pre-feedback client.
  adaptive c  ``cctl="adaptive"`` moves the drafting confidence bar c_th
              from the same feedback (serving/speclen.ConfidenceController):
              low acceptance raises the bar (shorter, surer rounds), high
              acceptance lowers it.  c_th rides into the jitted draft step
              as a traced scalar, so adapting never recompiles.

The client's committed stream is exactly the server's committed stream for
its slot; on zero-latency lossless links it is token-for-token identical to
the lock-step reference (tests + launch/serve.py --check enforce this).
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro import telemetry
from repro.core.server_engine import EdgeDevice, EdgeDeviceKit
from repro.serving.speclen import make_confidence_controller, make_controller
from repro.transport import codec
from repro.transport.links import Endpoint


@dataclasses.dataclass
class ClientStats:
    device_id: int
    rounds: int = 0
    committed: int = 0
    pipeline_hits: int = 0
    pipeline_misses: int = 0
    fallback_rounds: int = 0
    fallback_tokens: int = 0
    drafted: int = 0  # device-side draft() tokens (excludes ahead-drafts)
    late_verdicts: int = 0
    hello_retries: int = 0
    reconnects: int = 0  # mid-stream link deaths survived by redialing
    bytes_tx: int = 0
    bytes_rx: int = 0
    frames_tx: int = 0
    frames_rx: int = 0
    frames_dropped: int = 0
    wall_seconds: float = 0.0
    k_final: int = 0  # spec length after the last controller update
    k_mean: float = 0.0  # mean proposal length actually sent per round
    c_th_final: float = 0.0  # confidence bar after the last controller update
    c_th_mean: float = 0.0  # mean confidence bar across controller updates

    def to_json(self) -> dict:
        """Uniform stats record (json.dumps-safe), mirroring
        EngineStats.to_json — the one shape BENCH artifacts emit."""
        return dataclasses.asdict(self)

    def as_dict(self):
        return self.to_json()

    @classmethod
    def merge(cls, stats: List["ClientStats"]) -> "ClientStats":
        """Fleet-level sum (count fields) / mean (k, wall): launchers and
        benchmarks report one record instead of hand-summing per client."""
        if not stats:
            return cls(device_id=-1)
        out = cls(device_id=-1)
        for f in dataclasses.fields(cls):
            if f.name == "device_id":
                continue
            vals = [getattr(s, f.name) for s in stats]
            if f.name == "k_final":
                out.k_final = round(sum(vals) / len(vals))
            elif f.name in ("k_mean", "wall_seconds", "c_th_final", "c_th_mean"):
                setattr(out, f.name, float(sum(vals) / len(vals)))
            else:
                setattr(out, f.name, sum(vals))
        return out


class ProtocolError(RuntimeError):
    pass


class EdgeClient:
    def __init__(
        self,
        kit: EdgeDeviceKit,
        device_id: int,
        prompt: np.ndarray,
        endpoint: Endpoint,
        *,
        max_new: int,
        max_len: int,
        qmode: str = "none",
        pipeline: bool = True,
        verify_timeout: float = 2.0,
        admit_timeout: float = 2.0,
        max_retries: int = 64,
        draft_rate: Optional[float] = None,
        kctl: str = "fixed",
        kctl_kw: Optional[dict] = None,
        cctl: str = "fixed",
        cctl_kw: Optional[dict] = None,
        seed: int = 0,
        on_round: Optional[Callable[[np.ndarray, int, int, bool], None]] = None,
        reconnect: Optional[Callable[[], "asyncio.Future"]] = None,
        max_reconnects: int = 4,
    ):
        self.kit = kit
        self.device_id = device_id
        self.prompt = np.asarray(prompt, np.int32)
        self.ep = endpoint
        self.max_new = max_new
        self.max_len = max_len
        self.qmode = qmode
        self.pipeline = pipeline and kit.supports_pipeline
        self.verify_timeout = verify_timeout
        self.admit_timeout = admit_timeout
        self.max_retries = max_retries
        # emulated device speed (tokens/s): tiny reduced models draft orders
        # of magnitude faster than the paper's edge boards, so a fleet can
        # throttle to DeviceProfile rates — the sleep overlaps other clients'
        # compute, restoring the concurrency a real fleet would have
        self.draft_rate = draft_rate
        # closed-loop spec length: None (fixed k_max) or an AIMD controller
        # fed by the Verdict accept_rate/queue_depth feedback fields
        self.kctl = make_controller(kctl, k_max=kit.k_max, **(kctl_kw or {}))
        # closed-loop drafting confidence: None (the kit's fixed c_th) or a
        # bounded additive controller on the same Verdict feedback — the
        # k/c_th pair is the full per-device drafting policy
        self.cctl = make_confidence_controller(
            cctl, c_init=kit.c_th, device_id=device_id, **(cctl_kw or {})
        )
        # per-round observer (repro.api streaming events): called with
        # (committed_tokens, n_drafted, n_accepted, fallback) as each round
        # resolves — fallback rounds pass the locally-released tokens
        self.on_round = on_round
        # mid-stream link recovery: an async callable returning a FRESH
        # Endpoint already attached to the server (None = legacy behavior,
        # ConnectionError escapes).  Redials are bounded by max_reconnects
        # and paced by a seeded jittered backoff so chaos runs replay.
        self.reconnect_cb = reconnect
        self.max_reconnects = max_reconnects
        self._backoff = None
        self.seed = seed
        self.stats = ClientStats(device_id=device_id)
        self.device: Optional[EdgeDevice] = None
        # per-round trace (telemetry on): each verdict's server-timing fields
        # let the client attribute round latency to queue vs verify vs wire
        self.trace: List[telemetry.TraceEvent] = []

    # -- wire helpers --------------------------------------------------------

    async def _send(self, msg) -> None:
        await self.ep.send(codec.encode_frame(msg))

    async def _recv(self, timeout: Optional[float]):
        """One decoded message, or None on timeout; ConnectionError if the
        server side closed."""
        try:
            frame = await asyncio.wait_for(self.ep.recv(), timeout)
        except asyncio.TimeoutError:
            return None
        if frame is None:
            raise ConnectionError(f"device {self.device_id}: server closed the link")
        return codec.decode_frame(frame)[0]

    async def _redial(self, cause: BaseException) -> None:
        """The link died mid-stream: dial a fresh endpoint (bounded, seeded
        jittered backoff) and re-Hello.  The server answers a duplicate
        Hello for an admitted stream by resending Admit — re-admission is
        state-free — after which the caller resyncs any open round through
        the Fallback arbitration path.  The new link is live (and mapped in
        the server's connection table) BEFORE the dead one is closed, so
        the server never mistakes the redial for a device that vanished."""
        if self.reconnect_cb is None:
            raise cause
        if self._backoff is None:
            # lazy import: transport is a lower layer than cluster, and only
            # reconnect-enabled clients pay for the dependency
            from repro.cluster.faults import Backoff

            self._backoff = Backoff(
                base_s=0.05, max_s=1.0, jitter=0.1, seed=self.device_id
            )
        while True:
            if self.stats.reconnects >= self.max_reconnects:
                raise ProtocolError(
                    f"device {self.device_id}: link lost and "
                    f"{self.max_reconnects} reconnects exhausted"
                ) from cause
            await asyncio.sleep(self._backoff.attempt())
            self.stats.reconnects += 1
            telemetry.count("client_reconnects_total")
            try:
                fresh = await self.reconnect_cb()
                old = self.ep
                self.ep = fresh
                await self._admission()
            except ConnectionError:
                continue
            self._fold_link_stats(old)
            old.close()
            return

    def _fold_link_stats(self, ep: Endpoint) -> None:
        """Bank a dead endpoint's wire counters before abandoning it."""
        for f in ("bytes_tx", "bytes_rx", "frames_tx", "frames_rx", "frames_dropped"):
            setattr(self.stats, f, getattr(self.stats, f) + getattr(ep.stats, f))

    # -- protocol phases -----------------------------------------------------

    async def _admission(self) -> None:
        for _ in range(self.max_retries):
            await self._send(codec.Hello(self.device_id, self.prompt))
            deadline = asyncio.get_running_loop().time() + self.admit_timeout
            while True:
                left = deadline - asyncio.get_running_loop().time()
                msg = await self._recv(max(left, 0.0)) if left > 0 else None
                if msg is None:
                    self.stats.hello_retries += 1
                    break  # resend Hello
                if isinstance(msg, codec.Admit):
                    if msg.ok:
                        return
                    # pool full: the server queued us; wait for the real Admit
                    # without a deadline cap tied to admission retries
                    deadline = asyncio.get_running_loop().time() + 60.0
                # anything else pre-admission is a stale frame; keep waiting
        raise ProtocolError(f"device {self.device_id}: admission failed after retries")

    async def _await_verdict(self, seq: int, draft_tokens: np.ndarray):
        """Wait out one round.  Returns (verdict, fell_back): a codec.Verdict
        for seq (authoritative), or (None, True) after a server-confirmed
        fallback resync."""
        sent_fallback = False
        for _ in range(self.max_retries):
            try:
                msg = await self._recv(self.verify_timeout)
            except ConnectionError as e:
                # link died while the round was in flight: redial, then let
                # the same Fallback arbitration below resolve the round —
                # the server either resends the stored verdict or confirms
                # a resync, exactly as if the verdict had merely been slow
                await self._redial(e)
                msg = None
            if msg is None:
                # round timed out (or the link was just re-dialed): ask the
                # server to resync on our local release; state stays
                # untouched until the server arbitrates
                sent_fallback = True
                try:
                    await self._send(codec.Fallback(self.device_id, seq, draft_tokens))
                except ConnectionError as e:
                    await self._redial(e)
                continue
            if isinstance(msg, codec.Verdict):
                if msg.seq == seq:
                    if sent_fallback:
                        self.stats.late_verdicts += 1
                    return msg, False
                continue  # duplicate of an older round
            if isinstance(msg, codec.FallbackAck):
                if msg.seq == seq:
                    return None, True
                continue
            if isinstance(msg, codec.Admit):
                continue  # duplicate admission reply
            raise ProtocolError(f"device {self.device_id}: unexpected {type(msg).__name__}")
        raise ProtocolError(f"device {self.device_id}: round {seq} unresolved after retries")

    # -- main loop -----------------------------------------------------------

    async def run(self) -> List[int]:
        t0 = asyncio.get_running_loop().time()
        await self._admission()
        dev = self.device = EdgeDevice(
            self.kit, self.device_id, self.prompt, max_len=self.max_len, seed=self.seed
        )
        loop = asyncio.get_running_loop()

        async def throttle(n: int, since: Optional[float] = None) -> float:
            """Emulate drafting ``n`` tokens at the device's rate; time spent
            waiting on the network (``since``) already counts (sim's
            draft-ahead carry: need/device_rate).  Returns the NOMINAL
            drafting bill — the full n/rate — which ``draft_s`` reports so
            profiling recovers the emulated hardware rate even when
            pipelining hid part of the sleep under the round trip."""
            if not self.draft_rate:
                return 0.0
            need = n / self.draft_rate
            wait = need if since is None else need - (loop.time() - since)
            if wait > 0:
                await asyncio.sleep(wait)
            return need

        seq = 0
        k = self.kctl.k if self.kctl else None  # None: fixed k_max drafting
        c = self.cctl.c if self.cctl else None  # None: fixed kit c_th
        k_log = []
        t_d = loop.time()
        tokens = dev.draft(k=k, c_th=c)
        draft_s = loop.time() - t_d
        draft_s += await throttle(len(tokens))
        while True:
            q = dev.pending_q if self.qmode != "none" else None
            try:
                await self._send(
                    codec.DraftPacket(self.device_id, seq, tokens, draft_q=q, qmode=self.qmode)
                )
            except ConnectionError as e:
                # link died between rounds: redial and resend this round's
                # packet on the fresh link (the server dedups by seq)
                await self._redial(e)
                continue
            self.stats.rounds += 1
            # log what actually went on the wire: under pipelining a verdict
            # may shrink k after the next proposal was already pre-drafted,
            # and c_th confidence stopping shortens rounds below the cap
            k_log.append(len(tokens))
            t_sent = loop.time()
            if self.pipeline:
                # the round trip is in flight: keep drafting on speculation
                dev.draft_ahead(k=k, c_th=c)
                await asyncio.sleep(0)  # hand the loop to the server/link
            verdict, fell_back = await self._await_verdict(seq, tokens)
            rtt = loop.time() - t_sent
            traced = telemetry.enabled()
            if fell_back:
                released = dev.fallback_release()
                self.stats.fallback_rounds += 1
                next_tokens = None
                if traced:
                    telemetry.count("client_fallback_rounds_total")
                    self.trace.append(telemetry.TraceEvent(
                        device_id=self.device_id, round=seq, t=loop.time(),
                        k=len(tokens), n_accepted=0, n_commit=len(released),
                        draft_s=draft_s, fallback=True,
                    ))
                if self.on_round is not None:
                    self.on_round(released, len(tokens), 0, True)
            else:
                next_tokens = dev.on_verdict(verdict)
                if self.kctl is not None:
                    # closed loop: acceptance + replica congestion -> next k
                    k = self.kctl.update(verdict.accept_rate, verdict.queue_depth)
                if self.cctl is not None:
                    # same feedback moves the confidence bar the other way:
                    # low acceptance tightens, high acceptance relaxes
                    c = self.cctl.update(verdict.accept_rate, verdict.queue_depth)
                if traced:
                    # server-timing attribution: what the round trip spent in
                    # the replica's queue + verify; the rest was the wire
                    wire_s = max(rtt - verdict.queue_s - verdict.verify_s, 0.0)
                    telemetry.observe("client_round_seconds", rtt)
                    telemetry.observe("client_wire_seconds", wire_s)
                    telemetry.observe("client_draft_seconds", draft_s)
                    self.trace.append(telemetry.TraceEvent(
                        device_id=self.device_id, round=seq, t=loop.time(),
                        k=len(tokens), n_accepted=int(verdict.n_accepted),
                        n_commit=len(verdict.tokens),
                        queue_s=float(verdict.queue_s),
                        verify_s=float(verdict.verify_s),
                        wire_s=wire_s, draft_s=draft_s,
                    ))
                if self.on_round is not None:
                    self.on_round(verdict.tokens, len(tokens), verdict.n_accepted, False)
            seq += 1
            if len(dev.committed) >= self.max_new:
                break
            if next_tokens is not None:
                tokens = next_tokens
                # pre-drafted during the round trip: only the remainder of
                # the emulated drafting time is paid in the foreground, but
                # the trace bills the full nominal cost (see throttle)
                draft_s = await throttle(len(tokens), since=t_sent)
            else:
                t_d = loop.time()
                tokens = dev.draft(k=k, c_th=c)
                draft_s = loop.time() - t_d
                draft_s += await throttle(len(tokens))
        try:
            await self._send(codec.Close(self.device_id))
        except ConnectionError:
            pass  # best effort; the server reclaims the slot on conn loss
        self.ep.close()
        self.stats.committed = min(len(dev.committed), self.max_new)
        self.stats.pipeline_hits = dev.pipeline_hits
        self.stats.pipeline_misses = dev.pipeline_misses
        self.stats.fallback_tokens = dev.fallback_tokens
        self.stats.drafted = dev.drafted
        self._fold_link_stats(self.ep)  # += : earlier links already banked
        self.stats.wall_seconds = asyncio.get_running_loop().time() - t0
        self.stats.k_final = self.kctl.k if self.kctl else self.kit.k_max
        self.stats.k_mean = float(sum(k_log) / len(k_log)) if k_log else 0.0
        self.stats.c_th_final = self.cctl.c if self.cctl else self.kit.c_th
        self.stats.c_th_mean = self.cctl.c_mean if self.cctl else self.kit.c_th
        return dev.committed[: self.max_new]
