"""Paper Fig. 6: cost/throughput Pareto — all-server / all-edge / SLED
x {16,8,4}-bit x N in {1,2,4,8,16} devices.

Cost is Eq. 2 dollars per 1K verified tokens (serving/cost_model.py); SLED
devices additionally pay their share of the shared server.  Validation
targets from the paper's text: SLED dominates the frontier; ~137 tok/s at
16 devices 4-bit with cost ~0.13 $/1K; >3x throughput over centralized at
~29% of its cost at matched capacity.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.serving.cost_model import cost_per_1k_tokens, sled_cost_per_1k
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, simulate


def run(quick: bool = False) -> list:
    rows = []
    dev = RPI5
    bit_speed = {16: 1.0, 8: 1.9, 4: 3.6}  # llama.cpp-style decode scaling
    ns = (1, 2, 4, 8, 16) if not quick else (1, 4, 16)
    for bits in (16, 8, 4):
        for n in ns:
            rate = dev.rate("llama-1b-draft", bits)
            sim = SimConfig(
                mode="sled", spec_len=4, acceptance=0.90, device_rate=rate,
                target_params=11e9, server_batch=min(16, n), bits=bits,
                batch_policy="deadline", n_devices=n,
                sim_time=10.0 if quick else 25.0,
            )
            s = simulate(sim, A100_X4)
            c = simulate(dataclasses.replace(sim, mode="centralized"), A100_X4)
            e = simulate(dataclasses.replace(sim, mode="all_edge"), A100_X4)
            # quality-adjacent all-edge: the biggest local model the device
            # fits (3B) — all-edge with the 1B draft yields draft-quality
            # tokens, not target-quality ones
            e3_rate = dev.rate("llama-3b-draft", bits)
            e3 = simulate(dataclasses.replace(sim, mode="all_edge",
                                              device_rate=e3_rate), A100_X4)
            # server share: fraction of server busy time attributable per device
            share = s.server_busy_frac / max(n, 1)
            sled_cost = sled_cost_per_1k(s.per_device_rate, dev, A100_X4, share)
            cent_cost = cost_per_1k_tokens(
                c.wstgr, A100_X4.price_usd, A100_X4.power_w)
            edge_cost = cost_per_1k_tokens(rate, dev.price_usd, dev.power_w)
            edge3_cost = cost_per_1k_tokens(e3_rate, dev.price_usd, dev.power_w)
            rows.append({
                "bits": bits, "n": n,
                "sled_tok_s": round(s.wstgr, 1), "sled_cost": round(sled_cost, 4),
                "cent_tok_s": round(c.wstgr, 1), "cent_cost": round(cent_cost, 4),
                "edge1b_tok_s": round(e.wstgr, 1), "edge1b_cost": round(edge_cost, 4),
                "edge3b_tok_s": round(e3.wstgr, 1), "edge3b_cost": round(edge3_cost, 4),
            })
    # Pareto check at TARGET-model quality, same deployment size (bits, N):
    # is SLED ever dominated (>= throughput AND <= cost) by centralized or
    # by the quality-adjacent all-edge (3B local)?  All-edge with the 1B
    # draft is a different quality class (reported for reference; SLED's
    # advantage #1 in the paper is precisely the quality upgrade).
    dominated = 0
    for r in rows:
        for pre in ("cent", "edge3b"):
            if (r[f"{pre}_tok_s"] >= r["sled_tok_s"]
                    and r[f"{pre}_cost"] <= r["sled_cost"]):
                dominated += 1
                break
    best_e3 = max(r["edge3b_tok_s"] for r in rows)
    best_e1 = max(r["edge1b_tok_s"] for r in rows)
    best_sled = max(r["sled_tok_s"] for r in rows)
    rows.append({
        "sled_points_dominated": dominated, "total": len(rows),
        "best_sled_vs_edge3b": round(best_sled / best_e3, 2),
        "best_sled_vs_edge1b": round(best_sled / best_e1, 2),
        "paper_claim_vs_best_edge": 1.65,
    })
    emit(rows, "fig6_pareto")
    return rows


if __name__ == "__main__":
    run()
