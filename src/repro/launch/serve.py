"""SLED serving launcher: N edge clients + a replica-sharded cluster server.

The server side is a cluster Router (``--replicas``): N engine replicas
sharing one compiled step bundle behind a pluggable placement policy
(``--placement least-loaded|affinity|round-robin``), with stream migration
on retire.  ``--replicas 1`` is the single-engine special case and must stay
token-for-token identical to the lock-step reference.  ``--kctl adaptive``
closes the spec-length loop: Verdict frames carry acceptance + queue-depth
feedback and each client's AIMD controller tunes its draft length online
(adaptive runs skip the equivalence check — adapting k legitimately changes
scheduling AND tokens drafted per round).

Three transports share the same models, cluster, and equivalence check:

  loopback  (default) clients and server exchange wire-protocol frames over
            zero-latency in-memory links — the full codec/admission/verdict
            path with no network effects, so committed tokens must equal the
            lock-step reference (engine_loop.sled_generate) token-for-token
            under EVERY batch policy.
  sim       frames pay latency/bandwidth/jitter/drop from a NetProfile
            (serving/devices.py NETS) per link: RTT hiding via pipelined
            draft-ahead, straggler timeouts, and §III-A local fallback are
            real runtime behaviour.  Lossy profiles trade equivalence for
            availability (fallback tokens are unverified) — exactly the
            paper's trade.
  inproc    PR-1's in-process driver loop (no wire protocol), kept as the
            minimal engine demo.

    PYTHONPATH=src python -m repro.launch.serve --devices 6                # loopback
    PYTHONPATH=src python -m repro.launch.serve --transport sim --net wlan
    PYTHONPATH=src python -m repro.launch.serve --transport sim --net lossy-wlan --no-check
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --kctl adaptive \
        --transport sim --draft-noise 0.05 --no-check
"""

import argparse
import asyncio
import dataclasses
import math
import time

import jax
import numpy as np

from repro.cluster import PLACEMENT_POLICIES, Router
from repro.configs.base import get_config
from repro.core.engine_loop import sled_generate
from repro.core.server_engine import EdgeDeviceKit
from repro.models.model_zoo import build_model, perturb_params
from repro.quant.quantize import dequantize_pytree, quantize_pytree
from repro.serving.devices import NETS
from repro.transport.client import ClientStats, EdgeClient
from repro.transport.links import make_link
from repro.transport.server import TransportServer


def build_stack(args):
    """Models, cluster router, device kit, prompts — shared by every transport."""
    vocab = 256
    tcfg = dataclasses.replace(get_config(args.arch).reduced(), vocab_size=vocab)
    dcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="edge-draft", vocab_size=vocab, num_layers=1
    )
    target = build_model(tcfg)
    draft = build_model(dcfg)
    kw = {"max_pos": 256} if not tcfg.use_rope else {}
    tp = target.init_params(jax.random.key(0), **kw)
    if args.bits < 16:
        tp = dequantize_pytree(quantize_pytree(tp, args.bits))
        print(f"serving int{args.bits} weight-only quantized target")
    dp = perturb_params(draft.init_params(jax.random.key(1)), args.draft_noise)

    N = args.devices
    prompts = jax.random.randint(jax.random.key(2), (N, 12), 0, vocab)
    # per-replica slots: the fleet's pool capacity splits across replicas
    # (total capacity >= devices unless --slots caps it explicitly)
    slots = args.slots or math.ceil(N / args.replicas)
    router = Router.build(
        target,
        tp,
        replicas=args.replicas,
        n_slots=slots,
        placement=args.placement,
        max_len=128,
        k_max=args.k_max,
        policy=args.policy,
        max_wait=args.max_wait,
        straggler_timeout=args.verify_timeout,
        attn_chunk=32,
        paged_attention=args.paged_attention,
    )
    if args.replicas > 1:
        print(
            f"cluster: {args.replicas} replicas x {slots} slots, "
            f"placement {args.placement}, shared step bundle"
        )
    if args.paged_attention and not router.paged_attention:
        print(f"paged attention unsupported for family {tcfg.family}: gather fallback")
    kit = EdgeDeviceKit(draft, dp, k_max=args.k_max, c_th=args.c_th, greedy=True, attn_chunk=32)
    return draft, dp, target, tp, router, kit, prompts


def check_outputs(outputs, draft, dp, target, tp, prompts, args) -> bool:
    ref, _, _ = sled_generate(
        draft, dp, target, tp, prompts,
        max_new=args.max_new, k_max=args.k_max, c_th=args.c_th, greedy=True,
    )
    eng = np.array([outputs[i] for i in range(args.devices)])
    match = np.array_equal(eng, np.asarray(ref))
    print(f"greedy lock-step reference match: {'OK' if match else 'MISMATCH'}")
    return match


# ---------------------------------------------------------------------------
# transport modes: wire protocol over loopback / simulated links
# ---------------------------------------------------------------------------


async def serve_transport(args) -> dict:
    draft, dp, target, tp, engine, kit, prompts = build_stack(args)
    N = args.devices
    net = NETS[args.net]
    if args.transport == "sim":
        print(
            f"simulated links: rtt {net.rtt_mean*1e3:.1f}ms ± {net.rtt_jitter*1e3:.1f}ms, "
            f"{net.bandwidth_bps/1e6:.0f} Mbps, drop {net.drop_prob:.1%}"
        )

    server = TransportServer(engine)
    clients = []
    for i in range(N):
        link = make_link(
            "sim" if args.transport == "sim" else "loopback", net=net, seed=1000 + i
        )
        server.attach(link.server)
        clients.append(
            EdgeClient(
                kit, i, np.asarray(prompts[i]), link.device,
                max_new=args.max_new, max_len=128,
                qmode=args.qmode, pipeline=args.pipeline,
                verify_timeout=args.verify_timeout, admit_timeout=args.verify_timeout,
                kctl=args.kctl,
                seed=1000 + i,
            )
        )

    async def run_client(i: int, c: EdgeClient):
        await asyncio.sleep(i * args.stagger_s)  # staggered joins
        return await c.run()

    t0 = time.time()
    outputs = await asyncio.gather(*(run_client(i, c) for i, c in enumerate(clients)))
    wall = time.time() - t0
    for _ in range(500):  # let in-flight Close frames retire their streams
        if not engine.streams:
            break
        await asyncio.sleep(0.01)
    stats = server.stats()
    await server.stop()

    fleet = ClientStats.merge([c.stats for c in clients])
    drops = stats.frames_dropped + fleet.frames_dropped
    print(
        f"served {stats.streams_served} streams, "
        f"{sum(len(o) for o in outputs)} tokens in {stats.rounds} rounds / {wall:.1f}s "
        f"({stats.wstgr:.1f} tok/s) — mean fill {stats.mean_batch_fill:.2f}/{N}, "
        f"{stats.partial_rounds} partial, queue depth {stats.mean_queue_depth:.2f}, "
        f"acceptance {stats.acceptance_rate:.2f}"
    )
    print(
        f"wire: {stats.bytes_rx} B up / {stats.bytes_tx} B down in "
        f"{stats.frames_rx + stats.frames_tx} frames, {drops} dropped — "
        f"pipeline {fleet.pipeline_hits} hits / {fleet.pipeline_misses} misses, "
        f"{fleet.fallback_rounds} fallback rounds "
        f"({stats.fallback_tokens} unverified tokens)"
    )
    if args.replicas > 1:
        print(
            f"cluster: per-replica rounds "
            f"{[s.rounds for s in engine.replica_stats()]}, "
            f"{engine.migrations} migrations"
        )
    if args.kctl == "adaptive":
        print(
            f"adaptive k: mean {fleet.k_mean:.2f}, final "
            f"{[c.stats.k_final for c in clients]} (k_max {args.k_max})"
        )

    result = stats.as_dict()
    result["clients"] = [c.stats.as_dict() for c in clients]
    if args.check:
        if stats.fallback_tokens:
            print("skipping equivalence check: fallback released unverified tokens")
        elif args.kctl != "fixed":
            print("skipping equivalence check: adaptive spec length changes round shapes")
        else:
            out_map = {i: o for i, o in enumerate(outputs)}
            assert check_outputs(out_map, draft, dp, target, tp, prompts, args), (
                "transport serving must be output-identical to sled_generate"
            )
    return result


# ---------------------------------------------------------------------------
# inproc mode: PR-1's in-process engine driver (no wire protocol)
# ---------------------------------------------------------------------------


def serve_inproc(args) -> dict:
    if args.kctl != "fixed":
        raise SystemExit(
            "--kctl adaptive needs the transport runtime (the feedback rides "
            "Verdict frames); use --transport loopback or sim"
        )
    draft, dp, target, tp, engine, kit, prompts = build_stack(args)
    N, max_len = args.devices, 128

    # staggered joins: device i shows up i * stagger ticks into the run, so
    # early rounds verify a strict subset and late rounds drain the tail
    join_at = {i: i * args.stagger for i in range(N)}
    devices, outputs, waiting = {}, {}, set(range(N))
    t0 = time.time()
    tick, rounds = 0, 0
    min_fill, max_fill = N, 0
    while len(outputs) < N:
        tick += 1
        now = time.time() - t0
        for i in sorted(waiting):
            if join_at[i] > tick:
                continue
            if engine.admit(i, prompts[i], now) is None:
                break  # pool full: stays waiting, admitted when a slot frees
            devices[i] = kit.spawn(i, prompts[i], max_len=max_len, seed=1000 + i)
            waiting.discard(i)
        for i, dev in devices.items():
            if not dev.awaiting:
                engine.submit(i, dev.draft(), time.time() - t0)
        verdicts = engine.step(time.time() - t0)
        if verdicts is None:
            continue
        rounds += 1
        min_fill = min(min_fill, len(verdicts))
        max_fill = max(max_fill, len(verdicts))
        for v in verdicts:
            dev = devices[v.device_id]
            dev.on_verdict(v)
            if len(dev.committed) >= args.max_new:
                outputs[v.device_id] = dev.committed[: args.max_new]
                engine.retire(v.device_id)
                del devices[v.device_id]
        if rounds % 5 == 0 or len(verdicts) < N:
            print(
                f"round {rounds:3d}: batch {len(verdicts)}/{N} "
                f"queue {engine.queue_depth} active {len(devices)} "
                f"done {len(outputs)}"
            )

    now = time.time() - t0
    stats = engine.stats(now)
    print(
        f"served {stats.streams_served} streams, "
        f"{sum(len(o) for o in outputs.values())} tokens in {stats.rounds} rounds "
        f"({stats.wstgr:.1f} tok/s on CPU) — mean batch fill "
        f"{stats.mean_batch_fill:.2f}/{N}, {stats.partial_rounds} partial rounds, "
        f"fill range [{min_fill}, {max_fill}]"
    )
    if args.policy == "continuous" and N > 1:
        # deadline/static deliberately wait for fill; only the continuous
        # policy must dispatch whatever subset is queued
        assert min_fill < N, "staggered arrivals should produce a partial batch"

    if args.check:
        assert check_outputs(outputs, draft, dp, target, tp, prompts, args), (
            "continuous-batching engine must be output-identical to sled_generate"
        )
    return stats.as_dict()


def serve(args) -> dict:
    if args.transport == "inproc":
        return serve_inproc(args)
    return asyncio.run(serve_transport(args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2-1.5b")
    ap.add_argument("--transport", choices=("loopback", "sim", "inproc"), default="loopback")
    ap.add_argument("--net", choices=sorted(NETS), default="wlan",
                    help="NetProfile for --transport sim links")
    ap.add_argument("--devices", type=int, default=6)
    ap.add_argument("--replicas", type=int, default=1,
                    help="server engine replicas behind the cluster router")
    ap.add_argument("--placement", choices=sorted(PLACEMENT_POLICIES),
                    default="least-loaded",
                    help="replica placement policy for new streams")
    ap.add_argument("--kctl", choices=("fixed", "adaptive"), default="fixed",
                    help="spec-length control: fixed k_max, or closed-loop "
                         "AIMD on Verdict acceptance/queue-depth feedback")
    ap.add_argument("--slots", type=int, default=0,
                    help="cache pool rows PER REPLICA (0: ceil(devices/replicas))")
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--c-th", type=float, default=0.3)
    ap.add_argument("--max-new", "--steps", dest="max_new", type=int, default=24,
                    help="tokens committed per device")
    ap.add_argument("--policy", choices=("continuous", "deadline", "static"),
                    default="continuous")
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--qmode", choices=("none", "f32", "f16", "int8"), default="none",
                    help="draft-probability payload precision on the wire")
    ap.add_argument("--pipeline", action=argparse.BooleanOptionalAction, default=True,
                    help="draft ahead while a verify round is in flight")
    ap.add_argument("--paged-attention", action=argparse.BooleanOptionalAction, default=True,
                    help="slot-indexed verify attention straight out of the KV "
                         "pool (gather/scatter fallback when off or unsupported)")
    ap.add_argument("--verify-timeout", type=float, default=30.0,
                    help="device-side round timeout before §III-A fallback "
                         "(generous default: first rounds pay jit compiles)")
    ap.add_argument("--stagger", type=int, default=3,
                    help="inproc: device i joins i*stagger scheduler ticks in")
    ap.add_argument("--stagger-s", type=float, default=0.2,
                    help="transport: device i joins i*stagger_s seconds in")
    ap.add_argument("--bits", type=int, default=16, choices=(4, 8, 16))
    ap.add_argument("--draft-noise", type=float, default=0.0,
                    help="perturb draft params (random-init models otherwise "
                         "agree greedily -> trivial 1.0 acceptance)")
    ap.add_argument("--check", action=argparse.BooleanOptionalAction, default=True,
                    help="verify output equals the lock-step reference")
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
