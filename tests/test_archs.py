"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config, list_configs, shape_applicable
from repro.models.model_zoo import build_model, frontend_stub
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step

ASSIGNED = [
    "whisper-tiny", "granite-34b", "phi3-mini-3.8b", "qwen1.5-32b",
    "qwen2-1.5b", "zamba2-1.2b", "mamba2-370m", "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m", "llava-next-mistral-7b",
]

# whisper's enc-dec stack is by far the slowest smoke (30s+): slow tier
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a == "whisper-tiny" else a
    for a in ASSIGNED
]


def test_all_assigned_archs_registered():
    known = list_configs()
    for a in ASSIGNED:
        assert a in known


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), vocab_size=160)
    model = build_model(cfg)
    kw = {"max_pos": 64} if not cfg.use_rope else {}
    params = model.init_params(jax.random.key(0), **kw)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    fkw = {}
    if cfg.family == "encdec":
        fkw["enc_frames"] = frontend_stub(cfg, B)
        batch["frontend"] = fkw["enc_frames"]
    if cfg.family == "vlm":
        fkw["embeds_prefix"] = frontend_stub(cfg, B)
        batch["frontend"] = fkw["embeds_prefix"]

    h, aux = model.forward(params, toks, attn_chunk=16, **fkw)
    expect_S = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert h.shape == (B, expect_S, cfg.d_model)
    assert bool(jnp.isfinite(h).all()), "NaN/inf in forward"
    logits = model.lm_head(params, h)
    assert logits.shape == (B, expect_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    # one train step decreases nothing catastrophic & keeps finiteness
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10),
                       loss_chunk=8, attn_chunk=16)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    p2, opt2, _, metrics = step(params, opt, None, batch)
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    assert int(opt2.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not jnp.array_equal(d0, d1)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_prefill_verify_roundtrip(arch):
    """Every arch supports the SLED serve path: prefill -> verify -> commit."""
    cfg = dataclasses.replace(get_config(arch).reduced(), vocab_size=160)
    model = build_model(cfg)
    kw = {"max_pos": 64} if not cfg.use_rope else {}
    params = model.init_params(jax.random.key(0), **kw)
    B, P, K = 2, 8, 3
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    ckw = {"enc_len": cfg.encoder_seq} if cfg.family == "encdec" else {}
    cache = model.make_cache(B, 48, attn_chunk=16, **ckw)
    pkw = {}
    if cfg.family == "encdec":
        pkw["enc_frames"] = frontend_stub(cfg, B)
    if cfg.family == "vlm":
        pkw["embeds_prefix"] = frontend_stub(cfg, B)
    logits, cache = model.prefill(params, toks, cache, attn_chunk=16, **pkw)
    assert logits.shape == (B, cfg.vocab_size)
    drafts = jax.random.randint(jax.random.key(2), (B, K + 1), 0, cfg.vocab_size)
    h, ck_cache, _ = model.decode_forward(params, cache, drafts, attn_chunk=16)
    assert h.shape == (B, K + 1, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    committed = model.commit(ck_cache, jnp.array([1, K + 1], jnp.int32))
    base = P + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert committed["length"].tolist() == [base + 1, base + K + 1]


def test_long_context_applicability_matrix():
    """long_500k runs only for SSM/hybrid; decode shapes exist everywhere."""
    long = SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), long) for a in ASSIGNED}
    assert runs == {
        "whisper-tiny": False, "granite-34b": False, "phi3-mini-3.8b": False,
        "qwen1.5-32b": False, "qwen2-1.5b": False, "zamba2-1.2b": True,
        "mamba2-370m": True, "qwen3-moe-30b-a3b": False,
        "granite-moe-3b-a800m": False, "llava-next-mistral-7b": False,
    }


def test_exact_assigned_configs():
    """The FULL configs carry the exact assigned hyperparameters."""
    c = get_config("granite-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (88, 6144, 48, 1, 24576, 49152)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.num_experts, c.experts_per_token, c.moe_d_ff) == (128, 8, 768)
    c = get_config("mamba2-370m")
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == (48, 1024, 128, 50280)
    c = get_config("zamba2-1.2b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = get_config("whisper-tiny")
    assert c.is_encdec and c.vocab_size == 51865
    c = get_config("llava-next-mistral-7b")
    assert (c.d_model, c.d_ff, c.num_kv_heads) == (4096, 14336, 8)
