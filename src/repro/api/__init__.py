"""repro.api — the public front door over every SLED execution backend.

    from repro.api import ServeSpec, System

    spec = ServeSpec(backend="transport", devices=4, max_new=16)
    result = System.build(spec).serve()      # ServeResult
    spec.to_json_str()                       # the run as a committable artifact

See :mod:`repro.api.spec` for the declarative config and
:mod:`repro.api.system` for System/Session semantics.
"""

from repro.api.events import (
    DoneEvent,
    Event,
    RoundEvent,
    ServeResult,
    SessionResult,
    TokenEvent,
)
from repro.api.spec import (
    BACKENDS,
    ClusterSpec,
    DeviceClassSpec,
    FaultEvent,
    FaultPolicy,
    FaultSpec,
    FleetSpec,
    ModelSpec,
    ReplicaSpec,
    ResolvedClass,
    SchedulerSpec,
    ServeSpec,
    SpecError,
    TransportSpec,
)
from repro.api.system import (
    KitCache,
    ModelBundle,
    Session,
    System,
    build_draft_variant,
    build_models,
)

__all__ = [
    "BACKENDS",
    "ClusterSpec",
    "DeviceClassSpec",
    "DoneEvent",
    "Event",
    "FaultEvent",
    "FaultPolicy",
    "FaultSpec",
    "FleetSpec",
    "KitCache",
    "ModelBundle",
    "ModelSpec",
    "ReplicaSpec",
    "ResolvedClass",
    "RoundEvent",
    "SchedulerSpec",
    "ServeSpec",
    "ServeResult",
    "Session",
    "SessionResult",
    "SpecError",
    "System",
    "TokenEvent",
    "TransportSpec",
    "build_draft_variant",
    "build_models",
]
