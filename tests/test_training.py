"""Training substrate: convergence, checkpoint/resume, compression, data."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.models.model_zoo import build_model
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import (AdamWConfig, adamw_init, 
                                      compress_grads_int8, lr_schedule)
from repro.training.train_step import TrainConfig, make_train_step


def _setup(vocab=256):
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def test_loss_decreases():
    cfg, model, params = _setup()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=50),
                       loss_chunk=8, attn_chunk=16)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    err = None
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=33, global_batch=8)
    losses = []
    for s in range(25):
        b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, s).items()}
        params, opt, err, m = step(params, opt, err, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_accum_matches_full_batch():
    """grad_accum=4 computes the same update as one big batch (same math,
    different schedule) — within fp tolerance."""
    cfg, model, params = _setup(vocab=64)
    dcfg = DataConfig(vocab_size=64, seq_len=17, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, 0).items()}
    outs = {}
    for accum in (1, 4):
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), grad_accum=accum,
                           loss_chunk=8, attn_chunk=16)
        step = jax.jit(make_train_step(model, tcfg))
        p2, _, _, m = step(params, adamw_init(params), None, batch)
        outs[accum] = (p2, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-2)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=0.1, atol=5e-3)


def test_checkpoint_resume_bitwise():
    """Kill-and-resume training reproduces the exact same trajectory
    (fault tolerance: restart-safety of data pipeline + optimizer state)."""
    cfg, model, params = _setup(vocab=64)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), loss_chunk=8, attn_chunk=16)
    step = jax.jit(make_train_step(model, tcfg))
    dcfg = DataConfig(vocab_size=64, seq_len=17, global_batch=4)

    def run(p, opt, s0, n):
        err = None
        for s in range(s0, s0 + n):
            b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, s).items()}
            p, opt, err, m = step(p, opt, err, b)
        return p, opt, float(m["loss"])

    opt = adamw_init(params)
    p_full, _, loss_full = run(params, opt, 0, 6)

    with tempfile.TemporaryDirectory() as d:
        p3, opt3, _ = run(params, adamw_init(params), 0, 3)
        ckpt.save(d, 3, {"p": p3, "opt": opt3})
        restored, _ = ckpt.restore(d, {"p": p3, "opt": opt3})
        p_res, _, loss_res = run(restored["p"], restored["opt"], 3, 3)
    assert loss_res == loss_full
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, {"x": jnp.ones(3)}, keep_last=2)
        assert ckpt.latest_step(d) == 4
        assert ckpt.all_steps(d) == [3, 4]


def test_async_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        t = ckpt.save(d, 7, {"x": jnp.arange(5)}, async_save=True)
        t.join(10)
        r, _ = ckpt.restore(d, {"x": jnp.zeros(5, jnp.int32)})
        np.testing.assert_array_equal(np.asarray(r["x"]), np.arange(5))


def test_grad_compression_error_feedback():
    """int8 error feedback: the quantization error is carried, so the SUM of
    compressed grads tracks the sum of true grads (convergence-preserving)."""
    g = {"w": jnp.linspace(-1, 1, 128).reshape(8, 16)}
    err = None
    tot_true = jnp.zeros((8, 16))
    tot_comp = jnp.zeros((8, 16))
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        comp, err = compress_grads_int8(gi, err)
        tot_true += gi["w"]
        tot_comp += comp["w"]
    # error feedback keeps the accumulated difference bounded by one step's
    # quantization error (not 20x)
    diff = float(jnp.abs(tot_true - tot_comp).max())
    one_step_err = float(jnp.abs(g["w"]).max()) * 3 / 127
    assert diff < one_step_err * 2


def test_lr_schedule_shape():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_schedule(c, jnp.array(0))) == 0.0
    assert float(lr_schedule(c, jnp.array(10))) == 1.0
    assert 0.09 < float(lr_schedule(c, jnp.array(100))) < 0.11


def test_elastic_restore_different_structure_dtype():
    """Restore casts into the target dtype (elastic/precision migration)."""
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": jnp.ones((4, 4), jnp.float32)})
        tgt = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
        r, _ = ckpt.restore(d, tgt)
        assert r["w"].dtype == jnp.bfloat16
