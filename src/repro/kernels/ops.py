"""jit'd public wrappers around the Pallas kernels (layout packing + vjp-free
serving entry points).  Each op has a pure-jnp oracle in ref.py; tests sweep
shapes/dtypes in interpret mode."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan_chunked
from repro.kernels.verify_attn import verify_attention_packed
from repro.kernels.verify_attn import verify_attention_paged as _paged_kernel


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def verify_attention(
    q: jax.Array,        # (B, Sq, Hq, D)
    k: jax.Array,        # (B, Skv, Hkv, D)
    v: jax.Array,
    kv_valid: jax.Array,  # (B,)
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """SLED verification attention (see verify_attn.py for the TPU design)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    # pack (Sq, G) into MXU rows, grouped per kv head: row r = i*G + g
    qp = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, Sq * G, D)
    o = verify_attention_packed(qp, k, v, kv_valid.astype(jnp.int32), sq=Sq,
                                block_k=block_k, interpret=interpret)
    return o.reshape(B, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Sq, Hq, D)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def verify_attention_paged(
    q: jax.Array,         # (B, Sq, Hq, D)
    k_pool: jax.Array,    # (n_slots+1, Skv, Hkv, D) — PagedKVCache pool rows
    v_pool: jax.Array,
    slots: jax.Array,     # (B,) int32 pool row per batch entry
    kv_valid: jax.Array,  # (B,)
    k_scale: Optional[jax.Array] = None,  # (n_slots+1, Hkv) f32 — required
    v_scale: Optional[jax.Array] = None,  # when the pool is int8
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Slot-indexed verification attention straight out of the cache pool —
    the scalar-prefetched index maps pick pool row ``slots[b]`` per chunk,
    so no gathered dense K/V ever exists (see verify_attn.py).  An int8 pool
    additionally takes its per-(slot, head) dequant scales; tiles are
    dequantized in-kernel, never as a bf16 pool copy."""
    B, Sq, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    qp = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, Sq * G, D)
    o = _paged_kernel(qp, k_pool, v_pool, slots.astype(jnp.int32),
                      kv_valid.astype(jnp.int32), sq=Sq, block_k=block_k,
                      interpret=interpret, k_scale=k_scale, v_scale=v_scale)
    return o.reshape(B, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Sq, Hq, D)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) post-softplus fp32
    A: jax.Array,    # (H,) negative fp32
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    h0: Optional[jax.Array] = None,
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD over a full sequence (chunked kernel). Returns (y, h_final)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    return ssd_scan_chunked(x, dt, A, Bm, Cm, h0, chunk=chunk, interpret=interpret)
