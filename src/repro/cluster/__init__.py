"""Replica-sharded serving cluster (SLED at system scale).

  router.py — Router: N replicas behind a pluggable placement policy
              (least-loaded / affinity / round-robin), stream migration on
              retire, cluster-merged EngineStats, worker eviction on
              transport failure.  Replicas are LocalReplica-wrapped
              in-process ServerEngines or...
  remote.py — RemoteReplica: the same driver surface proxied to a
              ``repro worker`` process over codec v4 control frames on a
              blocking TCP/UDS ControlChannel; spawn_worker launches one.
  faults.py — supervision + chaos: seeded Backoff, the armable
              FaultyChannel wrapper, and the ChaosInjector that executes a
              ServeSpec's deterministic FaultSpec schedule against a
              live Router.

The router exposes the same admit/submit/step/retire surface as a single
``ServerEngine``, so every existing driver (launch/serve.py inproc loop,
transport/server.TransportServer, the benchmarks) serves a replica fleet by
swapping the object it holds — admission becomes a placement decision, and
with remote replicas the fleet spans OS processes.
"""

from repro.cluster.faults import Backoff, ChaosInjector, FaultyChannel
from repro.cluster.remote import (
    ControlChannel,
    RemoteReplica,
    ReplicaGone,
    WorkerError,
    spawn_worker,
)
from repro.cluster.router import (
    PLACEMENT_POLICIES,
    AffinityPlacement,
    LeastLoadedPlacement,
    LocalReplica,
    MigrationError,
    PlacementPolicy,
    RoundRobinPlacement,
    Router,
    make_placement,
)

__all__ = [
    "PLACEMENT_POLICIES",
    "AffinityPlacement",
    "Backoff",
    "ChaosInjector",
    "ControlChannel",
    "FaultyChannel",
    "LeastLoadedPlacement",
    "LocalReplica",
    "MigrationError",
    "PlacementPolicy",
    "RemoteReplica",
    "ReplicaGone",
    "RoundRobinPlacement",
    "Router",
    "WorkerError",
    "make_placement",
    "spawn_worker",
]
