"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared (weight-tied) attention block.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64  [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, register

ZAMBA2_1_2B = register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,  # mamba2 layers
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        attn_every=6,  # shared attention block applied after every 6 ssm layers
        act="swiglu",
        notes="runs long_500k (hybrid); shared attn block weight-tied across applications",
    )
)
