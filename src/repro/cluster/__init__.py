"""Replica-sharded serving cluster (SLED at system scale).

  router.py — Router: N ServerEngine replicas behind a pluggable placement
              policy (least-loaded / affinity / round-robin), stream
              migration on retire, cluster-merged EngineStats.

The router exposes the same admit/submit/step/retire surface as a single
``ServerEngine``, so every existing driver (launch/serve.py inproc loop,
transport/server.TransportServer, the benchmarks) serves a replica fleet by
swapping the object it holds — admission becomes a placement decision.
"""

from repro.cluster.router import (
    PLACEMENT_POLICIES,
    AffinityPlacement,
    LeastLoadedPlacement,
    PlacementPolicy,
    RoundRobinPlacement,
    Router,
    make_placement,
)

__all__ = [
    "PLACEMENT_POLICIES",
    "AffinityPlacement",
    "LeastLoadedPlacement",
    "PlacementPolicy",
    "RoundRobinPlacement",
    "Router",
    "make_placement",
]
