"""Cross-process cluster: codec v3 control plane, worker dispatch, eviction.

Fast tier covers the protocol and supervision logic without subprocesses:
frame round-trips + truncation properties for every v3 control message,
bit-exact KV-row serialization (bf16 rides the wire as raw uint16 words),
per-replica ReplicaSpec validation, and — via a fake in-process channel
that routes every RPC through full encode -> WorkerCore.handle -> decode —
token identity between a Router of "remote" replicas and the in-process
cluster, worker-crash eviction, and the mixed-flavor migration guard.

Slow tier spawns REAL ``repro worker`` subprocesses on unix sockets and
holds the PR's acceptance bar: a Router dialing 2 worker processes commits
exactly the tokens the in-process cluster commits for the same ServeSpec
seed, through both the cluster and transport backends.
"""

import dataclasses
import json

import numpy as np
import pytest

import ml_dtypes

from repro.api import (
    ClusterSpec,
    ModelSpec,
    ReplicaSpec,
    SchedulerSpec,
    ServeSpec,
    SpecError,
    System,
    build_models,
)
from repro.cluster import (
    MigrationError,
    RemoteReplica,
    ReplicaGone,
    Router,
    WorkerError,
)
from repro.core.server_engine import ServerEngine
from repro.transport import codec
from repro.transport.links import parse_addr
from repro.transport.worker import WorkerCore, build_engine_from_spec

V = 64


def _spec(**kw) -> ServeSpec:
    base = dict(
        backend="cluster",
        model=ModelSpec(vocab_size=V, target_layers=2, draft_layers=1, draft_noise=0.03),
        cluster=ClusterSpec(replicas=2),
        scheduler=SchedulerSpec(slots=2, stagger_ticks=1),
        devices=4,
        prompt_len=6,
        max_new=6,
        k_max=3,
        c_th=0.3,
    )
    base.update(kw)
    return ServeSpec(**base)


# ---------------------------------------------------------------------------
# codec v3: control-plane frames
# ---------------------------------------------------------------------------


def _roundtrip(msg):
    buf = codec.encode_frame(msg)
    out, used = codec.decode_frame(buf)
    assert used == len(buf)
    return out


def _eq(a, b) -> bool:
    """Structural equality that tolerates numpy fields inside dataclasses."""
    if type(a) is not type(b):
        return False
    if isinstance(a, np.ndarray):
        return a.dtype == b.dtype and a.shape == b.shape and bool(np.all(a == b))
    if dataclasses.is_dataclass(a):
        return all(
            _eq(getattr(a, f.name), getattr(b, f.name)) for f in dataclasses.fields(a)
        )
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    if isinstance(a, dict):
        return sorted(a) == sorted(b) and all(_eq(a[k], b[k]) for k in a)
    return a == b


def _sample_row():
    return {
        "layer0/k": np.arange(24, dtype=ml_dtypes.bfloat16).reshape(2, 3, 4),
        "layer0/v": np.linspace(-2, 2, 24, dtype=np.float32).reshape(2, 3, 4),
        "length": np.asarray([7], np.int32),
    }


def _sample_state():
    return codec.StreamState(
        device_id=3,
        slot=1,
        prev_token=42,
        committed=(5, 9, 1),
        admitted_at=1.25,
        rounds=4,
        drafted=12,
        accepted=9,
        row=_sample_row(),
    )


def _control_messages():
    toks = np.asarray([5, 0, V - 1, 3], np.int32)
    return [
        codec.PlaceReplica(spec_json='{"backend": "engine"}'),
        codec.PlaceAck(ok=True, n_slots=2, k_max=3, max_len=64, greedy=True,
                       paged_attention=False),
        codec.PlaceAck(ok=False, error="no: bad spec"),
        codec.AdmitRequest(device_id=7, prompt=toks, now=0.5),
        codec.AdmitReply(device_id=7, ok=True, slot=1, prev_token=-3),
        codec.SubmitRequest(device_id=7, tokens=toks, now=1.5),
        codec.SubmitAck(device_id=7),
        codec.StepRequest(now=2.25),
        codec.StepReply(
            verdicts=(
                codec.VerdictRec(device_id=7, n_accepted=2, tokens=toks[:3],
                                 next_prev=9, accept_rate=0.5, queue_depth=1,
                                 queue_s=0.5, verify_s=0.25),
            ),
            queue_depth=1, n_free=1, hint=3.5,
        ),
        codec.StepReply(verdicts=(), queue_depth=0, n_free=2, hint=None),
        codec.RetireRequest(device_id=7),
        codec.RetireReply(stream=_sample_state()),
        codec.CancelRequest(device_id=7),
        codec.CancelReply(device_id=7, ok=False),
        codec.ForceExtendRequest(device_id=7, tokens=toks),
        codec.ForceExtendReply(device_id=7, next_prev=11),
        codec.ExportStream(device_id=7),
        codec.ExportReply(stream=_sample_state()),
        codec.ImportStream(stream=_sample_state()),
        codec.ImportAck(device_id=7, slot=0),
        codec.StatsRequest(now=9.0, has_now=True),
        codec.ReplicaStats(stats_json='{"rounds": 3}'),
        codec.ReplicaStats(stats_json='{"rounds": 3}',
                           telemetry_json='{"snapshot": {"counters": {}}}'),
        codec.WarmupRequest(),
        codec.WarmupReply(compile_json='{"4": 0.1}'),
        codec.Drain(),
        codec.DrainAck(streams_left=2),
        codec.ErrorReply(message="ValueError: boom"),
        # v4: per-RPC seq on side-effectful requests + heartbeat frames
        codec.AdmitRequest(device_id=7, prompt=toks, now=0.5, seq=12),
        codec.SubmitRequest(device_id=7, tokens=toks, now=1.5, seq=13),
        codec.StepRequest(now=2.25, seq=14),
        codec.RetireRequest(device_id=7, seq=15),
        codec.CancelRequest(device_id=7, seq=16),
        codec.ForceExtendRequest(device_id=7, tokens=toks, seq=17),
        codec.ExportStream(device_id=7, seq=18),
        codec.ImportStream(stream=_sample_state(), seq=19),
        codec.Ping(seq=20, t=1.25),
        codec.Pong(seq=20, t=1.25),
    ]


def test_codec_v3_control_roundtrip():
    for msg in _control_messages():
        out = _roundtrip(msg)
        assert _eq(out, msg), f"{type(msg).__name__} did not round-trip"


def test_codec_v3_stream_state_row_bit_exact():
    state = _roundtrip(codec.ImportStream(stream=_sample_state())).stream
    row, want = state.row, _sample_row()
    assert sorted(row) == sorted(want)
    for k in want:
        assert row[k].dtype == want[k].dtype and row[k].shape == want[k].shape
        # bit-level equality, not value closeness: bf16 must ride the wire
        # as raw words or cross-process KV rows stop being migration-safe
        np.testing.assert_array_equal(
            row[k].view(np.uint16) if row[k].dtype == ml_dtypes.bfloat16 else row[k],
            want[k].view(np.uint16) if want[k].dtype == ml_dtypes.bfloat16 else want[k],
        )


def test_codec_v3_truncation_never_yields_a_frame():
    """Every strict prefix of a valid frame reassembles to nothing (the
    decoder waits for more bytes) and never decodes to garbage."""
    for msg in _control_messages():
        buf = codec.encode_frame(msg)
        for cut in range(len(buf)):
            dec = codec.FrameDecoder()
            dec.feed(buf[:cut])
            assert dec.next_raw() is None, (type(msg).__name__, cut)
            with pytest.raises(codec.CodecError):
                codec.decode_frame(buf[:cut])


def test_codec_v3_corrupt_payload_raises_codec_error():
    """Truncating the payload while fixing up the length header must raise
    CodecError (not IndexError/struct.error) — the worker loop turns codec
    failures into protocol errors, anything else would kill the process."""
    for msg in (codec.ImportStream(stream=_sample_state()),
                codec.AdmitRequest(device_id=1, prompt=np.arange(4, dtype=np.int32))):
        buf = bytearray(codec.encode_frame(msg))
        body = buf[codec.HEADER_SIZE:][:-3]  # drop payload tail
        trimmed = bytearray(buf[: codec.HEADER_SIZE]) + body
        trimmed[4:8] = len(body).to_bytes(4, "big")
        with pytest.raises(codec.CodecError):
            codec.decode_frame(bytes(trimmed))


def test_codec_version_is_v4():
    assert codec.VERSION == 4
    buf = codec.encode_frame(codec.Drain())
    assert buf[2] == 4


# ---------------------------------------------------------------------------
# per-replica ServeSpec
# ---------------------------------------------------------------------------


def test_replica_spec_shorthand_expands():
    c = ClusterSpec(replicas=3)
    assert c.n_replicas == 3 and not c.has_remote
    assert c.replica_specs == (ReplicaSpec(), ReplicaSpec(), ReplicaSpec())


def test_replica_spec_list_round_trips():
    spec = _spec(
        cluster=ClusterSpec(
            replicas=[
                {"flavor": "remote"},
                {"flavor": "remote", "address": "uds:/tmp/w.sock", "slots": 3},
            ]
        )
    )
    assert spec.cluster.has_remote and spec.cluster.n_replicas == 2
    blob = spec.to_json_str()
    assert json.loads(blob) == spec.to_json()  # artifact-safe (lists, not tuples)
    assert ServeSpec.from_json(blob) == spec


@pytest.mark.parametrize(
    "kw",
    [
        dict(backend="engine", cluster=ClusterSpec(replicas=[{"flavor": "remote"}])),
        dict(backend="reference", cluster=ClusterSpec(replicas=[{"flavor": "remote"}])),
        dict(cluster=ClusterSpec(replicas=[{"flavor": "inproc", "address": "tcp:h:1"}])),
        dict(cluster=ClusterSpec(replicas=[])),
        dict(cluster=ClusterSpec(replicas=[{"flavor": "weird"}])),
        dict(cluster=ClusterSpec(replicas=[{"flavor": "remote", "address": "nope"}])),
        dict(cluster=ClusterSpec(replicas=[{"flavor": "remote", "slots": -1}])),
    ],
)
def test_replica_spec_invalid_combos_rejected(kw):
    with pytest.raises(SpecError):
        _spec(**kw)


def test_replica_spec_unknown_keys_rejected_at_normalization():
    with pytest.raises(SpecError, match="unknown replica keys"):
        ClusterSpec(replicas=[{"flavour": "remote"}])


def test_with_backend_resets_remote_fleet():
    spec = _spec(cluster=ClusterSpec(replicas=[{"flavor": "remote"}] * 2))
    eng = spec.with_backend("engine")
    assert eng.cluster.replicas == 1 and not eng.cluster.has_remote


def test_parse_addr_forms():
    assert parse_addr("tcp:127.0.0.1:0") == ("tcp", "127.0.0.1", 0)
    assert parse_addr("host:7001") == ("tcp", "host", 7001)
    assert parse_addr("uds:/tmp/x.sock") == ("uds", "/tmp/x.sock")
    for bad in ("uds:", "tcp:hostonly", "tcp:h:notaport", ":9"):
        with pytest.raises(ValueError):
            parse_addr(bad)


# ---------------------------------------------------------------------------
# WorkerCore over a fake wire (full dispatch, no sockets)
# ---------------------------------------------------------------------------


class FakeChannel:
    """In-process stand-in for ControlChannel: every request is ENCODED,
    decoded by the worker dispatch, and its reply encoded/decoded again —
    the whole wire path minus the socket.  ``killed`` simulates a worker
    crash (every RPC raises ReplicaGone, like a dead TCP peer)."""

    def __init__(self, core=None):
        self.core = core or WorkerCore()
        self.address = "fake:0"
        self.killed = False
        self.connected = True
        self._seq = 0

    def next_seq(self):
        self._seq += 1
        return self._seq

    def request(self, msg, *, timeout=None):
        if self.killed:
            raise ReplicaGone("worker killed (fake)")
        wire, _ = codec.decode_frame(codec.encode_frame(msg))
        reply, _ = codec.decode_frame(codec.encode_frame(self.core.handle(wire)))
        if isinstance(reply, codec.ErrorReply):
            raise WorkerError(reply.message)
        return reply

    def kill(self):
        self.killed = True

    def close(self):
        pass

    def connect(self):
        if self.killed:
            raise ReplicaGone("worker dead (fake)")

    def reconnect(self):
        if self.killed:
            raise ReplicaGone("worker still dead (fake)")


def _fake_remote(engine=None) -> RemoteReplica:
    """RemoteReplica over a FakeChannel.  With a prebuilt ``engine`` the
    placement handshake is skipped (tests sharing one compiled VerifySteps
    bundle); fingerprint fields are adopted directly."""
    remote = RemoteReplica(FakeChannel(WorkerCore(engine)))
    if engine is not None:
        remote._placed = True
        remote._n_slots = engine.pool.n_slots
        remote.k_max = engine.k_max
        remote.max_len = engine.pool.max_len
        remote.greedy = engine.greedy
        remote.paged_attention = engine.paged_attention
    return remote


@pytest.fixture(scope="module")
def models():
    return build_models(_spec().model)


@pytest.fixture(scope="module")
def engine_factory(models):
    """Build homogeneous engines sharing ONE compiled VerifySteps bundle."""
    spec = _spec()
    shared = {}

    def make() -> ServerEngine:
        e = ServerEngine(
            models.target,
            models.target_params,
            n_slots=2,
            max_len=spec.max_len,
            k_max=spec.k_max,
            greedy=True,
            steps=shared.get("steps"),
        )
        shared.setdefault("steps", e.steps)
        return e

    return make


def test_remote_router_token_identical_to_inproc(models):
    """The PR's core invariant on the fast tier: a Router of remote
    replicas — every RPC through full codec v3 encode/decode and the real
    worker dispatch, engines built via PlaceReplica from a shipped spec —
    commits exactly the tokens the in-process cluster commits."""
    spec = _spec()
    inproc = System.build(spec, models=models)
    want = inproc.serve().outputs

    worker_spec = spec.with_backend(
        "engine",
        scheduler=dataclasses.replace(spec.scheduler, slots=spec.slots_per_replica),
    )
    remotes = []
    for _ in range(2):
        r = RemoteReplica(FakeChannel())
        r.place(worker_spec)  # builds the worker engine from the spec JSON
        remotes.append(r)
    router = Router(
        remotes,
        placement=spec.cluster.placement,
        migrate_on_retire=spec.cluster.migrate_on_retire,
    )
    system = System(spec, models, router, inproc.kit)
    got = system.serve().outputs
    assert got == want, "remote replicas diverged from the in-process cluster"
    assert router.migrations >= 0 and router.evictions == 0


def test_worker_crash_evicts_and_redistributes(engine_factory):
    router = Router([_fake_remote(engine_factory()), _fake_remote(engine_factory())])
    prompts = np.arange(4 * 6, dtype=np.int32).reshape(4, 6) % V
    for dev in range(4):  # least-loaded: 0,2 -> replica 0; 1,3 -> replica 1
        assert router.admit(dev, prompts[dev], 0.0) is not None
    assert router.loads() == [2, 2]
    for dev in range(4):
        router.submit(dev, np.asarray([1, 2, 3], np.int32), 0.1)

    router.replicas[1].channel.killed = True
    verdicts = router.step(0.2)  # replica 1 dies mid-step: evicted, not fatal

    assert router.evictions == 1
    assert router.replicas[1].dead and not router.replicas[0].dead
    assert sorted(router.lost_devices) == [1, 3]
    assert {v.device_id for v in verdicts} == {0, 2}  # survivors still served
    assert 1 not in router.streams and 3 not in router.streams

    # retire a survivor, then redistribution: new admissions land on the
    # live replica only
    router.retire(0)
    stream = router.admit(9, prompts[1], 1.0)
    assert stream is not None and router.replica_of(9) == 0
    # stats skip the dead replica instead of dialing a corpse
    st = router.stats(1.0)
    assert st.replicas == 1


def test_all_replicas_dead_is_fatal(engine_factory):
    router = Router([_fake_remote(engine_factory())])
    router.replicas[0].channel.killed = True
    with pytest.raises((RuntimeError, ConnectionError)):
        router.admit(0, np.zeros(6, np.int32), 0.0)


def test_mixed_flavor_migration_rejected(engine_factory):
    local = engine_factory()
    router = Router([local, _fake_remote(engine_factory())])
    assert router.replicas[0].flavor == "local"
    assert router.replicas[1].flavor == "remote"
    prompt = np.arange(6, dtype=np.int32)
    router.admit(0, prompt, 0.0)
    assert router.replica_of(0) == 0
    with pytest.raises(MigrationError, match="provenance"):
        router.migrate(0, 1)
    # the stream survived the refusal, untouched
    assert router.replica_of(0) == 0 and 0 in router.streams


def test_remote_to_remote_migration_over_frames(engine_factory):
    """Satellite 3: migration between remote replicas rides the
    ExportStream/ImportStream frames and preserves the stream record."""
    router = Router([_fake_remote(engine_factory()), _fake_remote(engine_factory())])
    prompt = np.arange(6, dtype=np.int32)
    stream = router.admit(0, prompt, 0.0)
    before = (stream.prev_token, list(stream.committed))
    router.migrate(0, 1)
    assert router.replica_of(0) == 1 and router.migrations == 1
    moved = router.streams[0]
    assert (moved.prev_token, list(moved.committed)) == before
    # the destination WORKER holds the stream now, not just the shadow
    assert 0 in router.replicas[1].channel.core.engine.streams
    assert 0 not in router.replicas[0].channel.core.engine.streams


def test_worker_error_is_not_eviction(engine_factory):
    """An engine-level rejection (ErrorReply) must surface as WorkerError
    and leave the replica alive — only transport failures evict."""
    remote = _fake_remote(engine_factory())
    router = Router([remote])
    with pytest.raises(WorkerError, match="KeyError"):
        remote.retire(99)  # no such stream: the worker says so, politely
    assert not remote.dead and router.evictions == 0


def test_worker_core_place_rejects_double_place(engine_factory):
    core = WorkerCore(engine_factory())
    ack = core.handle(codec.PlaceReplica('{"backend": "engine"}'))
    assert isinstance(ack, codec.PlaceAck) and not ack.ok
    assert "already" in ack.error


def test_worker_core_requires_engine():
    reply = WorkerCore().handle(codec.StepRequest(now=0.0))
    assert isinstance(reply, codec.ErrorReply)
    assert "PlaceReplica" in reply.message


def test_build_engine_from_spec_forces_engine_backend():
    spec = _spec()  # backend=cluster, replicas=2
    engine = build_engine_from_spec(spec)
    assert isinstance(engine, ServerEngine)
    assert engine.pool.n_slots == spec.slots_per_replica


# ---------------------------------------------------------------------------
# real worker processes (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_spawned_workers_token_identical_across_backends(models):
    """Acceptance bar: 2 REAL ``repro worker`` processes on unix sockets,
    spawned + placed by System.build, commit token-identical streams to the
    in-process cluster for the same spec seed — via the cluster backend's
    in-process pump AND via the transport backend's wire runtime."""
    spec = _spec()
    want = System.build(spec, models=models).serve().outputs

    remote_cluster = dataclasses.replace(
        spec, cluster=ClusterSpec(replicas=[{"flavor": "remote"}] * 2)
    )
    with System.build(remote_cluster) as system:
        assert [r.flavor for r in system.engine.replicas] == ["remote", "remote"]
        got = system.serve().outputs
    assert got == want, "cross-process cluster diverged from in-process"

    remote_transport = remote_cluster.with_backend(
        "transport", cluster=remote_cluster.cluster
    )
    with System.build(remote_transport) as system:
        got = system.serve().outputs
    assert got == want, "transport over worker processes diverged"
