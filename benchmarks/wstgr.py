"""Paper Fig. 4: Whole-System Token Generation Rate vs server batch size.

SLED vs centralized serving for 11B and 70B target models; the server is
kept saturated (N = 8x batch devices) so WSTGR reflects server-side
efficiency.  Expected shape: WSTGR rises with batch (weight-stream
amortisation), SLED sits >2x above centralized at equal batch — the paper's
x2.2 system-throughput claim.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, simulate


def run(quick: bool = False) -> list:
    rows = []
    batches = (1, 2, 4, 8, 16, 32) if not quick else (2, 8, 32)
    for target_p, tname in ((11e9, "11B"), (70e9, "70B")):
        for b in batches:
            base = SimConfig(
                mode="sled", spec_len=4, acceptance=0.90,
                device_rate=RPI5.rate("llama-3b-draft", 4),
                target_params=target_p, server_batch=b,
                batch_policy="deadline", n_devices=64 * b,
                sim_time=10.0 if quick else 20.0,
            )
            sled = simulate(base, A100_X4)
            cent = simulate(dataclasses.replace(base, mode="centralized"), A100_X4)
            rows.append({
                "target": tname, "batch": b,
                "wstgr_sled": round(sled.wstgr, 1),
                "wstgr_centralized": round(cent.wstgr, 1),
                "ratio": round(sled.wstgr / max(cent.wstgr, 1e-9), 2),
                "sled_busy": round(sled.server_busy_frac, 2),
            })
    emit(rows, "fig4_wstgr")
    return rows


if __name__ == "__main__":
    run()
