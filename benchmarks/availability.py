"""Availability under chaos: what a mid-serve worker kill costs.

Three runs of the SAME fleet spec (2 replicas, shared models/steps so the
sweep measures serving, not compiles):

  baseline       fault-free
  kill+recover   seeded kill of replica 1 mid-serve, supervised respawn +
                 device-replay stream recovery ON — must stay
                 token-identical to baseline with zero shed streams
  kill+shed      same schedule, recovery OFF — the dead replica's streams
                 are shed into ``lost_devices`` (today's pre-supervision
                 behavior, kept as the degraded floor)

Reported per run: committed tokens, committed-tokens/s, evictions /
respawns / recovered / shed counts, and (telemetry spans) respawn +
recovery latency.  ``--json PATH`` writes the BENCH artifact with the
uniform ``ServeResult.to_json`` records.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import emit


def _specs(quick: bool, processes: bool):
    from repro.api import ClusterSpec, FaultSpec, ModelSpec, ServeSpec

    replicas: object = 2
    if processes:
        replicas = [{"flavor": "remote"}, {"flavor": "remote"}]
    base = ServeSpec(
        backend="cluster",
        model=ModelSpec(vocab_size=128, target_layers=2, draft_layers=1,
                        draft_noise=0.03),
        cluster=ClusterSpec(replicas=replicas),
        devices=4 if quick else 8,
        prompt_len=8,
        max_new=8 if quick else 16,
        k_max=4,
        telemetry=True,
    )
    schedule = FaultSpec(events=({"kind": "kill", "replica": 1, "round": 5},))
    recover = dataclasses.replace(
        base,
        cluster=dataclasses.replace(
            base.cluster,
            faults={"respawn": True, "recover_streams": True,
                    "backoff_base_s": 0.02, "backoff_max_s": 0.2},
        ),
        faults=schedule,
    )
    shed = dataclasses.replace(base, faults=schedule)
    return base, recover, shed


def _span_stats(result, name: str):
    hists = ((result.telemetry or {}).get("snapshot", {}) or {}).get("histograms", {})
    h = hists.get(name)
    return (h["count"], h["sum"]) if h else (0, 0.0)


def _run_one(spec, models, case: str) -> tuple:
    from repro import telemetry
    from repro.api import System

    telemetry.registry().reset()  # per-case spans: no bleed between runs
    system = System.build(spec, models=models)
    t0 = time.time()
    try:
        result = system.serve()
    except BaseException:
        system.close()
        raise
    wall = time.time() - t0
    router = system.engine
    n_resp, s_resp = _span_stats(result, "router_respawn_seconds")
    n_rec, s_rec = _span_stats(result, "router_recovery_seconds")
    row = {
        "case": case,
        "tokens": result.total_tokens,
        "tok_s": round(result.total_tokens / max(wall, 1e-9), 1),
        "wall_s": round(wall, 3),
        "evictions": getattr(router, "evictions", 0),
        "respawns": getattr(router, "respawns", 0),
        "recovered": getattr(router, "recovered_streams", 0),
        "shed": getattr(router, "shed_streams", 0),
        "lost": len(result.lost_devices),
        "respawn_s": round(s_resp / max(n_resp, 1), 4) if n_resp else 0.0,
        "recovery_s": round(s_rec / max(n_rec, 1), 4) if n_rec else 0.0,
    }
    system.close()
    return row, result, system.models


def run(quick: bool = False, processes: bool = False, json_path: str = "") -> list:
    base, recover, shed = _specs(quick, processes)

    row_base, res_base, models = _run_one(base, None, "baseline")
    row_rec, res_rec, _ = _run_one(recover, models, "kill_recover")
    row_shed, res_shed, _ = _run_one(shed, models, "kill_shed")

    # the availability claims this benchmark exists to watch
    row_rec["identical"] = res_rec.outputs == res_base.outputs
    row_rec["availability"] = round(
        res_rec.total_tokens / max(res_base.total_tokens, 1), 3
    )
    row_shed["availability"] = round(
        res_shed.total_tokens / max(res_base.total_tokens, 1), 3
    )
    rows = [row_base, row_rec, row_shed]
    emit(rows, "availability")
    assert row_rec["identical"], "recovery must be token-identical to baseline"
    assert row_rec["shed"] == 0 and row_rec["lost"] == 0
    assert row_shed["lost"] > 0, "evict-only run should shed the dead replica"

    if json_path:
        artifact = {
            "rows": [dict(r) for r in rows],
            "results": {
                "baseline": res_base.to_json(),
                "kill_recover": res_rec.to_json(),
                "kill_shed": res_shed.to_json(),
            },
        }
        with open(json_path, "w") as f:
            json.dump(artifact, f, indent=2, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--processes", action="store_true",
                    help="spawned worker processes (real SIGKILL recovery)")
    ap.add_argument("--json", type=str, default="",
                    help="write the BENCH artifact here")
    args = ap.parse_args()
    run(quick=args.quick, processes=args.processes, json_path=args.json)
