"""End-to-end training driver: train a ~100M-param draft model.

    PYTHONPATH=src python examples/train_draft_model.py --steps 300

Full substrate: synthetic data pipeline -> remat'd train step -> AdamW ->
periodic async checkpoints -> resume-on-restart.  The config is a 100M
llama-style draft (the class of model SLED puts ON the edge devices).
Use --tiny for a seconds-long smoke run.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.models.model_zoo import build_model
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step

DRAFT_100M = ModelConfig(
    name="draft-100m", family="dense", num_layers=10, d_model=640,
    num_heads=10, num_kv_heads=2, d_ff=2560, vocab_size=32000,
    tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", type=str, default="experiments/draft100m")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tiny", action="store_true", help="smoke-scale run")
    args = ap.parse_args()

    cfg = DRAFT_100M
    if args.tiny:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, d_ff=256,
                                  vocab_size=512)
        args.steps, args.seq = min(args.steps, 20), 64

    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        remat=True, loss_chunk=128, attn_chunk=128,
    )
    step_fn = jax.jit(make_train_step(model, tcfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
                      global_batch=args.batch, mode="markov", det_frac=0.8)

    start = ckpt.latest_step(args.ckpt_dir)
    if start is not None:
        state, _ = ckpt.restore(args.ckpt_dir, {
            "params": model.init_params_spec(),
            "opt": jax.eval_shape(adamw_init, model.init_params_spec()),
        })
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")
    else:
        params = model.init_params(jax.random.key(0))
        opt = adamw_init(params)
        start = 0

    err, pending = None, None
    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, s).items()}
        params, opt, err, metrics = step_fn(params, opt, err, batch)
        if s % 10 == 0 or s == args.steps - 1:
            rate = (s - start + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {s:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                  f"({rate:,.0f} tok/s)")
        if s and s % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save(args.ckpt_dir, s, {"params": params, "opt": opt},
                                async_save=True)
    if pending is not None:
        pending.join()
    ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
