"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import BatchPlanner, VerifyRequest
from repro.core.speculative import PAD_TOKEN, speculative_verify
from repro.quant.quantize import dequantize, quantize
from repro.roofline.hlo_cost import HloCostModel
from repro.serving.cost_model import cost_per_1k_tokens


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 4), k=st.integers(1, 6), v=st.integers(4, 32),
    seed=st.integers(0, 2**16),
)
def test_verify_invariants(b, k, v, seed):
    """For ANY logits/drafts: 1 <= n_commit <= K+1; committed tokens are the
    accepted draft prefix + one extra; everything past is PAD; accepted
    drafts match the target argmax (greedy)."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    drafts = jax.random.randint(k1, (b, k), 0, v)
    logits = jax.random.normal(k2, (b, k + 1, v)) * 3
    lengths = jax.random.randint(k3, (b,), 0, k + 1)
    res = speculative_verify(drafts, logits, key, lengths=lengths, greedy=True)
    n_acc = np.asarray(res.n_accepted)
    n_commit = np.asarray(res.n_commit)
    out = np.asarray(res.out_tokens)
    tgt = np.asarray(jnp.argmax(logits, -1))
    for i in range(b):
        assert 0 <= n_acc[i] <= int(lengths[i])
        assert n_commit[i] == n_acc[i] + 1
        for j in range(int(n_acc[i])):
            assert out[i, j] == np.asarray(drafts)[i, j]
            assert out[i, j] == tgt[i, j]  # accepted == target choice
        assert out[i, n_acc[i]] == tgt[i, n_acc[i]]  # correction/bonus
        assert (out[i, n_commit[i]:] == PAD_TOKEN).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 40), batch=st.integers(1, 8),
    policy=st.sampled_from(["static", "deadline", "continuous"]),
)
def test_batch_planner_conservation(n, batch, policy):
    """No request is lost or duplicated by the planner."""
    p = BatchPlanner(batch_size=batch, k_max=4, policy=policy,
                     max_wait=0.01, straggler_timeout=10.0)
    for i in range(n):
        p.add(VerifyRequest(device_id=i, arrival=i * 0.001, prev_token=0,
                            draft_tokens=np.zeros(3, np.int32), request_id=i))
    seen = []
    t = 1.0
    while True:
        b = p.next_batch(t, server_idle=True)
        if b is None:
            break
        seen += [r.request_id for r in b.requests]
        assert len(b.requests) <= batch
        t += 0.01
    leftover = [r.request_id for r in p.queue]
    assert sorted(seen + leftover) == list(range(n))
    if policy in ("deadline", "continuous"):
        assert not leftover  # these policies drain


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 6), cols=st.integers(1, 64),
    bits=st.sampled_from([4, 8]), seed=st.integers(0, 999),
)
def test_quantization_error_bound(rows, cols, bits, seed):
    """|deq(q(w)) - w| <= scale/2 + eps per element (per-channel scales)."""
    w = jax.random.normal(jax.random.key(seed), (rows, cols))
    t = quantize(w, bits)
    back = dequantize(t, jnp.float32)
    scale = np.asarray(t.scale)
    err = np.abs(np.asarray(back) - np.asarray(w))
    assert (err <= scale * 0.5 + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(0.01, 1000), price=st.floats(1, 1e5), watts=st.floats(0.1, 3000))
def test_cost_model_monotonic(rate, price, watts):
    c1 = cost_per_1k_tokens(rate, price, watts)
    c2 = cost_per_1k_tokens(rate * 2, price, watts)
    assert c2 < c1  # faster is always cheaper per token
    assert c1 > 0


def test_hlo_cost_model_on_known_program():
    """Exact flop accounting through nested scans (trip-count handling)."""
    def f(w, x):
        def outer(c, _):
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, c, w)
            return h, ()
        h, _ = jax.lax.scan(outer, x, jnp.arange(3))
        return h.sum()

    W = jnp.zeros((4, 64, 64), jnp.float32)
    X = jnp.zeros((8, 64), jnp.float32)
    hlo = jax.jit(f).lower(W, X).compile().as_text()
    t = HloCostModel(hlo).totals()
    expect = 3 * 4 * (2 * 8 * 64 * 64)
    assert abs(t["flops"] - expect) / expect < 0.05
