"""Fault tolerance: supervised respawn, device-replay recovery, chaos.

Fast tier only — worker "processes" are WorkerCores behind fake in-process
channels (full codec encode/decode, no sockets), and local-replica chaos
goes through the same evict/recover machinery, so the PR's acceptance pair
runs in seconds:

  * with respawn + stream recovery enabled, killing 1 of 2 replicas
    mid-serve completes every session GREEDY-TOKEN-IDENTICAL to the
    fault-free run, with zero shed streams;
  * with recovery disabled, the same seeded kill schedule reproduces the
    evict-only behavior: the dead replica's streams land in
    ``lost_devices`` and their sessions end shed, not hung.

The real-subprocess variant (SIGKILL of a spawned ``repro worker``) rides
the slow tier in this file.
"""

import dataclasses

import numpy as np
import pytest

from repro.api import (
    ClusterSpec,
    FaultPolicy,
    FaultSpec,
    ModelSpec,
    SchedulerSpec,
    ServeSpec,
    System,
    build_models,
)
from repro.cluster import (
    Backoff,
    ChaosInjector,
    FaultyChannel,
    RemoteReplica,
    ReplicaGone,
    Router,
)
from repro.cluster.router import _HeartbeatMonitor
from repro.core.server_engine import ServerEngine
from repro.transport import codec
from repro.transport.worker import WorkerCore

V = 64


def _spec(**kw) -> ServeSpec:
    base = dict(
        backend="cluster",
        model=ModelSpec(vocab_size=V, target_layers=2, draft_layers=1, draft_noise=0.03),
        cluster=ClusterSpec(replicas=2),
        scheduler=SchedulerSpec(slots=2, stagger_ticks=1),
        devices=4,
        prompt_len=6,
        max_new=6,
        k_max=3,
        c_th=0.3,
    )
    base.update(kw)
    return ServeSpec(**base)


class FakeChannel:
    """ControlChannel stand-in: every RPC rides the full codec encode ->
    WorkerCore.handle -> decode path; ``killed`` fails like a dead peer."""

    def __init__(self, core=None):
        self.core = core or WorkerCore()
        self.address = "fake:0"
        self.killed = False
        self.connected = True
        self._seq = 0

    def next_seq(self):
        self._seq += 1
        return self._seq

    def request(self, msg, *, timeout=None):
        if self.killed:
            raise ReplicaGone("worker killed (fake)")
        wire, _ = codec.decode_frame(codec.encode_frame(msg))
        reply, _ = codec.decode_frame(codec.encode_frame(self.core.handle(wire)))
        if isinstance(reply, codec.ErrorReply):
            from repro.cluster import WorkerError

            raise WorkerError(reply.message)
        return reply

    def kill(self):
        self.killed = True

    def close(self):
        pass

    def connect(self):
        if self.killed:
            raise ReplicaGone("worker dead (fake)")

    def reconnect(self):
        if self.killed:
            raise ReplicaGone("worker still dead (fake)")


@pytest.fixture(scope="module")
def models():
    return build_models(_spec().model)


@pytest.fixture(scope="module")
def engine_factory(models):
    spec = _spec()
    shared = {}

    def make() -> ServerEngine:
        e = ServerEngine(
            models.target,
            models.target_params,
            n_slots=2,
            max_len=spec.max_len,
            k_max=spec.k_max,
            greedy=True,
            steps=shared.get("steps"),
        )
        shared.setdefault("steps", e.steps)
        return e

    return make


def _fake_remote(engine) -> RemoteReplica:
    remote = RemoteReplica(FakeChannel(WorkerCore(engine)))
    remote._placed = True
    remote._n_slots = engine.pool.n_slots
    remote.k_max = engine.k_max
    remote.max_len = engine.pool.max_len
    remote.greedy = engine.greedy
    remote.paged_attention = engine.paged_attention
    return remote


# ---------------------------------------------------------------------------
# primitives: Backoff, FaultyChannel, replay cache, heartbeat
# ---------------------------------------------------------------------------


def test_backoff_deterministic_and_capped():
    a = Backoff(base_s=0.1, max_s=1.0, jitter=0.2, seed=7)
    b = Backoff(base_s=0.1, max_s=1.0, jitter=0.2, seed=7)
    seq_a = [a.attempt() for _ in range(8)]
    seq_b = [b.attempt() for _ in range(8)]
    assert seq_a == seq_b, "same seed must sleep identically (chaos repro)"
    assert all(d <= 1.0 * 1.2 + 1e-9 for d in seq_a), "cap (plus jitter) holds"
    assert 0.08 <= seq_a[0] <= 0.12
    a.reset()
    assert a.attempts == 0
    with pytest.raises(ValueError):
        Backoff(base_s=0.0)


def test_faulty_channel_drop_delay_kill(engine_factory):
    chan = FaultyChannel(FakeChannel(WorkerCore(engine_factory())))
    prompt = np.arange(6, dtype=np.int32)
    ok = chan.request(codec.AdmitRequest(device_id=0, prompt=prompt, now=0.0, seq=1))
    assert isinstance(ok, codec.AdmitReply) and ok.ok  # transparent until armed
    chan.arm_drop(2)
    for _ in range(2):
        with pytest.raises(ReplicaGone, match="chaos"):
            chan.request(codec.StepRequest(now=0.0, seq=chan.next_seq()))
    assert chan.dropped == 2 and chan.drop_n == 0
    chan.request(codec.StepRequest(now=0.0, seq=chan.next_seq()))  # healed
    chan.arm_delay(1, 0.01)
    chan.request(codec.StepRequest(now=0.0, seq=chan.next_seq()))
    assert chan.delayed == 1
    chan.kill()
    with pytest.raises(ReplicaGone):
        chan.request(codec.StepRequest(now=0.0, seq=chan.next_seq()))
    with pytest.raises(ReplicaGone):
        chan.reconnect()


def test_worker_replay_cache_dedups_resent_frames(engine_factory):
    """v4 replay protection: a resent (device, seq) side-effectful frame
    returns the ORIGINAL reply without re-applying — the worker absorbs a
    one-shot reconnect retry safely."""
    core = WorkerCore(engine_factory())
    prompt = np.arange(6, dtype=np.int32)
    first = core.handle(codec.AdmitRequest(device_id=0, prompt=prompt, now=0.0, seq=1))
    again = core.handle(codec.AdmitRequest(device_id=0, prompt=prompt, now=0.0, seq=1))
    assert core.replay_hits == 1
    assert again.slot == first.slot and len(core.engine.streams) == 1
    toks = np.asarray([1, 2, 3], np.int32)
    core.handle(codec.SubmitRequest(device_id=0, tokens=toks, now=0.1, seq=2))
    core.handle(codec.SubmitRequest(device_id=0, tokens=toks, now=0.1, seq=2))
    assert core.replay_hits == 2
    step = core.handle(codec.StepRequest(now=0.2, seq=3))
    assert len(step.verdicts) == 1, "the duplicate submit must not queue a round"
    # seq=0 frames (v3-style senders) are never cached
    assert core._replay_key(codec.StepRequest(now=0.0)) is None
    # Ping answers without touching the replay cache
    pong = core.handle(codec.Ping(seq=9, t=1.5))
    assert isinstance(pong, codec.Pong) and pong.seq == 9 and pong.t == 1.5


def test_retry_rpcs_absorbs_link_flap(engine_factory):
    """A flap (one severed RPC) is invisible to the Router when the replica
    retries over reconnect: the frame is resent with the same seq."""
    remote = _fake_remote(engine_factory())
    remote.channel = FaultyChannel(remote.channel)
    remote.retry_rpcs = True
    router = Router([remote])
    prompt = np.arange(6, dtype=np.int32)
    assert router.admit(0, prompt, 0.0) is not None
    remote.channel.flap()
    router.submit(0, np.asarray([1, 2, 3], np.int32), 0.1)  # survives the flap
    assert remote.retries == 1 and remote.channel.dropped == 1
    assert router.evictions == 0 and not remote.dead
    verdicts = router.step(0.2)
    assert verdicts is not None and verdicts[0].device_id == 0


def test_chaos_injector_fires_on_schedule(engine_factory):
    router = Router([_fake_remote(engine_factory()), _fake_remote(engine_factory())])
    spec = FaultSpec(events=(
        {"kind": "kill", "replica": 1, "round": 3},
        {"kind": "flap", "replica": 0, "round": 2},
    ))
    router.replicas[0].channel = FaultyChannel(router.replicas[0].channel)
    inj = ChaosInjector(spec, router)
    inj.on_step(1)
    assert not inj.fired and not inj.done
    inj.on_step(2)
    assert inj.fired == [(2, "flap", 0)]
    assert router.replicas[0].channel.drop_n == 1
    inj.on_step(5)  # past-due events still fire, once
    assert inj.fired[-1] == (5, "kill", 1) and inj.done
    assert router.replicas[1].channel.killed
    inj.on_step(9)
    assert len(inj.fired) == 2


def test_chaos_injector_refuses_unwrapped_channel(engine_factory):
    router = Router([_fake_remote(engine_factory())])
    inj = ChaosInjector(FaultSpec(events=({"kind": "drop", "replica": 0, "round": 1},)), router)
    with pytest.raises(RuntimeError, match="not a FaultyChannel"):
        inj.on_step(1)


def test_heartbeat_monitor_marks_suspect_then_router_evicts(engine_factory):
    class Silent:
        dead = False
        suspect = False

        def ping(self, *, timeout):
            return False

    class Fleet:
        replicas = [Silent()]

    policy = FaultPolicy(heartbeat_interval_s=0.05, heartbeat_misses=3)
    mon = _HeartbeatMonitor(Fleet(), policy)
    mon.sweep()
    mon.sweep()
    assert not Fleet.replicas[0].suspect
    mon.sweep()
    assert Fleet.replicas[0].suspect, "3 consecutive misses -> suspect"

    # a suspect replica is evicted at the next router step
    router = Router([_fake_remote(engine_factory()), _fake_remote(engine_factory())])
    router.replicas[1].suspect = True
    router.step(0.0)
    assert router.replicas[1].dead and router.evictions == 1
    assert not router.replicas[0].dead


# ---------------------------------------------------------------------------
# the acceptance pair: seeded kill mid-serve, with and without recovery
# ---------------------------------------------------------------------------


def _fake_fleet(spec, n=2):
    """Remote replicas over fake channels, engines built via PlaceReplica
    from the shipped spec (the worker path); revive() gets a fresh fake
    worker from channel_factory — an in-process respawn."""
    worker_spec = spec.with_backend(
        "engine",
        scheduler=dataclasses.replace(spec.scheduler, slots=spec.slots_per_replica),
    )
    remotes = []
    for _ in range(n):
        r = RemoteReplica(FakeChannel())
        r.place(worker_spec)
        r.channel_factory = lambda: FakeChannel()
        remotes.append(r)
    return remotes


def _kill_schedule(round_no=5):
    return FaultSpec(events=({"kind": "kill", "replica": 1, "round": round_no},))


def test_kill_with_recovery_is_token_identical(models):
    """Tentpole acceptance, fast tier: kill 1 of 2 workers mid-serve with
    respawn + device-replay recovery on -> every session completes with
    exactly the fault-free tokens, zero streams shed."""
    spec = _spec()
    inproc = System.build(spec, models=models)
    want = inproc.serve().outputs

    policy = FaultPolicy(
        respawn=True, recover_streams=True,
        backoff_base_s=0.01, backoff_max_s=0.05,
    )
    remotes = _fake_fleet(spec)
    router = Router(remotes, placement=spec.cluster.placement, faults=policy)
    router.chaos = ChaosInjector(_kill_schedule(), router)
    system = System(spec, models, router, inproc.kit)
    result = system.serve()

    assert router.chaos.done and router.evictions == 1
    assert router.respawns == 1, "the killed worker must have been respawned"
    assert router.shed_streams == 0 and result.lost_devices == []
    assert router.recovered_streams >= 1
    assert not any(s.shed for s in result.sessions)
    assert result.outputs == want, "recovery diverged from the fault-free run"


def test_kill_without_recovery_sheds_lost_streams(models):
    """Same seeded schedule, recovery off: today's behavior — the dead
    replica's streams are shed into lost_devices, their sessions end with
    an explicit rejection (committed prefix intact), survivors complete."""
    spec = _spec()
    inproc = System.build(spec, models=models)
    want = inproc.serve().outputs

    remotes = _fake_fleet(spec)
    router = Router(remotes, placement=spec.cluster.placement)  # default policy
    router.chaos = ChaosInjector(_kill_schedule(), router)
    system = System(spec, models, router, inproc.kit)
    result = system.serve()

    assert router.evictions == 1 and router.respawns == 0
    lost = sorted(result.lost_devices)
    assert lost, "killing a loaded replica with recovery off must lose streams"
    assert lost == sorted(router.lost_devices)
    by_dev = {s.device_id: s for s in result.sessions}
    for dev in lost:
        s = by_dev[dev]
        assert s.shed and len(s.tokens) < len(want[dev])
        assert want[dev][: len(s.tokens)] == s.tokens, "shed prefix must match"
    for dev, s in by_dev.items():
        if dev not in lost:
            assert not s.shed and s.tokens == want[dev]


def test_recovery_without_respawn_sheds_over_capacity(models):
    """recover_streams alone: orphans fit only the survivor's free slots —
    with both pools full at kill time, everything on the dead replica is
    shed (lost_devices shrinks exactly to the capacity overflow)."""
    spec = _spec()
    remotes = _fake_fleet(spec)
    policy = FaultPolicy(recover_streams=True)  # no respawn
    router = Router(remotes, placement=spec.cluster.placement, faults=policy)
    prompts = np.arange(4 * 6, dtype=np.int32).reshape(4, 6) % V
    for dev in range(4):
        assert router.admit(dev, prompts[dev], 0.0) is not None
    router.replicas[1].chaos_kill()
    for dev in range(4):
        if dev not in router._where:  # already shed by an earlier eviction
            continue
        try:
            router.submit(dev, np.asarray([1, 2, 3], np.int32), 0.1)
        except ConnectionError:
            pass
    router.step(0.2)
    assert router.evictions == 1
    # survivor had 0 free slots: both orphans shed, none recovered
    assert router.recovered_streams == 0 and router.shed_streams == 2
    assert sorted(router.lost_devices) == [1, 3]


# ---------------------------------------------------------------------------
# spec-driven chaos through System.build (local replicas)
# ---------------------------------------------------------------------------


def test_spec_driven_local_chaos_recovers_token_identical(models):
    spec = _spec(
        cluster=ClusterSpec(
            replicas=2,
            faults={
                "respawn": True, "recover_streams": True,
                "backoff_base_s": 0.01, "backoff_max_s": 0.05,
            },
        ),
        faults=FaultSpec(events=({"kind": "kill", "replica": 1, "round": 5},)),
    )
    want = System.build(
        dataclasses.replace(spec, faults=FaultSpec()), models=models
    ).serve().outputs

    system = System.build(spec, models=models)
    assert system.engine.chaos is not None, "FaultSpec must attach the injector"
    result = system.serve()
    assert system.engine.evictions == 1 and system.engine.respawns == 1
    assert result.lost_devices == [] and not any(s.shed for s in result.sessions)
    assert result.outputs == want


def test_spec_driven_chaos_without_recovery_surfaces_lost_devices(models):
    spec = _spec(
        faults=FaultSpec(events=({"kind": "kill", "replica": 1, "round": 5},)),
    )
    system = System.build(spec, models=models)
    result = system.serve()
    assert system.engine.evictions == 1
    assert result.lost_devices, "ServeResult must surface the shed devices"
    shed = {s.device_id for s in result.sessions if s.shed}
    assert shed == set(result.lost_devices)
    assert "lost_devices" in result.to_json()


def test_all_replicas_evicted_is_fatal_through_serve(models):
    spec = _spec(
        cluster=ClusterSpec(replicas=1),
        devices=2,
        faults=FaultSpec(events=({"kind": "kill", "replica": 0, "round": 3},)),
    )
    system = System.build(spec, models=models)
    with pytest.raises(RuntimeError, match="all 1 replicas evicted"):
        system.serve()


def test_fault_spec_json_round_trip():
    spec = _spec(
        cluster=ClusterSpec(replicas=2, faults={"respawn": True, "max_respawns": 5}),
        faults=FaultSpec(seed=3, events=(
            {"kind": "kill", "replica": 1, "round": 4},
            {"kind": "delay", "replica": 0, "round": 2, "count": 3, "delay_s": 0.5},
        )),
    )
    assert spec.cluster.faults.respawn and spec.cluster.faults.max_respawns == 5
    assert spec.faults.active and spec.faults.events[0].kind == "kill"
    assert ServeSpec.from_json(spec.to_json_str()) == spec


# ---------------------------------------------------------------------------
# real worker processes (slow tier): SIGKILL mid-serve
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_real_worker_sigkill_recovery_token_identical(models):
    """Acceptance bar on real processes: ``kill -9`` one of 2 spawned
    workers mid-serve; with respawn + recovery the run completes
    token-identical to the fault-free run, with recovery visible in
    telemetry counters and zero shed streams."""
    spec = _spec()
    want = System.build(spec, models=models).serve().outputs

    chaos_spec = dataclasses.replace(
        spec,
        cluster=ClusterSpec(
            replicas=[{"flavor": "remote"}] * 2,
            faults={
                "respawn": True, "recover_streams": True,
                "backoff_base_s": 0.05, "backoff_max_s": 0.5,
            },
        ),
        faults=FaultSpec(events=({"kind": "kill", "replica": 1, "round": 5},)),
    )
    with System.build(chaos_spec) as system:
        result = system.serve()
        router = system.engine
        assert router.evictions == 1 and router.respawns == 1
        assert router.shed_streams == 0 and result.lost_devices == []
    assert result.outputs == want, "post-SIGKILL recovery diverged"
