"""One logging setup for the ``repro.*`` logger hierarchy.

Every module in the package logs through ``logging.getLogger(__name__)``,
which puts it under the single ``repro`` root this helper configures: one
stderr handler, one format, one level knob (the ``repro`` CLI's
``--log-level``, or REPRO_LOG_LEVEL in the environment).  Library use stays
silent by default — nothing is configured until an entry point calls
:func:`setup_logging`.
"""

from __future__ import annotations

import logging
import os
import sys
from typing import Optional

_FORMAT = "%(asctime)s %(levelname)-7s %(name)s: %(message)s"
_DATEFMT = "%H:%M:%S"


def setup_logging(level: Optional[str] = None, stream=None) -> logging.Logger:
    """Configure the ``repro`` root logger and return it.

    ``level`` is a name ("debug" … "critical"); when None, REPRO_LOG_LEVEL
    or "warning".  Idempotent: re-calling adjusts the level without stacking
    handlers, so the CLI and a worker it spawned can both call it.
    """
    name = (level or os.environ.get("REPRO_LOG_LEVEL") or "warning").upper()
    resolved = getattr(logging, name, None)
    if not isinstance(resolved, int):
        raise ValueError(f"unknown log level {name!r}")
    root = logging.getLogger("repro")
    root.setLevel(resolved)
    if not root.handlers:
        h = logging.StreamHandler(stream or sys.stderr)
        h.setFormatter(logging.Formatter(_FORMAT, datefmt=_DATEFMT))
        root.addHandler(h)
        root.propagate = False
    return root
