import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the production 16x16 (x2 pods)
# mesh out of host platform devices; smoke tests/benches see 1 device.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Per cell this records:
  * compiled.memory_analysis()  — proves the step fits per-device HBM
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes accessed
  * collective bytes parsed from the optimized HLO (roofline/analysis.py)
  * the three roofline terms + dominant bottleneck

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape decode_32k
  python -m repro.launch.dryrun --all --mesh pod          # 16x16, all cells
  python -m repro.launch.dryrun --all --mesh multipod     # 2x16x16
Results accumulate in experiments/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import json
import time
import traceback


from repro.compat import use_mesh
from repro.configs.base import SHAPES, all_cells, get_config, shape_applicable
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import Roofline, model_flops
from repro.roofline.hlo_cost import HloCostModel

OUT_DIR = "experiments/dryrun"


def run_cell(cfg, shape, mesh, mesh_name: str, *, verbose: bool = True,
             save: bool = True, attn_chunk: int = 1024, tag: str = "",
             kv_bits: int = 16) -> dict:
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, attn_chunk=attn_chunk, kv_bits=kv_bits)
    with use_mesh(mesh):
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    # trip-count-aware HLO walk: XLA's cost_analysis counts loop bodies ONCE
    # (scan-over-layers / grad-accum would be undercounted by 88x / 8x)
    costs = HloCostModel(hlo).totals()
    chips = mesh.size

    r = Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=costs["flops"],
        hlo_bytes=costs["bytes"],
        collective_bytes=costs["collective_bytes"],
        model_flops=model_flops(cfg, shape),
        arg_bytes=int(ma.argument_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
    )
    rec = r.to_dict()
    rec.update(
        alias_bytes=int(ma.alias_size_in_bytes),
        collectives_by_kind=costs["collective_by_kind"],
        xla_flops_nomult=float(ca.get("flops", 0.0)),
        xla_bytes_nomult=float(ca.get("bytes accessed", 0.0)),
        t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
        status="ok", tag=tag,
    )
    if verbose:
        hbm = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
               + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 1e9
        print(
            f"[{mesh_name}] {cfg.name} x {shape.name}: OK "
            f"per-dev HBM ~{hbm:.2f} GB (args {ma.argument_size_in_bytes/1e9:.2f} "
            f"+ temp {ma.temp_size_in_bytes/1e9:.2f} - alias {ma.alias_size_in_bytes/1e9:.2f}), "
            f"flops/dev {r.hlo_flops:.3g}, coll {costs['collective_bytes']/1e6:.1f} MB -> "
            f"compute {r.t_compute*1e3:.2f} ms | memory {r.t_memory*1e3:.2f} ms | "
            f"collective {r.t_collective*1e3:.2f} ms  [{r.bottleneck}-bound] "
            f"useful-flops {r.useful_flops_frac:.2f} "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
        )
    if save:
        _save(rec, mesh_name, cfg.name, shape.name, tag)
    return rec


def _save(rec: dict, mesh_name: str, arch: str, shape: str, tag: str = "") -> None:
    d = os.path.join(OUT_DIR, mesh_name)
    os.makedirs(d, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(d, f"{arch}__{shape}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", type=str, default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--attn-impl", type=str, default="xla", choices=["xla", "stub"],
                    help="stub = fused-kernel traffic model (see models/layers.py)")
    ap.add_argument("--tag", type=str, default="", help="perf-iteration tag")
    ap.add_argument("--combine-bf16", action="store_true",
                    help="§Perf A2: bf16 flash-decoding combine")
    ap.add_argument("--ssd-headshard", action="store_true",
                    help="§Perf B1 variant (refuted): SSD head sharding")
    ap.add_argument("--ssd-impl", type=str, default="xla", choices=["xla", "stub"],
                    help="§Perf B2: stub = ssd_scan kernel traffic model")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16],
                    help="int8 KV cache (beyond-paper fit/bandwidth feature)")
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    if args.attn_impl != "xla":
        from repro.models import layers as _L
        _L.ATTN_IMPL = args.attn_impl
        if not args.tag:
            args.tag = f"attn_{args.attn_impl}"
    if args.combine_bf16:
        import jax.numpy as jnp
        from repro.distributed import collectives as _C
        _C.COMBINE_DTYPE = jnp.bfloat16
    if args.ssd_headshard:
        from repro.models import mamba2 as _M2
        _M2.HEAD_SHARD = True
        if not args.tag:
            args.tag = "headshard"
    if args.ssd_impl != "xla":
        from repro.models import mamba2 as _M2
        _M2.SSD_IMPL = args.ssd_impl
        if not args.tag:
            args.tag = f"ssd_{args.ssd_impl}"

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod", make_production_mesh(multi_pod=True)))

    if args.all:
        cells = all_cells()
    else:
        cfg = get_config(args.arch)
        shapes = [SHAPES[args.shape]] if args.shape else [
            s for s in SHAPES.values() if shape_applicable(cfg, s)
        ]
        cells = [(cfg, s) for s in shapes]

    failures = []
    for mesh_name, mesh in meshes:
        for cfg, shape in cells:
            if not shape_applicable(cfg, shape):
                print(f"[{mesh_name}] {cfg.name} x {shape.name}: SKIP "
                      f"(long-context requires sub-quadratic mixing; see DESIGN.md)")
                continue
            try:
                run_cell(cfg, shape, mesh, mesh_name, attn_chunk=args.attn_chunk,
                         tag=args.tag, kv_bits=args.kv_bits)
            except Exception as e:  # noqa: BLE001 — report & continue
                failures.append((mesh_name, cfg.name, shape.name, repr(e)))
                print(f"[{mesh_name}] {cfg.name} x {shape.name}: FAIL {e!r}")
                _save({"status": "fail", "error": traceback.format_exc()},
                      mesh_name, cfg.name, shape.name, args.tag)
                if not args.keep_going:
                    raise

    print(f"\ndone: {len(failures)} failures")
    for f in failures:
        print("  FAIL", *f)


if __name__ == "__main__":
    main()
