"""SLED verification-attention kernel: modeled HBM traffic vs the XLA path.

No TPU in this container, so the comparison is structural: we lower the
pure-XLA flash verification attention, walk its HLO with the trip-aware
cost model, and compare bytes moved against the Pallas kernel's analytic
minimum (stream KV exactly once + write O(Sq) output).  Correctness of the
kernel itself is covered by tests/test_kernels.py (interpret-mode sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops
from repro.models.layers import flash_attention
from repro.roofline.hlo_cost import HloCostModel


def run(quick: bool = False) -> list:
    rows = []
    shapes = [
        (8, 5, 48, 1, 4096, 128),   # granite-34b-like MQA verify
        (8, 5, 32, 4, 4096, 128),   # qwen3-moe-like GQA verify
    ] if not quick else [(4, 5, 8, 1, 1024, 64)]
    for (B, Sq, Hq, Hkv, Skv, D) in shapes:
        q = jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.bfloat16)
        k = jax.ShapeDtypeStruct((B, Skv, Hkv, D), jnp.bfloat16)
        v = jax.ShapeDtypeStruct((B, Skv, Hkv, D), jnp.bfloat16)
        kv_valid = jax.ShapeDtypeStruct((B,), jnp.int32)

        def xla_path(q, k, v, kv_valid):
            q_pos = kv_valid[:, None] - Sq + jnp.arange(Sq)[None]
            return flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                   chunk=min(1024, Skv))

        lowered = jax.jit(xla_path).lower(q, k, v, kv_valid)
        costs = HloCostModel(lowered.compile().as_text()).totals()
        kv_bytes = 2 * B * Skv * Hkv * D * 2  # stream K and V exactly once
        out_bytes = 2 * B * Sq * Hq * D * 2
        kernel_min = kv_bytes + out_bytes
        rows.append({
            "shape": f"B{B}xSq{Sq}xHq{Hq}/{Hkv}xS{Skv}xD{D}",
            "xla_bytes_mb": round(costs["bytes"] / 1e6, 1),
            "kernel_min_mb": round(kernel_min / 1e6, 1),
            "traffic_ratio": round(costs["bytes"] / kernel_min, 2),
            "mxu_rows_packed": Sq * (Hq // Hkv),
        })
    emit(rows, "verify_kernel")
    return rows


if __name__ == "__main__":
    run()
