"""llava-next-mistral-7b [vlm]: mistral-7b backbone, anyres patch tiling stubbed.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=32000  [hf:llava-hf/llava-v1.6]
"""
from repro.configs.base import ModelConfig, register

LLAVA_NEXT = register(
    ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        act="swiglu",
        num_patches=576,  # stub anyres frontend: precomputed patch embeddings
        notes="backbone only; modality frontend is a stub (input_specs provides patch embeddings)",
    )
)
