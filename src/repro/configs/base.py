"""Config system: model architecture + workload shape dataclasses and registry.

Every assigned architecture gets a module in this package registering a
``ModelConfig`` via :func:`register`.  Shapes are the four assigned workload
cells (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for every family in the zoo."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // num_heads

    # attention / mlp options
    qkv_bias: bool = False
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    use_rope: bool = True

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.001
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): a shared (weight-tied) attention block is applied
    # after every `attn_every` ssm layers.
    attn_every: int = 0

    # encoder-decoder (whisper-style)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s -> 1500 frames (stub frontend)

    # VLM (llava-style): stub frontend provides precomputed patch embeddings
    num_patches: int = 0

    dtype: str = "bfloat16"
    notes: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim
        n = 0
        # embeddings (+ output head unless tied)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "hybrid_attn"):
            pass
        if self.family in ("dense", "moe", "vlm"):
            qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads) + hd * self.num_heads * d
            if self.qkv_bias:
                qkv += hd * (self.num_heads + 2 * self.num_kv_heads)
            per_layer += qkv
            if self.family == "moe":
                per_layer += d * self.num_experts  # router
                per_layer += self.num_experts * (3 * d * self.moe_d_ff)
            else:
                per_layer += 3 * d * self.d_ff
            per_layer += 2 * d  # norms
            n += L * per_layer
        elif self.family == "ssm":
            n += L * self._ssd_layer_params()
        elif self.family == "hybrid":
            n += L * self._ssd_layer_params()
            # one shared attention block (weight tied across applications)
            qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads) + hd * self.num_heads * d
            n += qkv + 3 * d * self.d_ff + 2 * d
        elif self.family == "encdec":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + hd * self.num_heads * d
            mlp = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
            n += self.encoder_layers * (attn + mlp + 2 * d)
            n += L * (2 * attn + mlp + 3 * d)  # self + cross attention
        return n

    def _ssd_layer_params(self) -> int:
        d, di = self.d_model, self.d_inner
        h, st = self.ssm_heads, self.ssm_state
        n = d * (2 * di + 2 * h * st + h)  # in_proj: x, z, B, C, dt
        n += self.conv_width * (di + 2 * h * st)  # conv over x,B,C
        n += h * 2  # A_log, D
        n += di * d  # out_proj
        n += d  # norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        dense_share = self.param_count() - self.num_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        active_moe = self.num_layers * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        return dense_share + active_moe

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            d_head=16,
            vocab_size=256,
        )
        if self.family == "moe":
            kw.update(num_experts=4, experts_per_token=2, moe_d_ff=32)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.family == "hybrid":
            kw.update(attn_every=2, num_layers=4)
        if self.family == "encdec":
            kw.update(encoder_layers=2, encoder_seq=32)
        if self.family == "vlm":
            kw.update(num_patches=8)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    # decode shapes run the SLED verify step: K draft tokens + 1 bonus position.
    spec_len: int = 4


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic sequence mixing; only SSM/hybrid families run it.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return model.family in LONG_CONTEXT_FAMILIES
    return True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False

_CONFIG_MODULES = [
    "whisper_tiny",
    "granite_34b",
    "phi3_mini_3_8b",
    "qwen15_32b",
    "qwen2_1_5b",
    "zamba2_1_2b",
    "mamba2_370m",
    "qwen3_moe_30b_a3b",
    "granite_moe_3b_a800m",
    "llava_next_mistral_7b",
    "sled_paper",
]


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    for m in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig]]:
    """Every applicable (architecture x shape) pair — the dry-run grid."""
    _load_all()
    cells = []
    for name in list_configs():
        cfg = _REGISTRY[name]
        if cfg.notes.startswith("paper-"):
            continue  # paper draft/target pairs are not assigned grid cells
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                cells.append((cfg, shape))
    return cells
