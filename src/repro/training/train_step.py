"""Training step: remat forward, seq-chunked vocab-sharded cross-entropy, AdamW.

Memory design for the big train cells (granite-34b @ 4k x 256):
  * scan-over-layers + per-layer remat bounds live activations to one layer;
  * the (B, S, V) logits tensor NEVER materialises: the loss is a
    jax.checkpoint'd scan over sequence chunks, each chunk computing
    (B, chunk, V) logits, its CE contribution, and recomputing in backward;
  * with vocab TP (sharding/policy.py shards lm_head columns), each chunk's
    logits are additionally sharded over the model axis — XLA inserts the
    max/sum all-reduces for a numerically exact sharded softmax.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.layers import MeshContext, NO_MESH
from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update, cast_like,
    compress_grads_int8,
)

IGNORE = -100


def chunked_ce_loss(model, params, h: jax.Array, labels: jax.Array,
                    chunk: int = 1024) -> jax.Array:
    """Mean CE over non-ignored labels, scanning sequence chunks."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=IGNORE)
    n = (S + pad) // chunk
    hc = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        tot, cnt = carry
        hb, lb = xs
        logits = model.lm_head(params, hb)  # (B, chunk, V) fp32
        mask = lb != IGNORE
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        nll = (lse - tgt) * mask
        return (tot + nll.sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = True
    loss_chunk: int = 1024
    attn_chunk: int = 1024
    grad_accum: int = 1
    compress_grads: bool = False  # int8 error-feedback DCN compression
    aux_coef: float = 0.001       # MoE load-balance loss weight


def make_loss_fn(model, tcfg: TrainConfig, ctx: MeshContext = NO_MESH):
    def loss_fn(params, batch):
        kw = {}
        if model.cfg.family == "encdec":
            kw["enc_frames"] = batch["frontend"]
        elif model.cfg.family == "vlm":
            kw["embeds_prefix"] = batch["frontend"]
        h, aux = model.forward(params, batch["tokens"], ctx, remat=tcfg.remat,
                               attn_chunk=tcfg.attn_chunk, **kw)
        labels = batch["labels"]
        if model.cfg.family == "vlm":  # hidden includes the patch prefix
            npch = model.cfg.num_patches
            labels = jnp.pad(labels, ((0, 0), (npch, 0)), constant_values=IGNORE)
        loss = chunked_ce_loss(model, params, h, labels, tcfg.loss_chunk)
        return loss + tcfg.aux_coef * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(model, tcfg: TrainConfig, ctx: MeshContext = NO_MESH,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, err_fb, batch) -> (...).

    ``grad_shardings``: optional NamedSharding pytree (the ZeRO-2 opt-state
    layout).  Pinning the gradients to it makes XLA emit a reduce-scatter
    into the optimizer shards instead of a full all-reduce — half the
    gradient-sync bytes, and the update then runs on 1/N of the state.
    """
    loss_fn = make_loss_fn(model, tcfg, ctx)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, err_fb, batch):
        if tcfg.grad_accum > 1:
            # microbatch over the leading batch axis; psum of grads is
            # deferred until the final accumulated gradient (overlap-
            # friendly: one reduce per step instead of per microbatch).
            mb = jax.tree.map(
                lambda a: a.reshape(tcfg.grad_accum, a.shape[0] // tcfg.grad_accum,
                                    *a.shape[1:]),
                batch,
            )
            if ctx.mesh is not None:
                # the reshape defeats GSPMD's batch-sharding propagation
                # (it replicates the loop body otherwise) — re-pin it
                from jax.sharding import NamedSharding, PartitionSpec as P

                def pin(a):
                    bs = ctx.bspec(a.shape[1])
                    spec = P(None, bs, *((None,) * (a.ndim - 2)))
                    return jax.lax.with_sharding_constraint(
                        a, NamedSharding(ctx.mesh, spec))

                mb = jax.tree.map(pin, mb)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (l, met), g = grad_fn(params, mbatch)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mets = jax.lax.scan(acc_body, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss = loss / tcfg.grad_accum
            metrics = jax.tree.map(lambda a: a.mean(), mets)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if tcfg.compress_grads:
            grads, err_fb = compress_grads_int8(grads, err_fb)

        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_shardings)
        master, opt_state, opt_metrics = adamw_update(grads, opt_state, tcfg.optimizer)
        params = cast_like(params, master)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, err_fb, metrics

    return train_step
