"""granite-34b [dense]: llama-arch code model, MQA (kv=1).

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152  [arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig, register

GRANITE_34B = register(
    ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        act="gelu",  # granite-34b-code uses gpt_bigcode-style MLP
        notes="MQA: kv cache replicated over model axis, batch over data",
    )
)
