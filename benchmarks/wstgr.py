"""Paper Fig. 4: Whole-System Token Generation Rate vs server batch size.

SLED vs centralized serving for 11B and 70B target models; the server is
kept saturated (N = 8x batch devices) so WSTGR reflects server-side
efficiency.  Expected shape: WSTGR rises with batch (weight-stream
amortisation), SLED sits >2x above centralized at equal batch — the paper's
x2.2 system-throughput claim.

``--engine`` switches to the REAL continuous-batching engine
(core/server_engine.py) with tiny models: the same SimResult-style fields
(wstgr, mean_batch_fill, rounds) are measured from an actual serving run and
emitted next to the discrete-event simulator's prediction for a matched
arrival pattern, so simulator claims can be cross-checked end-to-end.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import emit
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, simulate


def run(quick: bool = False) -> list:
    rows = []
    batches = (1, 2, 4, 8, 16, 32) if not quick else (2, 8, 32)
    for target_p, tname in ((11e9, "11B"), (70e9, "70B")):
        for b in batches:
            base = SimConfig(
                mode="sled", spec_len=4, acceptance=0.90,
                device_rate=RPI5.rate("llama-3b-draft", 4),
                target_params=target_p, server_batch=b,
                batch_policy="deadline", n_devices=64 * b,
                sim_time=10.0 if quick else 20.0,
            )
            sled = simulate(base, A100_X4)
            cent = simulate(dataclasses.replace(base, mode="centralized"), A100_X4)
            rows.append({
                "target": tname, "batch": b,
                "wstgr_sled": round(sled.wstgr, 1),
                "wstgr_centralized": round(cent.wstgr, 1),
                "ratio": round(sled.wstgr / max(cent.wstgr, 1e-9), 2),
                "sled_busy": round(sled.server_busy_frac, 2),
            })
    emit(rows, "fig4_wstgr")
    return rows


def run_engine(quick: bool = False) -> list:
    """Real-model continuous batching: serve a small staggered fleet through
    ServerEngine per policy and report measured SimResult-style stats next to
    the simulator's batch-fill prediction for the same fleet."""
    import jax

    from repro.configs.base import get_config
    from repro.core.server_engine import EdgeDeviceKit, ServerEngine
    from repro.models.model_zoo import build_model

    vocab = 128
    tcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    dcfg = dataclasses.replace(tcfg, name="draft", num_layers=1)
    target, draft = build_model(tcfg), build_model(dcfg)
    tp = target.init_params(jax.random.key(0))
    dp = draft.init_params(jax.random.key(1))

    n_dev, max_new, k_max = (3, 8, 4) if quick else (6, 16, 4)
    prompts = jax.random.randint(jax.random.key(2), (n_dev, 12), 0, vocab)
    rows = []
    for policy in (("continuous",) if quick else ("continuous", "deadline")):
        engine = ServerEngine(target, tp, n_slots=n_dev, max_len=128, k_max=k_max,
                              policy=policy, max_wait=0.0, attn_chunk=32)
        kit = EdgeDeviceKit(draft, dp, k_max=k_max, c_th=0.3, greedy=True, attn_chunk=32)
        devices, outputs = {}, {}
        t0 = time.time()
        tick = 0
        while len(outputs) < n_dev:
            tick += 1
            for i in range(n_dev):
                if i not in devices and i not in outputs and i * 2 <= tick:
                    engine.admit(i, prompts[i], time.time() - t0)
                    devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=i)
            for i, dev in devices.items():
                if not dev.awaiting:
                    engine.submit(i, dev.draft(), time.time() - t0)
            verdicts = engine.step(time.time() - t0)
            for v in verdicts or []:
                devices[v.device_id].on_verdict(v)
                if len(devices[v.device_id].committed) >= max_new:
                    outputs[v.device_id] = devices[v.device_id].committed[:max_new]
                    engine.retire(v.device_id)
                    del devices[v.device_id]
        st = engine.stats(time.time() - t0)
        sim = simulate(
            SimConfig(mode="sled", n_devices=n_dev, spec_len=k_max,
                      server_batch=n_dev, batch_policy=policy,
                      sim_time=5.0 if quick else 10.0),
            A100_X4,
        )
        rows.append({
            "policy": policy,
            "wstgr_measured": round(st.wstgr, 1),
            "mean_batch_fill": round(st.mean_batch_fill, 2),
            "partial_rounds": st.partial_rounds,
            "rounds": st.rounds,
            "sim_mean_batch_fill": round(sim.mean_batch_fill, 2),
        })
    emit(rows, "engine_wstgr")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="run the real-model continuous-batching engine")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    (run_engine if a.engine else run)(quick=a.quick)
