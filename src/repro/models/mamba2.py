"""Mamba2 (SSD — state-space duality) in pure JAX.

Training/prefill use the chunked matmul form (intra-chunk quadratic +
sequential inter-chunk state pass via lax.scan — TPU-friendly: the quadratic
part is MXU matmuls, the scan carries only the (B, H, P, N) state).  Decode
is the O(1) recurrence.

Speculative verification support: SSM/conv states cannot be rolled back by
masking (unlike KV caches), so ``decode_forward`` emits per-position state
CHECKPOINTS for each of the K+1 fed tokens; ``select_checkpoint`` commits the
state at the acceptance boundary.  This is the SSM-specific piece of SLED's
server-side verify step (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import MeshContext, NO_MESH

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# SSD layer
# ---------------------------------------------------------------------------


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state  # x, B, C share the causal conv


def init_ssd_layer(cfg, key) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    H, N, cw = cfg.ssm_heads, cfg.ssm_state, cfg.conv_width
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H  # z, x, B, C, dt
    std = 0.02
    out_std = std / math.sqrt(2 * max(cfg.num_layers, 1))
    return {
        "norm": L.init_norm(d, cfg.norm),
        "in_proj": (jax.random.normal(k1, (d, d_in_proj)) * std).astype(jnp.bfloat16),
        "conv_w": (jax.random.normal(k2, (cw, conv_dim(cfg))) * 0.2).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim(cfg),), jnp.bfloat16),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(jnp.float32),
        "gnorm": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(k4, (di, d)) * out_std).astype(jnp.bfloat16),
    }


def _split_in_proj(cfg, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xBC, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: (B, S, C), w: (cw, C)."""
    cw = w.shape[0]
    up = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    S = u.shape[1]
    y = sum(up[:, i : i + S] * w[i][None, None] for i in range(cw))
    return y + b[None, None]


def _gated_out(cfg, lp, y: jax.Array, z: jax.Array) -> jax.Array:
    """Mamba2 RMSNormGated + out_proj. y, z: (B, S, di)."""
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["gnorm"])
    return y.astype(jnp.bfloat16) @ lp["out_proj"]


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) post-softplus, fp32
    A: jax.Array,   # (H,) negative, fp32
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) fp32
    remat: bool = False,  # don't save per-chunk (Q,Q) decay/score tensors
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final state (B,H,P,N))."""
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, Q, *a.shape[2:]), 1, 0)

    if SSD_IMPL == "stub":  # single-pass traffic model of the Pallas kernel
        w = (dt * A[None, None]) + (
            Bm.astype(jnp.float32).mean(-1) + Cm.astype(jnp.float32).mean(-1)
        )[..., None]
        y = (x.astype(jnp.float32) * w[..., None]).astype(x.dtype)
        h = (jnp.zeros((B, H, Pd, N), jnp.float32) if h0 is None
             else h0.astype(jnp.float32)) + jnp.einsum(
                 "bh,bhp->bhp", w.sum(1), y.astype(jnp.float32).sum(1).reshape(B, H, Pd)
             )[..., None] * 0.0
        return y[:, :S], h

    a_log = (dt * A[None, None]).astype(jnp.float32)  # (B,Sp,H) log-decays
    xs = (to_chunks(x), to_chunks(dt), to_chunks(a_log), to_chunks(Bm), to_chunks(Cm))
    h_init = jnp.zeros((B, H, Pd, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h, inp):
        xq, dtq, aq, Bq, Cq = inp
        cum = jnp.cumsum(aq, axis=1)  # (B,Q,H)
        # carry-in contribution: y_i += exp(cum_i) * C_i . h
        y_off = jnp.einsum("bqn,bhpn->bqhp", Cq.astype(jnp.float32), h) * jnp.exp(cum)[..., None]
        # intra-chunk: W_ij = (C_i.B_j) exp(cum_i - cum_j) dt_j  (j <= i)
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
        W = cb[..., None] * decay * dtq[:, None, :, :]
        y_diag = jnp.einsum("bijh,bjhp->bihp", W, xq.astype(jnp.float32))
        # chunk-end state update
        d_end = jnp.exp(cum[:, -1:, :] - cum) * dtq  # (B,Q,H)
        h_new = jnp.einsum("bqh,bqn,bqhp->bhpn", d_end, Bm_f := Bq.astype(jnp.float32), xq.astype(jnp.float32))
        h = jnp.exp(cum[:, -1])[..., None, None] * h + h_new
        return h, (y_off + y_diag).astype(x.dtype)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, ys = jax.lax.scan(body, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, Pd)[:, :S]
    return y, h


HEAD_SHARD = False  # §Perf B1: REFUTED under corrected cost accounting — the
# head-shard boundary gathers (2.9 s) exceed the memory/compute win (1.5 s);
# kept as a dryrun flag (--ssd-headshard) for the measurement record.

# §Perf B2: the Pallas ssd_scan kernel keeps the per-chunk quadratic tensors
# (seg/decay/W, each (B,Q,Q,H)) in VMEM; "stub" models its traffic: read
# x/dt/B/C once, write y/state once.  Kernel GEMM FLOPs re-added analytically.
SSD_IMPL = "xla"


def _head_shard(a: jax.Array, ctx: MeshContext, axis: int):
    """Pin an (..., H, ...) tensor to head-sharding over the model axis.

    Without this, GSPMD improvises shardings for the big SSD intermediates
    and pays repeated model-axis gathers (the mamba2 train cells were
    collective-BOUND — §Perf iteration B1); with it the SSD math partitions
    cleanly per head and only the layer output is re-gathered once.
    """
    if not HEAD_SHARD or ctx.mesh is None or a.shape[axis] % ctx.tp != 0:
        return a
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = [None] * a.ndim
    if ctx.batch_axes and a.shape[0] % ctx.n_batch_shards == 0:
        spec[0] = ctx.batch_axes
    spec[axis] = "model"
    return jax.lax.with_sharding_constraint(a, NamedSharding(ctx.mesh, P(*spec)))


def ssd_layer_forward(
    cfg, lp: Params, x: jax.Array, *, chunk: Optional[int] = None,
    h0: Optional[jax.Array] = None, conv0: Optional[jax.Array] = None,
    return_state: bool = False, remat_inner: bool = False,
    ctx: MeshContext = NO_MESH,
):
    """Full-sequence SSD block. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    H, N, Pd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    cw = cfg.conv_width
    zxbcdt = L.apply_norm(x, lp["norm"], cfg.norm) @ lp["in_proj"]
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    if conv0 is not None:
        full = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)
        conv_out = _causal_conv(full, lp["conv_w"], lp["conv_b"])[:, cw - 1 :]
        new_conv = full[:, -(cw - 1) :]
    else:
        conv_out = _causal_conv(xBC, lp["conv_w"], lp["conv_b"])
        new_conv = xBC[:, -(cw - 1) :]
    xBC = jax.nn.silu(conv_out.astype(jnp.float32)).astype(jnp.bfloat16)
    di = cfg.d_inner
    x_ssm = _head_shard(xBC[..., :di].reshape(B, S, H, Pd), ctx, 2)
    Bm = xBC[..., di : di + N]
    Cm = xBC[..., di + N :]
    dt = _head_shard(
        jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None]), ctx, 2)
    A = -jnp.exp(lp["A_log"])
    y, h = ssd_chunked(x_ssm, dt, A, Bm, Cm, chunk or cfg.ssm_chunk, h0=h0,
                       remat=remat_inner)
    y = _head_shard(y, ctx, 2) + lp["D"][None, None, :, None] * x_ssm.astype(jnp.float32)
    out = x + _gated_out(cfg, lp, y.reshape(B, S, di), z)
    if return_state:
        return out, (h, new_conv)
    return out


def ssd_layer_decode(
    cfg, lp: Params, x: jax.Array,  # (B, K, d) — the K+1 verify tokens
    h0: jax.Array,    # (B, H, P, N) fp32
    conv0: jax.Array,  # (B, cw-1, conv_dim)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sequential decode over K tokens, emitting per-position checkpoints.

    Returns (out (B,K,d), h_ckpts (B,K,H,P,N) bf16, conv_ckpts (B,K,cw-1,C)).
    ``h_ckpts[:, i]`` is the SSM state after consuming token i — speculative
    rollback selects index ``n_accepted`` (see core/verification.py).
    """
    B, K, d = x.shape
    H, N, Pd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    cw = cfg.conv_width
    zxbcdt = L.apply_norm(x, lp["norm"], cfg.norm) @ lp["in_proj"]
    z, xBC, dt = _split_in_proj(cfg, zxbcdt)
    full = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)  # (B, cw-1+K, C)
    conv_out = _causal_conv(full, lp["conv_w"], lp["conv_b"])[:, cw - 1 :]
    # conv checkpoints: the cw-1 window ending at each position
    idx = jnp.arange(K)[:, None] + jnp.arange(1, cw)[None]  # (K, cw-1)
    conv_ckpts = jnp.moveaxis(full[:, idx], 1, 1)  # (B, K, cw-1, C)
    xBC = jax.nn.silu(conv_out.astype(jnp.float32)).astype(jnp.bfloat16)
    di = cfg.d_inner
    x_ssm = xBC[..., :di].reshape(B, K, H, Pd).astype(jnp.float32)
    Bm = xBC[..., di : di + N].astype(jnp.float32)
    Cm = xBC[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None])  # (B,K,H)
    A = -jnp.exp(lp["A_log"])

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A[None])  # (B,H)
        h = decay[..., None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, Bt, xt
        )
        y = jnp.einsum("bn,bhpn->bhp", Ct, h)
        return h, (y, h.astype(jnp.bfloat16))

    inps = (
        jnp.moveaxis(x_ssm, 1, 0), jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0),
    )
    _, (ys, hs) = jax.lax.scan(step, h0.astype(jnp.float32), inps)
    y = jnp.moveaxis(ys, 0, 1)  # (B,K,H,P)
    h_ckpts = jnp.moveaxis(hs, 0, 1)  # (B,K,H,P,N)
    y = y + lp["D"][None, None, :, None] * x_ssm
    out = x + _gated_out(cfg, lp, y.reshape(B, K, di), z)
    return out, h_ckpts, conv_ckpts


# ---------------------------------------------------------------------------
# Pure-SSM model (mamba2-370m)
# ---------------------------------------------------------------------------


def init_params(cfg, key, **_) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.num_layers)
    p: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(jnp.bfloat16),
        "layers": jax.vmap(lambda k: init_ssd_layer(cfg, k))(keys),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * 0.02).astype(jnp.bfloat16)
    return p


def lm_head(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)


def make_cache(cfg, batch: int, max_len: int = 0, *, spec_only: bool = False, **_):
    """SSM cache: O(1) in sequence length. ``max_len`` ignored (API parity)."""
    H, N, Pd, cw = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.conv_width
    shapes = {
        "ssm": ((cfg.num_layers, batch, H, Pd, N), jnp.float32),
        "conv": ((cfg.num_layers, batch, cw - 1, conv_dim(cfg)), jnp.bfloat16),
        "length": ((batch,), jnp.int32),
    }
    if spec_only:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def forward(cfg, params, tokens, ctx: MeshContext = NO_MESH, *, remat=False, **_):
    x = L.embed_lookup(params["embed"], tokens, ctx)

    def body(h, lp):
        return ssd_layer_forward(cfg, lp, h, remat_inner=remat, ctx=ctx), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(x, params["final_norm"], cfg.norm), jnp.zeros((), jnp.float32)


def prefill(cfg, params, tokens, cache, ctx: MeshContext = NO_MESH, **_):
    x = L.embed_lookup(params["embed"], tokens, ctx)

    def body(h, xs):
        lp, h0, c0 = xs
        out, (hf, cf) = ssd_layer_forward(cfg, lp, h, h0=h0, conv0=c0,
                                          return_state=True, ctx=ctx)
        return out, (hf, cf.astype(jnp.bfloat16))

    x, (hs, convs) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    new_cache = {"ssm": hs, "conv": convs, "length": cache["length"] + tokens.shape[1]}
    return lm_head(cfg, params, x[:, -1:, :])[:, 0], new_cache


def decode_forward(cfg, params, cache, tokens, ctx: MeshContext = NO_MESH, *,
                   slots=None, **_):
    """Verify-style decode: K tokens, per-position state checkpoints.

    Returns (h (B,K,d), ckpt_cache, aux).  ``ckpt_cache['ssm']`` has an extra
    K axis: (L, B, K, H, P, N); commit with select_checkpoint(ckpt_cache, n).
    """
    if slots is not None:
        raise NotImplementedError(
            "slot-indexed paged attention is not supported for the 'ssm' family: "
            "recurrent state leaves (ssm, conv) are not position-indexed K/V, so "
            "pool rows cannot be addressed in place.  Route this model through "
            "the gather/scatter fallback instead (paged_attention=False, or gate "
            "on models.kvcache.supports_paged_attention(cfg))."
        )
    x = L.embed_lookup(params["embed"], tokens, ctx)

    def body(h, xs):
        lp, h0, c0 = xs
        out, h_ck, c_ck = ssd_layer_decode(cfg, lp, h, h0, c0)
        return out, (h_ck, c_ck)

    x, (h_cks, c_cks) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    ckpt_cache = {**cache, "ssm_ckpt": h_cks, "conv_ckpt": c_cks}
    return x, ckpt_cache, jnp.zeros((), jnp.float32)


def select_checkpoint(cache: Dict[str, jax.Array], n_commit: jax.Array) -> Dict[str, jax.Array]:
    """Commit the state after ``n_commit`` tokens (per row), n_commit >= 1.

    ``n_commit = m + 1`` where m is the accepted-draft count (the first fed
    token is the previously-committed one, always kept).
    """
    i = (n_commit - 1).astype(jnp.int32)  # checkpoint index per row
    b = jnp.arange(cache["ssm_ckpt"].shape[1])

    def take(a):  # a: (L, B, K, ...) -> (L, B, ...)
        return a[:, b, i]

    return {
        "ssm": take(cache["ssm_ckpt"]).astype(jnp.float32),
        "conv": take(cache["conv_ckpt"]),
        "length": cache["length"] + n_commit.astype(jnp.int32),
    }
