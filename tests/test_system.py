"""End-to-end behaviour of the SLED system (paper-level invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import drafting, verification
from repro.core.engine_loop import autoregressive_generate, sled_generate
from repro.models.model_zoo import build_model

V = 96


def _pair():
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    tcfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(),
                               name="t", vocab_size=V)
    dm, tm = build_model(dcfg), build_model(tcfg)
    return dm, dm.init_params(jax.random.key(1)), tm, tm.init_params(jax.random.key(2))


@pytest.mark.slow
def test_end_to_end_heterogeneous_drafts_one_target():
    """SLED's core serving property: ONE target model verifies drafts from
    DIFFERENT draft models (device heterogeneity, §III-B) — outputs stay
    exactly the target's greedy outputs either way."""
    dm1, dp1, tm, tp = _pair()
    dcfg2 = dataclasses.replace(get_config("qwen2-1.5b").reduced(),
                                name="d2", vocab_size=V, num_layers=1, d_ff=64)
    dm2 = build_model(dcfg2)
    dp2 = dm2.init_params(jax.random.key(7))
    prompts = jax.random.randint(jax.random.key(3), (2, 10), 0, V)
    ref = autoregressive_generate(tm, tp, prompts, max_new=16)
    out1, _, _ = sled_generate(dm1, dp1, tm, tp, prompts, max_new=16, k_max=3)
    out2, _, _ = sled_generate(dm2, dp2, tm, tp, prompts, max_new=16, k_max=5)
    np.testing.assert_array_equal(out1, ref)
    np.testing.assert_array_equal(out2, ref)


def test_verify_step_batch_padding_matches_unpadded():
    """The server's padded static batch (paper: 'applies appropriate
    padding to equalize token lengths') gives identical verdicts to
    per-request processing."""
    _, _, tm, tp = _pair()
    B, P, K = 3, 8, 4
    prompts = jax.random.randint(jax.random.key(5), (B, P), 0, V)
    cache = tm.make_cache(B, 64, attn_chunk=16)
    pf = jax.jit(verification.make_prefill_step(tm, attn_chunk=16))
    _, cache, prev = pf(tp, cache, prompts)
    drafts = jax.random.randint(jax.random.key(6), (B, K), 0, V)
    lengths = jnp.array([4, 2, 1], jnp.int32)
    vs = jax.jit(verification.make_verify_step(tm, greedy=True, attn_chunk=16))
    batch = verification.make_verify_batch(prev, drafts, lengths, seed=0)
    res, _ = vs(tp, cache, batch)
    # row-by-row with its own exact length must agree
    for i in range(B):
        c1 = tm.make_cache(1, 64, attn_chunk=16)
        _, c1, prev1 = pf(tp, c1, prompts[i : i + 1])
        b1 = verification.make_verify_batch(
            prev1, drafts[i : i + 1], lengths[i : i + 1], seed=0)
        r1, _ = vs(tp, c1, b1)
        assert int(r1.n_accepted[0]) == int(res.n_accepted[i])
        assert int(r1.extra_token[0]) == int(res.extra_token[i])


def test_draft_round_confidence_thresholding():
    dm, dp, _, _ = _pair()
    B, P = 2, 8
    prompts = jax.random.randint(jax.random.key(5), (B, P), 0, V)
    cache = dm.make_cache(B, 64, attn_chunk=16)
    pf = jax.jit(verification.make_prefill_step(dm, attn_chunk=16))
    _, cache, prev = pf(dp, cache, prompts)
    # impossible threshold -> every round drafts exactly 1 token
    res = drafting.draft_round(dm, dp, cache, prev, jax.random.key(0),
                               k_max=6, c_th=1.1, greedy=True, attn_chunk=16)
    assert res.lengths.tolist() == [1, 1]
    # zero threshold -> always drafts k_max
    res = drafting.draft_round(dm, dp, cache, prev, jax.random.key(0),
                               k_max=6, c_th=0.0, greedy=True, attn_chunk=16)
    assert res.lengths.tolist() == [6, 6]


def test_resume_after_verify_rollback_consistency():
    """Device cache rollback: after a rejection, re-drafting from the
    rolled-back cache matches a fresh cache built from the committed
    prefix only."""
    dm, dp, tm, tp = _pair()
    B, P, K = 1, 8, 4
    prompts = jax.random.randint(jax.random.key(5), (B, P), 0, V)
    cache = dm.make_cache(B, 64, attn_chunk=16)
    pf = jax.jit(verification.make_prefill_step(dm, attn_chunk=16))
    _, cache, prev = pf(dp, cache, prompts)
    res = drafting.draft_round(dm, dp, cache, prev, jax.random.key(0),
                               k_max=K, greedy=True, attn_chunk=16)
    # pretend the server accepted 2 drafts and corrected with token 7
    n_acc = jnp.array([2], jnp.int32)
    rolled = drafting.resume_after_verify(dm, res, n_acc)
    corr = jnp.array([7], jnp.int32)
    res2 = drafting.draft_round(dm, dp, rolled, corr, jax.random.key(1),
                                k_max=K, greedy=True, attn_chunk=16)
    # reference: fresh cache over [prompt, d1, d2], then feed the correction
    seq = jnp.concatenate([prompts, res.tokens[:, :2], corr[:, None]], axis=1)
    c2 = dm.make_cache(B, 64, attn_chunk=16)
    _, c2, prev2 = pf(dp, c2, seq)
    ref2 = drafting.draft_round(dm, dp, c2, prev2, jax.random.key(1),
                                k_max=K, greedy=True, attn_chunk=16)
    np.testing.assert_array_equal(np.asarray(res2.tokens), np.asarray(ref2.tokens))
