"""Server-side batch planner (SLED §III-B) + timeout/straggler policies.

The paper's implementation uses *static batching*: verification requests
queue until a fixed batch size is reached, then a batch planner pads token
lengths and dispatches one verification forward pass.  We implement that
faithfully, plus two beyond-paper policies the paper lists as future work
("adaptive queue and batching strategy ... for better server utilization"):

  * ``continuous`` — dispatch whatever is queued whenever the target model
    is idle (up to batch_size), vLLM-style.
  * ``deadline``   — static batching with a max-wait: a partially filled
    batch is dispatched once its oldest request has waited ``max_wait``.

Straggler mitigation: requests whose device link stalls past
``straggler_timeout`` are dropped from the queue (the device falls back to
local drafts per §III-A's timeout protocol) rather than holding the batch.

All host-side, deterministic, and driven either by the discrete-event
simulator (serving/simulator.py) or a real serving loop (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class VerifyRequest:
    device_id: int
    arrival: float            # seconds
    prev_token: int
    draft_tokens: np.ndarray  # (k,) variable length <= k_max
    draft_q: Optional[np.ndarray] = None
    request_id: int = 0

    @property
    def k(self) -> int:
        return len(self.draft_tokens)


@dataclasses.dataclass
class PlannedBatch:
    requests: List[VerifyRequest]
    dispatch_time: float
    k_max: int

    @property
    def size(self) -> int:
        return len(self.requests)

    def padded_arrays(self):
        """The paper's padding step: equalize token lengths across the batch."""
        B = len(self.requests)
        toks = np.zeros((B, self.k_max), np.int32)
        qs = np.zeros((B, self.k_max), np.float32)
        lens = np.zeros((B,), np.int32)
        prev = np.zeros((B,), np.int32)
        for i, r in enumerate(self.requests):
            k = min(r.k, self.k_max)
            toks[i, :k] = r.draft_tokens[:k]
            if r.draft_q is not None:
                qs[i, :k] = r.draft_q[:k]
            lens[i] = k
            prev[i] = r.prev_token
        return prev, toks, qs, lens


class BatchPlanner:
    def __init__(
        self,
        batch_size: int,
        k_max: int,
        policy: str = "static",       # static | continuous | deadline
        max_wait: float = 0.050,      # deadline policy: oldest-request wait cap
        straggler_timeout: float = 1.0,
    ):
        assert policy in ("static", "continuous", "deadline")
        self.batch_size = batch_size
        self.k_max = k_max
        self.policy = policy
        self.max_wait = max_wait
        self.straggler_timeout = straggler_timeout
        self.queue: Deque[VerifyRequest] = deque()
        self.dropped: List[VerifyRequest] = []

    def add(self, req: VerifyRequest) -> None:
        self.queue.append(req)

    def _evict_stragglers(self, now: float) -> None:
        kept: Deque[VerifyRequest] = deque()
        for r in self.queue:
            if now - r.arrival > self.straggler_timeout:
                self.dropped.append(r)  # device falls back per §III-A timeout
            else:
                kept.append(r)
        self.queue = kept

    def next_batch(self, now: float, server_idle: bool) -> Optional[PlannedBatch]:
        """Called by the event loop; returns a batch to dispatch or None."""
        self._evict_stragglers(now)
        if not self.queue:
            return None
        if self.policy == "static":
            if len(self.queue) < self.batch_size:
                return None
        elif self.policy == "deadline":
            oldest_wait = now - self.queue[0].arrival
            if len(self.queue) < self.batch_size and oldest_wait < self.max_wait:
                return None
        elif self.policy == "continuous":
            if not server_idle:
                return None
        n = min(self.batch_size, len(self.queue))
        reqs = [self.queue.popleft() for _ in range(n)]
        return PlannedBatch(requests=reqs, dispatch_time=now, k_max=self.k_max)

    def next_event_hint(self, now: float) -> Optional[float]:
        """Earliest future time at which a deadline/straggler fires."""
        times = []
        for r in self.queue:
            times.append(r.arrival + self.straggler_timeout)
            if self.policy == "deadline":
                times.append(r.arrival + self.max_wait)
        future = [t for t in times if t > now]
        return min(future) if future else None
