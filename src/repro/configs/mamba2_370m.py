"""mamba2-370m [ssm]: pure SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128  [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register

MAMBA2_370M = register(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        tie_embeddings=True,
        notes="attention-free; runs long_500k; decode state is O(1) in sequence length",
    )
)
