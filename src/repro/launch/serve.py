"""SLED server launcher: real models + batch planner, single-host demo of
the deployment path (the production mesh path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 6

Runs the server loop: requests (prompt + device draft stream) arrive, the
BatchPlanner forms padded verification batches, the jitted verify_step
commits tokens, timeouts evict stragglers.  Uses reduced configs on CPU;
--arch selects which assigned architecture plays the target.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import drafting, verification
from repro.core.scheduler import BatchPlanner, VerifyRequest
from repro.models.model_zoo import build_model, frontend_stub
from repro.quant.quantize import dequantize_pytree, quantize_pytree


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--c-th", type=float, default=0.3)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--bits", type=int, default=16, choices=(4, 8, 16))
    args = ap.parse_args()

    vocab = 256
    tcfg = dataclasses.replace(get_config(args.arch).reduced(), vocab_size=vocab)
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(),
                               name="edge-draft", vocab_size=vocab, num_layers=1)
    target = build_model(tcfg)
    draft = build_model(dcfg)
    kw = {"max_pos": 256} if not tcfg.use_rope else {}
    tp = target.init_params(jax.random.key(0), **kw)
    if args.bits < 16:
        tp = dequantize_pytree(quantize_pytree(tp, args.bits))
        print(f"serving int{args.bits} weight-only quantized target")
    dp = draft.init_params(jax.random.key(1))

    B = args.requests
    prompts = jax.random.randint(jax.random.key(2), (B, 12), 0, vocab)
    ckw = {"enc_len": tcfg.encoder_seq} if tcfg.family == "encdec" else {}
    t_cache = target.make_cache(B, 128, attn_chunk=32, **ckw)
    d_cache = draft.make_cache(B, 128, attn_chunk=32)
    pkw = {}
    if tcfg.family in ("encdec", "vlm"):
        stub = frontend_stub(tcfg, B)
        pkw["enc_frames" if tcfg.family == "encdec" else "embeds_prefix"] = stub
    t_pf = jax.jit(verification.make_prefill_step(
        target, attn_chunk=32, with_frontend=bool(pkw)))
    d_pf = jax.jit(verification.make_prefill_step(draft, attn_chunk=32))
    verify = jax.jit(verification.make_verify_step(target, greedy=True, attn_chunk=32))

    _, t_cache, prev = t_pf(tp, t_cache, prompts, *(pkw.values() or []))
    _, d_cache, _ = d_pf(dp, d_cache, prompts)

    # the demo's target cache is row-per-device, so each round verifies the
    # full device set (row-subset batches need paged caches — the simulator
    # models partial fills; see serving/simulator.py)
    planner = BatchPlanner(batch_size=B, k_max=args.k_max,
                           policy="deadline", max_wait=0.0)
    committed = np.zeros(B, np.int64)
    rounds = 0
    t0 = time.time()
    while committed.min() < args.max_new:
        dres = drafting.draft_round(draft, dp, d_cache, prev, jax.random.key(rounds),
                                    k_max=args.k_max, c_th=args.c_th,
                                    greedy=True, attn_chunk=32)
        # requests enter the planner (device -> server hop)
        for i in range(B):
            planner.add(VerifyRequest(
                device_id=i, arrival=time.time() - t0, prev_token=int(prev[i]),
                draft_tokens=np.asarray(dres.tokens[i, : int(dres.lengths[i])]),
                request_id=rounds * B + i))
        batch = planner.next_batch(time.time() - t0, server_idle=True)
        assert batch is not None
        prev_np, toks, _, lens = batch.padded_arrays()
        vb = verification.make_verify_batch(
            jnp.asarray(prev_np), jnp.asarray(toks), jnp.asarray(lens), seed=rounds)
        res, t_cache = verify(tp, t_cache, vb)
        d_cache = drafting.resume_after_verify(draft, dres, res.n_accepted)
        prev = res.extra_token
        committed += np.asarray(res.n_commit)
        rounds += 1
        print(f"round {rounds:3d}: batch {batch.size} "
              f"acc {np.asarray(res.n_accepted).tolist()} committed {committed.tolist()}")
    dt = time.time() - t0
    print(f"served {committed.sum()} tokens across {B} devices in {rounds} rounds "
          f"({committed.sum()/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
