"""repro.telemetry — fleet-wide observability for the SLED serving stack.

Three pieces, all dependency-free:

* a process-local :class:`~repro.telemetry.metrics.MetricsRegistry`
  (counters / gauges / fixed-bucket histograms) with Prometheus-style text
  exposition and a JSON snapshot, fed by cheap host-side monotonic spans;
* per-round :class:`~repro.telemetry.trace.TraceEvent` records propagated
  across process boundaries (Verdict frames carry the server-timing
  breakdown; codec v3 ``ReplicaStats`` carries a telemetry payload), plus a
  bounded :class:`~repro.telemetry.trace.FlightRecorder` ring dumped on
  replica crash/eviction/drain;
* surfacing: ``repro top`` (live fleet table over the control plane),
  ``repro trace`` (per-round JSONL), and the span breakdowns in BENCH
  artifacts.

Telemetry is OFF by default — :func:`enable` is flipped by ``System.build``
when the ServeSpec says so, and instrumented call sites cost one flag check
per round while disabled.  Spans wrap host-side boundaries only; nothing
here runs inside jitted code.
"""

from repro.telemetry.logs import setup_logging
from repro.telemetry.metrics import (
    C_TH_BUCKETS,
    K_BUCKETS,
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    count,
    enable,
    enabled,
    observe,
    registry,
    span,
)
from repro.telemetry.trace import FlightRecorder, TraceEvent

__all__ = [
    "C_TH_BUCKETS",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "K_BUCKETS",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "TraceEvent",
    "count",
    "enable",
    "enabled",
    "observe",
    "registry",
    "setup_logging",
    "span",
]
