"""Synchronous single-host SLED reference loop (draft + verify, real models).

This is the algorithmic ground truth used by tests, examples, and the Fig. 3
confidence benchmark: a draft model and a target model running the full
SLED drafting/verification protocol in lock-step.  System-scale timing
behaviour (Poisson arrivals, RTT, async draft-ahead, batching across
devices) lives in serving/simulator.py; THIS loop is about token-level
correctness — e.g. greedy SLED output must equal greedy target-only output.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drafting, verification
from repro.core.speculative import PAD_TOKEN


@dataclasses.dataclass
class SledStats:
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    committed: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.committed / max(self.rounds, 1)


def make_sled_steps(
    draft_model, target_model, *,
    k_max: int = 4, c_th: float = 0.0, greedy: bool = True,
    temperature: float = 1.0, attn_chunk: int = 256,
) -> dict:
    """The lock-step loop's jitted bundle (prefill both models, draft,
    verify).  Build once and pass to :func:`sled_rounds`/:func:`sled_generate`
    so repeated loops (e.g. the repro.api reference backend's sessions) share
    compiled executables."""
    return {
        "d_prefill": jax.jit(
            verification.make_prefill_step(draft_model, attn_chunk=attn_chunk)
        ),
        "t_prefill": jax.jit(
            verification.make_prefill_step(target_model, attn_chunk=attn_chunk)
        ),
        "verify": jax.jit(verification.make_verify_step(
            target_model, greedy=greedy, temperature=temperature, attn_chunk=attn_chunk
        )),
        "draft": jax.jit(
            lambda params, cache, prev, key: drafting.draft_round(
                draft_model, params, cache, prev, key,
                k_max=k_max, c_th=c_th, temperature=temperature, greedy=greedy,
                keep_q_full=not greedy, attn_chunk=attn_chunk,
            )
        ),
    }


@dataclasses.dataclass
class SledRound:
    """One lock-step round's per-row outcome (materialized numpy)."""

    tokens: np.ndarray  # (B, k_max+1) committed candidates per row
    n_commit: np.ndarray  # (B,) tokens actually committed this round
    lengths: np.ndarray  # (B,) draft tokens proposed
    n_accepted: np.ndarray  # (B,) draft tokens accepted
    confidence: Optional[np.ndarray] = None  # (B, k_max) when collected
    accepted_mask: Optional[np.ndarray] = None  # (B, k_max) when collected


def sled_rounds(
    draft_model, draft_params,
    target_model, target_params,
    prompts: jax.Array,  # (B, P) int32
    *,
    max_new: int,
    k_max: int = 4,
    c_th: float = 0.0,
    greedy: bool = True,
    temperature: float = 1.0,
    seed: int = 0,
    attn_chunk: int = 256,
    collect_confidence: bool = False,
    steps: Optional[dict] = None,
    kv_dtype: str = "bf16",
):
    """THE lock-step SLED loop, as a per-round generator.

    Yields a :class:`SledRound` per draft+verify round until every row has
    committed ``max_new`` tokens.  :func:`sled_generate` and the repro.api
    reference backend are both thin consumers of this generator — there is
    exactly one copy of the ground-truth loop (seeding, q plumbing, rollback)
    to keep bit-identical.
    """
    B, P = prompts.shape
    max_len = P + max_new + k_max + 8
    steps = steps or make_sled_steps(
        draft_model, target_model, k_max=k_max, c_th=c_th, greedy=greedy,
        temperature=temperature, attn_chunk=attn_chunk,
    )
    # the TARGET cache honours kv_dtype (it is the server-pool stand-in the
    # engine backends must match token-for-token); device-side draft caches
    # are always bf16 — SLED quantizes the shared server pool, not the edge
    t_kw = {}
    if kv_dtype == "int8":
        t_kw["kv_dtype"] = jnp.int8
    elif kv_dtype != "bf16":
        raise ValueError(f"unknown kv_dtype {kv_dtype!r} (one of ['bf16', 'int8'])")
    d_cache = draft_model.make_cache(B, max_len, attn_chunk=attn_chunk)
    t_cache = target_model.make_cache(B, max_len, attn_chunk=attn_chunk, **t_kw)
    _, d_cache, prev = steps["d_prefill"](draft_params, d_cache, prompts)
    _, t_cache, _ = steps["t_prefill"](target_params, t_cache, prompts)

    key = jax.random.key(seed)
    counts = np.zeros((B,), np.int64)
    rounds = 0
    while counts.min() < max_new:
        key, k_d = jax.random.split(key)
        dres = steps["draft"](draft_params, d_cache, prev, k_d)
        batch = verification.make_verify_batch(
            prev, dres.tokens, dres.lengths, draft_q=None if greedy else dres.q_sel,
            seed=np.uint32(rounds + seed),
        )
        if not greedy and dres.q_full is not None:
            batch["draft_q_full"] = dres.q_full
        res, t_cache = steps["verify"](target_params, t_cache, batch)

        d_cache = drafting.resume_after_verify(draft_model, dres, res.n_accepted)
        prev = res.extra_token
        n_commit = np.asarray(res.n_commit)
        counts += n_commit
        rounds += 1
        yield SledRound(
            tokens=np.asarray(res.out_tokens),
            n_commit=n_commit,
            lengths=np.asarray(dres.lengths),
            n_accepted=np.asarray(res.n_accepted),
            confidence=np.asarray(dres.confidence) if collect_confidence else None,
            accepted_mask=np.asarray(res.accepted_mask) if collect_confidence else None,
        )


def sled_generate(
    draft_model, draft_params,
    target_model, target_params,
    prompts: jax.Array,  # (B, P) int32
    *,
    max_new: int,
    k_max: int = 4,
    c_th: float = 0.0,
    greedy: bool = True,
    temperature: float = 1.0,
    seed: int = 0,
    attn_chunk: int = 256,
    collect_confidence: bool = False,
    steps: Optional[dict] = None,
    kv_dtype: str = "bf16",
) -> Tuple[np.ndarray, SledStats, Optional[List[Tuple[float, bool]]]]:
    """Run SLED end-to-end. Returns (tokens (B, max_new), stats, conf_pairs).

    conf_pairs (when collect_confidence): list of (draft confidence,
    accepted?) per drafted token — the raw data behind paper Fig. 3.
    """
    B = prompts.shape[0]
    # rows commit at different rates; a fast row may overshoot max_new by
    # (k_max+1) per round until the slowest row finishes
    out = np.full((B, max_new + 16 * (k_max + 1)), PAD_TOKEN, np.int64)
    counts = np.zeros((B,), np.int64)
    stats = SledStats()
    conf_pairs: List[Tuple[float, bool]] = [] if collect_confidence else None

    for rnd in sled_rounds(
        draft_model, draft_params, target_model, target_params, prompts,
        max_new=max_new, k_max=k_max, c_th=c_th, greedy=greedy,
        temperature=temperature, seed=seed, attn_chunk=attn_chunk,
        collect_confidence=collect_confidence, steps=steps, kv_dtype=kv_dtype,
    ):
        if collect_confidence:
            for b in range(B):
                for i in range(int(rnd.lengths[b])):
                    conf_pairs.append(
                        (float(rnd.confidence[b, i]), bool(rnd.accepted_mask[b, i]))
                    )
        for b in range(B):
            n = min(int(rnd.n_commit[b]), out.shape[1] - int(counts[b]))
            out[b, counts[b] : counts[b] + n] = rnd.tokens[b, :n]
            counts[b] += n
        stats.rounds += 1
        stats.drafted += int(rnd.lengths.sum())
        stats.accepted += int(rnd.n_accepted.sum())
        stats.committed += int(rnd.n_commit.sum())

    return out[:, :max_new], stats, conf_pairs


def autoregressive_generate(
    model, params, prompts: jax.Array, *, max_new: int, greedy: bool = True,
    temperature: float = 1.0, seed: int = 0, attn_chunk: int = 256,
) -> np.ndarray:
    """Plain target-only decoding — the centralized-serving baseline."""
    B, P = prompts.shape
    cache = model.make_cache(B, P + max_new + 8, attn_chunk=attn_chunk)
    prefill = jax.jit(verification.make_prefill_step(model, attn_chunk=attn_chunk))
    _, cache, prev = prefill(params, cache, prompts)

    @jax.jit
    def step(params, cache, prev, key):
        h, ck, _ = model.decode_forward(params, cache, prev[:, None],
                                        attn_chunk=attn_chunk)
        cache = model.commit(ck, jnp.ones((B,), jnp.int32))
        logits = model.lm_head(params, h)[:, 0]
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(key, logits / temperature).astype(jnp.int32)
        return cache, nxt

    key = jax.random.key(seed)
    out = np.zeros((B, max_new), np.int64)
    for t in range(max_new):
        key, ks = jax.random.split(key)
        cache, prev = step(params, cache, prev, ks)
        out[:, t] = np.asarray(prev)
    return out
