"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt


def flatten_row(r: dict, *, skip: tuple = ("spec",)) -> Dict[str, object]:
    """Dotted-prefix flattening for the CSV path: nested dicts become
    ``parent.key`` columns and lists of dicts become ``parent.N.key``
    columns, so per-class / per-device fleet stats survive into the
    ``derived`` field instead of being dropped.  Keys in ``skip`` (full
    ServeSpec dumps) stay JSON-only — a flattened spec would drown the
    CSV line."""
    flat: Dict[str, object] = {}

    def put(prefix: str, v) -> None:
        if isinstance(v, dict):
            for k, sub in v.items():
                put(f"{prefix}.{k}" if prefix else str(k), sub)
        elif isinstance(v, (list, tuple)) and any(isinstance(x, dict) for x in v):
            for i, sub in enumerate(v):
                put(f"{prefix}.{i}", sub)
        else:
            flat[prefix] = v

    for k, v in r.items():
        if k in skip:
            continue
        put(str(k), v)
    return flat


def emit(rows: List[dict], name: str) -> None:
    """Benchmark output contract: ``name,us_per_call,derived`` CSV rows.

    Nested records are flattened into dotted-prefix columns (see
    :func:`flatten_row`); only ``spec`` sub-dicts (the uniform ``to_json``
    surface) stay in the JSON artifact alone."""
    for r in rows:
        r = flatten_row(r)
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
