"""``repro`` console entry point.

    repro serve --spec spec.json [--check]     run a ServeSpec artifact
    repro serve --devices 4 --dump-spec        resolve flags into a spec
    repro serve --transport sim --net wlan     legacy-flag serving
    repro worker --listen tcp:0.0.0.0:7001     run one replica worker process

Subcommands are lazy-imported so ``repro --help`` stays instant (no jax
import until a command actually runs).
"""

from __future__ import annotations

import sys
from typing import List, Optional

_USAGE = """\
usage: repro <command> [args...]

commands:
  serve    serve a SLED deployment from a ServeSpec (see: repro serve --help)
  worker   run one engine replica behind a TCP/UDS control socket, to be
           placed and driven by a cluster Router (see: repro worker --help)

Run configurations are declarative ServeSpec JSON artifacts; `repro serve
--dump-spec` converts any flag combination into one.
"""


def main(argv: Optional[List[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE, end="")
        return
    cmd, rest = argv[0], argv[1:]
    if cmd == "serve":
        from repro.launch.serve import main as serve_main

        serve_main(rest)
        return
    if cmd == "worker":
        from repro.transport.worker import main as worker_main

        worker_main(rest)
        return
    print(_USAGE, end="", file=sys.stderr)
    raise SystemExit(f"repro: unknown command {cmd!r}")


if __name__ == "__main__":
    main()
