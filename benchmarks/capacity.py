"""Paper Table I: system capacity, SLED vs centralized, per device type.

Capacity = number of edge devices the system supports at the same response
rate.  The paper reports x2.60 (RPi 4B), x2.86 (RPi 5), x2.77 (Jetson) —
our validation target is ratios in that x2-3 band.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.serving.devices import A100_X4, DEVICES
from repro.serving.simulator import SimConfig, capacity


def run(quick: bool = False) -> list:
    rows = []
    sim_time = 20.0 if quick else 45.0
    for dev_name in ("rpi4b", "rpi5", "jetson-orin-nano"):
        dev = DEVICES[dev_name]
        base = SimConfig(
            mode="sled", spec_len=4, acceptance=0.90,
            device_rate=dev.rate("llama-1b-draft", 4),
            target_params=11e9, server_batch=16, batch_policy="deadline",
            sim_time=sim_time,
        )
        cap_sled = capacity(base, A100_X4, n_max=2048)
        cap_cent = capacity(dataclasses.replace(base, mode="centralized"),
                            A100_X4, n_max=2048)
        rows.append({
            "device": dev_name,
            "cap_sled": cap_sled,
            "cap_centralized": cap_cent,
            "improvement": round(cap_sled / max(cap_cent, 1), 2),
            "paper_claim": {"rpi4b": 2.60, "rpi5": 2.86, "jetson-orin-nano": 2.77}[dev_name],
        })
    emit(rows, "table1_capacity")
    return rows


if __name__ == "__main__":
    run()
