"""Discrete-event simulator of the SLED service area (paper §IV methodology).

The paper evaluates system-scale behaviour by modelling each edge device as
an independent Poisson source of verification requests whose rate derives
from measured device drafting throughput; we implement exactly that, plus
the full device state machine from §III-A:

  draft (k tokens at device rate) -> send (RTT/2) -> server queue ->
  batched verification (BatchPlanner + server latency model) ->
  reply (RTT/2) -> commit m+1 tokens, roll back, draft again

with the paper's async decoding (devices draft ahead while a request is in
flight; on full acceptance the draft-ahead tokens seed the next round) and
the timeout protocol (fallback release of local drafts after
``verify_timeout``; the device resyncs on the next round).

Three system modes share the loop:
  sled         — the above
  centralized  — devices send one-token generation requests; the server
                 decodes autoregressively in batches (no local drafting)
  all_edge     — devices decode locally, never contact the server
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import List, Optional, Tuple

from repro.core.scheduler import BatchPlanner, VerifyRequest
from repro.serving.devices import ServerProfile


@dataclasses.dataclass(frozen=True)
class ClassLoad:
    """One heterogeneous device class in the service area: ``count`` devices
    sharing a drafting rate, spec length, acceptance and (optionally) their
    own network RTT.  ``SimConfig.classes`` holds one per fleet class —
    the calibrated counterpart of a ServeSpec's resolved fleet."""

    count: int = 1
    device_rate: float = 8.0        # draft tokens/s (devices.py profile)
    spec_len: int = 4               # the class's k
    acceptance: float = 0.75        # per-token alpha for this draft config
    rtt_mean: float = -1.0          # seconds; -1 inherits SimConfig.rtt_mean


@dataclasses.dataclass
class SimConfig:
    mode: str = "sled"              # sled | centralized | all_edge
    n_devices: int = 8
    spec_len: int = 4               # K (fixed-length drafting)
    dynamic: bool = False           # dynamic drafting: geometric draft lengths
    c_th_mean_len: float = 4.0      #   mean dynamic draft length at c_th
    acceptance: float = 0.75        # per-token acceptance probability alpha
    device_rate: float = 8.0        # draft tokens/s (devices.py profile)
    draft_model_params: float = 1.2e9
    target_params: float = 11e9
    server_batch: int = 8
    batch_policy: str = "static"    # static | deadline | continuous
    max_wait: float = 0.05
    rtt_mean: float = 0.020         # network round-trip, seconds
    rtt_jitter: float = 0.005
    verify_timeout: float = 0.8     # paper §III-A timeout protocol
    drop_prob: float = 0.0          # network loss -> exercises the timeout
    draft_ahead: int = 4            # async decoding depth
    sim_time: float = 120.0
    seed: int = 0
    bits: int = 16
    cache_tokens: int = 1024        # context depth for kv-read cost
    server_latency_scale: float = 1.0
    # heterogeneous fleet: when non-empty, overrides n_devices / device_rate /
    # spec_len / acceptance with per-class values (devices get contiguous ids
    # class by class, matching ServeSpec.resolved_classes ranges)
    classes: Tuple[ClassLoad, ...] = ()
    # deadline SLO: rounds slower than this (and timeout rounds) count as
    # misses in SimResult.deadline_miss_rate; 0 disables the accounting
    deadline_s: float = 0.0


@dataclasses.dataclass
class SimResult:
    wstgr: float                 # whole-system token generation rate (tok/s)
    per_device_rate: float       # committed tokens/s per device
    server_busy_frac: float
    rounds: int
    timeouts: int
    fallback_tokens: int
    mean_batch_fill: float
    mean_round_latency: float
    server_rounds_per_s: float
    deadline_miss_rate: float = 0.0  # rounds over SimConfig.deadline_s
    # committed tokens/s PER DEVICE by fleet class (SimConfig.classes order);
    # empty for uniform configs — the per-class response-rate surface that
    # capacity admission (tuning/search.py) holds a goodput floor against
    class_device_rates: Tuple[float, ...] = ()

    def as_dict(self):
        return dataclasses.asdict(self)


def _device_cfgs(cfg: SimConfig) -> List[SimConfig]:
    """Per-device views of the config: uniform without classes, else each
    class's overrides applied (the returned list's length IS the fleet
    size — ``n_devices`` is derived, not read, under a fleet)."""
    if not cfg.classes:
        return [cfg] * cfg.n_devices
    out: List[SimConfig] = []
    for cl in cfg.classes:
        dcfg = dataclasses.replace(
            cfg,
            device_rate=cl.device_rate,
            spec_len=cl.spec_len,
            acceptance=cl.acceptance,
            rtt_mean=cl.rtt_mean if cl.rtt_mean >= 0 else cfg.rtt_mean,
            classes=(),
        )
        out.extend([dcfg] * cl.count)
    return out


class _Device:
    def __init__(self, i: int, cfg: SimConfig, rng: random.Random):
        self.i = i
        self.cfg = cfg
        self.rng = rng
        self.committed = 0
        self.inflight: Optional[int] = None  # request id awaiting verdict
        self.sent_at = 0.0
        self.ahead = 0  # draft-ahead tokens banked while waiting
        self.timeouts = 0
        self.fallback = 0
        self.round_latencies: List[float] = []

    def draft_len(self) -> int:
        cfg = self.cfg
        if not cfg.dynamic:
            return cfg.spec_len
        # dynamic drafting: confidence-thresholded lengths are geometric-ish
        p = 1.0 / max(cfg.c_th_mean_len, 1.01)
        k = 1
        while k < cfg.spec_len * 4 and self.rng.random() > p:
            k += 1
        return k


def _accepted(k: int, alpha: float, rng: random.Random) -> int:
    m = 0
    while m < k and rng.random() < alpha:
        m += 1
    return m


def simulate(cfg: SimConfig, server: ServerProfile) -> SimResult:
    rng = random.Random(cfg.seed)
    dcfgs = _device_cfgs(cfg)
    n_devices = len(dcfgs)
    devices = [_Device(i, dcfgs[i], random.Random(cfg.seed * 977 + i)) for i in range(n_devices)]

    if cfg.mode == "all_edge":
        # no server: closed-form — devices decode locally
        total = sum(c.device_rate for c in dcfgs)
        return SimResult(
            wstgr=total, per_device_rate=total / max(n_devices, 1),
            server_busy_frac=0.0, rounds=0, timeouts=0, fallback_tokens=0,
            mean_batch_fill=0.0,
            mean_round_latency=1.0 / max(total / max(n_devices, 1), 1e-9),
            server_rounds_per_s=0.0,
        )

    # static batching can only ever fill up to n_devices (closed loop): cap
    # so an oversized fixed batch doesn't deadlock waiting for itself
    eff_batch = min(cfg.server_batch, n_devices)
    k_top = max(c.spec_len for c in dcfgs)
    planner = BatchPlanner(
        batch_size=eff_batch, k_max=k_top * 4,
        policy=cfg.batch_policy, max_wait=cfg.max_wait,
        straggler_timeout=cfg.verify_timeout,
    )
    # event heap: (time, seq, kind, payload)
    evq: List = []
    seq = 0

    def push(t: float, kind: str, payload) -> None:
        nonlocal seq
        heapq.heappush(evq, (t, seq, kind, payload))
        seq += 1

    def rtt_half(c: SimConfig = cfg) -> float:
        return max(0.001, c.rtt_mean / 2 + rng.gauss(0.0, cfg.rtt_jitter / 2))

    # verify width is padded to the widest class's k (matching the engine's
    # k_max-padded batches), so server cost is set by the fleet's max k
    k1 = k_top + 1
    verify_lat = lambda b: cfg.server_latency_scale * server.verify_latency(
        cfg.target_params, b, k1, cache_tokens=cfg.cache_tokens, bits=cfg.bits
    )
    decode_lat = lambda b: cfg.server_latency_scale * server.decode_latency(
        cfg.target_params, b, cache_tokens=cfg.cache_tokens, bits=cfg.bits
    )

    server_busy_until = 0.0
    server_busy_time = 0.0
    server_rounds = 0
    batch_fills: List[int] = []
    rounds = 0
    reqid = 0
    next_tick_at = float("inf")  # throttle: at most one pending planner tick

    # warm start: every device begins a drafting round at a random phase
    for d in devices:
        if cfg.mode == "sled":
            k = d.draft_len()
            push(rng.random() * 0.05 + k / d.cfg.device_rate, "draft_done", (d.i, k))
        else:  # centralized: device immediately requests its next token
            push(rng.random() * 0.01, "request", (d.i, 1))

    def maybe_dispatch(now: float) -> None:
        nonlocal server_busy_until, server_busy_time, server_rounds
        if now < server_busy_until:
            return
        batch = planner.next_batch(now, server_idle=True)
        if batch is None:
            return
        b = batch.size
        lat = verify_lat(b) if cfg.mode == "sled" else decode_lat(b)
        server_busy_until = now + lat
        server_busy_time += lat
        server_rounds += 1
        batch_fills.append(b)
        push(now + lat, "batch_done", batch)

    T = cfg.sim_time
    now = 0.0
    while evq:
        now, _, kind, payload = heapq.heappop(evq)
        if now > T:
            break
        if kind == "draft_done":
            i, k = payload
            d = devices[i]
            if d.inflight is not None:
                continue  # stale event from a superseded round
            if rng.random() < cfg.drop_prob:
                # request lost: timeout will fire
                d.inflight = reqid
                d.sent_at = now
                push(now + cfg.verify_timeout, "timeout", (i, reqid, k))
            else:
                req = VerifyRequest(device_id=i, arrival=now + rtt_half(d.cfg),
                                    prev_token=0, draft_tokens=[0] * k,
                                    request_id=reqid)
                d.inflight = reqid
                d.sent_at = now
                push(req.arrival, "arrive", req)
                push(now + cfg.verify_timeout, "timeout", (i, reqid, k))
            reqid += 1
        elif kind == "request":  # centralized mode
            i, _ = payload
            req = VerifyRequest(device_id=i, arrival=now + rtt_half(devices[i].cfg),
                                prev_token=0, draft_tokens=[0], request_id=reqid)
            devices[i].inflight = reqid
            devices[i].sent_at = now
            push(req.arrival, "arrive", req)
            reqid += 1
        elif kind == "arrive":
            planner.add(payload)
            maybe_dispatch(now)
        elif kind == "batch_done":
            for req in payload.requests:
                d = devices[req.device_id]
                if d.inflight != req.request_id:
                    continue  # superseded by a timeout fallback
                d.inflight = None
                d.round_latencies.append(now - d.sent_at)
                if cfg.mode == "sled":
                    k = len(req.draft_tokens)
                    m = _accepted(k, d.cfg.acceptance, d.rng)
                    d.committed += m + 1
                    # §III-A async decoding: the device kept drafting during
                    # the round trip; on full acceptance those tokens seed
                    # the next round (on rejection they are discarded)
                    wait = max(now - d.sent_at, 0.0)
                    carry = 0
                    if m == k:
                        carry = min(int(wait * d.cfg.device_rate), cfg.draft_ahead)
                    nk = d.draft_len()
                    need = max(nk - carry, 0)
                    push(now + rtt_half(d.cfg) + need / d.cfg.device_rate,
                         "draft_done", (req.device_id, nk))
                else:
                    d.committed += 1
                    push(now + rtt_half(d.cfg), "request", (req.device_id, 1))
            maybe_dispatch(now)
        elif kind == "timeout":
            i, rid, k = payload
            d = devices[i]
            if d.inflight == rid:
                # paper §III-A: release local drafts, resync next round
                d.inflight = None
                d.timeouts += 1
                d.fallback += k
                d.committed += k
                nk = d.draft_len()
                push(now + nk / d.cfg.device_rate, "draft_done", (i, nk))
        if kind == "tick":
            next_tick_at = float("inf")
            maybe_dispatch(now)
        # deadline-policy batches may become ready without a new arrival;
        # keep at most ONE pending tick (unthrottled ticks are O(events^2))
        hint = planner.next_event_hint(now)
        if hint is not None and hint <= T and hint + 1e-6 < next_tick_at:
            next_tick_at = hint + 1e-6
            push(next_tick_at, "tick", None)

    total = sum(d.committed for d in devices)
    lat = [x for d in devices for x in d.round_latencies]
    timeouts = sum(d.timeouts for d in devices)
    miss_rate = 0.0
    if cfg.deadline_s > 0:
        # timeout rounds never produced a verdict in time: always misses
        misses = sum(1 for x in lat if x > cfg.deadline_s) + timeouts
        miss_rate = misses / max(len(lat) + timeouts, 1)
    class_rates: List[float] = []
    if cfg.classes and now > 0:
        # devices hold contiguous ids class by class (same layout as
        # ServeSpec.resolved_classes), so slice by the class counts
        lo = 0
        for cl in cfg.classes:
            rows = devices[lo:lo + cl.count]
            class_rates.append(
                sum(d.committed for d in rows) / max(cl.count, 1) / now
            )
            lo += cl.count
    return SimResult(
        wstgr=total / now if now > 0 else 0.0,
        per_device_rate=total / max(n_devices, 1) / now if now > 0 else 0.0,
        server_busy_frac=server_busy_time / now if now > 0 else 0.0,
        rounds=sum(len(d.round_latencies) for d in devices),
        timeouts=timeouts,
        fallback_tokens=sum(d.fallback for d in devices),
        mean_batch_fill=sum(batch_fills) / max(len(batch_fills), 1),
        mean_round_latency=sum(lat) / max(len(lat), 1),
        server_rounds_per_s=server_rounds / now if now > 0 else 0.0,
        deadline_miss_rate=miss_rate,
        class_device_rates=tuple(class_rates),
    )


def capacity(cfg: SimConfig, server: ServerProfile, *, min_rate_frac: float = 0.8,
             n_max: int = 512, probe_time: float = 8.0) -> int:
    """Max devices sustaining >= min_rate_frac of their solo token rate
    (Table I's 'system capacity' at an equal response-rate requirement)."""
    if cfg.classes:
        # n_devices is derived under a fleet, so the n-sweep below would
        # silently probe the same load at every n — refuse loudly
        raise ValueError(
            "capacity() sweeps n_devices, which a fleet config overrides; "
            "scale ClassLoad.count per class (tuning/search.py does) instead"
        )
    cfg = dataclasses.replace(cfg, sim_time=min(cfg.sim_time, probe_time))
    solo = simulate(dataclasses.replace(cfg, n_devices=1), server).per_device_rate
    if solo <= 0:
        return 0

    def ok(n: int) -> bool:
        r = simulate(dataclasses.replace(cfg, n_devices=n), server)
        return r.per_device_rate >= min_rate_frac * solo

    if ok(n_max):  # saturates the probe range: skip the search
        return n_max
    lo, hi = 1, n_max
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if ok(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo
