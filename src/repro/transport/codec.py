"""SLED wire protocol: versioned, length-prefixed binary frames.

Every frame is ``header || payload`` with an 8-byte header::

    magic "SL" (2) | version u8 | msg_type u8 | payload_len u32 (big-endian)

so frames survive byte-stream transports (TCP-style reassembly via
``FrameDecoder``) as well as message-oriented links.  All multi-byte integers
are big-endian; token vectors are little-endian int32 arrays (numpy
``tobytes`` of the natural serving dtype) behind a u16 count.

The draft-probability payload of a ``DraftPacket`` (the q(token) row needed
for lossless sampling-mode verification) dominates frame size at fp32, so it
can ride the wire quantized — ``qmode``:

    "none"  no q payload (greedy verification)
    "f32"   4 bytes/token, exact
    "f16"   2 bytes/token
    "int8"  1 byte/token + one fp32 scale (reuses quant/quantize.py's
            symmetric per-row scheme)

Quantization is an honest wire cost/fidelity trade the benchmarks measure;
decode returns fp32 either way.

v3 adds the cluster CONTROL PLANE: the frames a Router speaks to a remote
replica worker (transport/worker.py) over one TCP/UDS control connection —
``PlaceReplica`` ships a serialized ServeSpec subtree and the worker builds
its engine from it; ``AdmitRequest``/``SubmitRequest``/``StepRequest``
proxy the in-process replica driver surface (every ``now`` is the Router's
clock, so cross-process scheduling is deterministic); ``ExportStream``/
``ImportStream`` carry a stream's full server-side state plus a bit-exact
serialization of its KV pool row (bfloat16 rides the wire as raw uint16
words — no float round-trip); ``ReplicaStats`` returns the uniform
EngineStats record; ``Drain`` retires the worker.  Control payloads can
carry whole KV rows, so the payload cap is far above the v2 data-plane one.

v4 HARDENS the control plane for fault tolerance: every side-effectful
request (admit/submit/step/retire/cancel/force-extend/export/import) now
carries a per-channel ``seq`` id, and the worker keeps a bounded replay
cache keyed by (msg type, device, seq) — a retried frame after a reconnect
returns the ORIGINAL reply instead of double-applying the side effect, so a
one-shot retry over a flapped link is safe.  ``Ping``/``Pong`` add a
lightweight heartbeat (echoed seq + sender timestamp) so a partitioned or
hung peer is detected in seconds rather than at the 120 s RPC timeout.
``seq=0`` means "no replay protection" (v3-style fire-once semantics).
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Iterator, List, Optional, Union

import numpy as np

from repro.quant.quantize import QTensor, dequantize, quantize

MAGIC = b"SL"
VERSION = 4  # v4: per-RPC seq ids (replay-safe retries) + Ping/Pong heartbeat
_HEADER = struct.Struct(">2sBBI")
HEADER_SIZE = _HEADER.size
# v3 control frames carry serialized KV rows (ExportStream/ImportStream), so
# the cap must hold a full pool row, not just a draft packet
MAX_PAYLOAD = 1 << 26

# message type ids (wire-stable: append only)
T_HELLO = 1
T_ADMIT = 2
T_DRAFT = 3
T_VERDICT = 4
T_FALLBACK = 5
T_FALLBACK_ACK = 6
T_CLOSE = 7
# v3 control plane (Router <-> remote replica worker)
T_PLACE = 8
T_PLACE_ACK = 9
T_ADMIT_REQ = 10
T_ADMIT_REPLY = 11
T_SUBMIT = 12
T_SUBMIT_ACK = 13
T_STEP = 14
T_STEP_REPLY = 15
T_RETIRE = 16
T_RETIRE_REPLY = 17
T_CANCEL = 18
T_CANCEL_REPLY = 19
T_FORCE_EXTEND = 20
T_FORCE_EXTEND_REPLY = 21
T_EXPORT = 22
T_EXPORT_REPLY = 23
T_IMPORT = 24
T_IMPORT_ACK = 25
T_STATS = 26
T_REPLICA_STATS = 27
T_WARMUP = 28
T_WARMUP_REPLY = 29
T_DRAIN = 30
T_DRAIN_ACK = 31
T_ERROR = 32
# v4 heartbeat
T_PING = 33
T_PONG = 34

QMODES = ("none", "f32", "f16", "int8")


class CodecError(ValueError):
    """Malformed, truncated, or version-incompatible frame."""


@dataclasses.dataclass(frozen=True)
class Hello:
    """Device -> server admission request; prompt is prefilled server-side."""

    device_id: int
    prompt: np.ndarray  # (P,) int32


@dataclasses.dataclass(frozen=True)
class Admit:
    """Server -> device admission verdict (ok=False: pool full, wait)."""

    device_id: int
    ok: bool
    slot: int = 0


@dataclasses.dataclass(frozen=True)
class DraftPacket:
    """Device -> server: one drafting round's proposal."""

    device_id: int
    seq: int
    tokens: np.ndarray  # (k,) int32
    draft_q: Optional[np.ndarray] = None  # (k,) fp32 (decoded), or None
    qmode: str = "none"


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Server -> device: verification outcome for DraftPacket ``seq``.

    ``accept_rate`` (this round's draft-acceptance ratio — per-round so the
    control loop reacts to regime shifts; smoothing is the receiver's job)
    and ``queue_depth`` (the serving replica's planner queue after dispatch)
    are the v2 closed-loop feedback fields: devices feed them to an AIMD
    spec-length controller (serving/speclen.py) to tune ``k`` online.

    ``queue_s``/``verify_s`` are the server-timing breakdown (how long the
    round sat in the admission queue and how long its verify step took), so
    an edge client can attribute round latency to queue vs verify vs wire:
    wire time = measured RTT minus the two server spans.
    """

    device_id: int
    seq: int
    n_accepted: int
    tokens: np.ndarray  # committed this round (accepted + correction/bonus)
    next_prev: int
    flags: int = 0  # reserved for future protocol bits (always 0 in v2)
    accept_rate: float = 0.0  # this round's accepted/drafted, in [0, 1]
    queue_depth: int = 0  # replica queue depth after this round's dispatch
    queue_s: float = 0.0  # admission-queue wait for this round (server clock)
    verify_s: float = 0.0  # verify-step wall time for this round's batch


@dataclasses.dataclass(frozen=True)
class Fallback:
    """Device -> server: round ``seq`` timed out device-side; the device
    released ``tokens`` locally (§III-A) and asks the server to resync."""

    device_id: int
    seq: int
    tokens: np.ndarray  # (k,) int32 locally-released draft tokens


@dataclasses.dataclass(frozen=True)
class FallbackAck:
    """Server -> device: resync applied; draft from ``next_prev``."""

    device_id: int
    seq: int
    next_prev: int


@dataclasses.dataclass(frozen=True)
class Close:
    """Either side: stream ends; server frees the slot."""

    device_id: int


# -- v3 control plane (Router <-> remote replica worker) ---------------------


@dataclasses.dataclass(frozen=True)
class PlaceReplica:
    """Router -> worker: build your engine from this ServeSpec subtree
    (JSON; backend forced to "engine" with the per-replica slot count)."""

    spec_json: str


@dataclasses.dataclass(frozen=True)
class PlaceAck:
    """Worker -> router: engine built (or not); the fields echo the engine
    shape so the router can fingerprint replicas for migration safety."""

    ok: bool
    n_slots: int = 0
    k_max: int = 0
    max_len: int = 0
    greedy: bool = True
    paged_attention: bool = True
    error: str = ""


@dataclasses.dataclass(frozen=True)
class AdmitRequest:
    """Router -> worker: place a stream (prompt prefilled worker-side).
    ``now`` is the ROUTER's clock — the worker never consults its own.
    ``seq`` (v4, all side-effectful requests) keys the worker's replay
    cache: a retried frame with the same seq returns the original reply."""

    device_id: int
    prompt: np.ndarray  # (P,) int32
    now: float = 0.0
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class AdmitReply:
    device_id: int
    ok: bool
    slot: int = 0
    prev_token: int = 0


@dataclasses.dataclass(frozen=True)
class SubmitRequest:
    """Router -> worker: one drafting round's proposal for verification."""

    device_id: int
    tokens: np.ndarray  # (k,) int32
    now: float = 0.0
    draft_q: Optional[np.ndarray] = None
    qmode: str = "none"
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class SubmitAck:
    device_id: int


@dataclasses.dataclass(frozen=True)
class StepRequest:
    """Router -> worker: run one engine.step at the router's clock."""

    now: float
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class VerdictRec:
    """One verdict inside a StepReply (mirrors core.engine.Verdict)."""

    device_id: int
    n_accepted: int
    tokens: np.ndarray  # (n,) int32 committed this round
    next_prev: int
    accept_rate: float = 0.0
    queue_depth: int = 0
    queue_s: float = 0.0  # server-timing breakdown (see Verdict)
    verify_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class StepReply:
    """Worker -> router: the round's verdicts plus the replica's load
    signals (queue depth, free slots, next planner event hint)."""

    verdicts: tuple  # tuple[VerdictRec, ...]
    queue_depth: int = 0
    n_free: int = 0
    hint: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RetireRequest:
    device_id: int
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class CancelRequest:
    device_id: int
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class CancelReply:
    device_id: int
    ok: bool


@dataclasses.dataclass(frozen=True)
class ForceExtendRequest:
    """Router -> worker: append unverified fallback tokens (§III-A)."""

    device_id: int
    tokens: np.ndarray  # (n,) int32
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class ForceExtendReply:
    device_id: int
    next_prev: int


@dataclasses.dataclass(frozen=True)
class StreamState:
    """Full server-side state of one stream (RetireReply / migration).

    ``committed`` is the stream's lifetime committed-token list; ``row`` is
    the bit-exact serialized KV pool row (flat name->array dict; empty for
    replies that do not move the cache, e.g. retirement)."""

    device_id: int
    slot: int
    prev_token: int
    committed: tuple  # tuple[int, ...]
    admitted_at: float = 0.0
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    row: dict = dataclasses.field(default_factory=dict)  # name -> np.ndarray

    # np arrays in a frozen dataclass: compare fields, not array truthiness
    def __eq__(self, other):
        if not isinstance(other, StreamState):
            return NotImplemented
        if (
            self.device_id, self.slot, self.prev_token, self.committed,
            self.admitted_at, self.rounds, self.drafted, self.accepted,
        ) != (
            other.device_id, other.slot, other.prev_token, other.committed,
            other.admitted_at, other.rounds, other.drafted, other.accepted,
        ):
            return False
        if sorted(self.row) != sorted(other.row):
            return False
        return all(
            self.row[k].dtype == other.row[k].dtype
            and self.row[k].shape == other.row[k].shape
            and bool(np.all(self.row[k] == other.row[k]))
            for k in self.row
        )


@dataclasses.dataclass(frozen=True)
class RetireReply:
    stream: StreamState


@dataclasses.dataclass(frozen=True)
class ExportStream:
    """Router -> worker: detach a quiescent stream for migration."""

    device_id: int
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class ExportReply:
    stream: StreamState  # row populated


@dataclasses.dataclass(frozen=True)
class ImportStream:
    """Router -> worker: adopt a stream exported elsewhere (row populated)."""

    stream: StreamState
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class ImportAck:
    device_id: int
    slot: int


@dataclasses.dataclass(frozen=True)
class StatsRequest:
    now: float = 0.0
    has_now: bool = False


@dataclasses.dataclass(frozen=True)
class ReplicaStats:
    """Worker -> router: the uniform EngineStats record as JSON, plus an
    optional telemetry payload (metrics snapshot + flight-recorder dump —
    see repro.telemetry) when the placed spec enabled telemetry."""

    stats_json: str
    telemetry_json: str = ""


@dataclasses.dataclass(frozen=True)
class WarmupRequest:
    pass


@dataclasses.dataclass(frozen=True)
class WarmupReply:
    compile_json: str = "{}"  # bucket -> seconds


@dataclasses.dataclass(frozen=True)
class Drain:
    """Router -> worker: retire everything and exit after the ack."""


@dataclasses.dataclass(frozen=True)
class DrainAck:
    streams_left: int = 0


@dataclasses.dataclass(frozen=True)
class ErrorReply:
    """Worker -> router: the request raised; message carries the detail."""

    message: str


@dataclasses.dataclass(frozen=True)
class Ping:
    """Heartbeat probe (v4).  ``t`` is the SENDER's monotonic timestamp,
    echoed back in the Pong so the sender computes RTT without clock sync.
    Side-effect free: never enters the replay cache, safe on any channel."""

    seq: int
    t: float = 0.0


@dataclasses.dataclass(frozen=True)
class Pong:
    """Heartbeat reply: echoes the Ping's seq and timestamp."""

    seq: int
    t: float = 0.0


Message = Union[
    Hello, Admit, DraftPacket, Verdict, Fallback, FallbackAck, Close,
    PlaceReplica, PlaceAck, AdmitRequest, AdmitReply, SubmitRequest,
    SubmitAck, StepRequest, StepReply, RetireRequest, RetireReply,
    CancelRequest, CancelReply, ForceExtendRequest, ForceExtendReply,
    ExportStream, ExportReply, ImportStream, ImportAck, StatsRequest,
    ReplicaStats, WarmupRequest, WarmupReply, Drain, DrainAck, ErrorReply,
    Ping, Pong,
]


# -- primitive encoders ------------------------------------------------------


def _put_tokens(out: List[bytes], toks: np.ndarray) -> None:
    toks = np.ascontiguousarray(np.asarray(toks, dtype="<i4"))
    if toks.ndim != 1:
        raise CodecError(f"token vector must be 1-D, got shape {toks.shape}")
    if toks.shape[0] > 0xFFFF:
        raise CodecError(f"token vector too long: {toks.shape[0]}")
    out.append(struct.pack(">H", toks.shape[0]))
    out.append(toks.tobytes())


def _put_str(out: List[bytes], s: str) -> None:
    b = s.encode("utf-8")
    out.append(struct.pack(">I", len(b)))
    out.append(b)


def _put_tokens32(out: List[bytes], toks) -> None:
    """Token vector behind a u32 count (lifetime committed lists can exceed
    the data-plane u16 cap)."""
    arr = np.ascontiguousarray(np.asarray(toks, dtype="<i4").reshape(-1))
    out.append(struct.pack(">I", arr.shape[0]))
    out.append(arr.tobytes())


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes  # jax dependency; the KV pool's default dtype

        return np.dtype(ml_dtypes.bfloat16)
    try:
        return np.dtype(name)
    except TypeError as e:
        raise CodecError(f"unknown array dtype {name!r}") from e


def _put_array(out: List[bytes], arr) -> None:
    """Bit-exact array serialization: dtype name, shape, little-endian raw
    bytes.  bfloat16 (the KV pool's serving dtype) has no numpy byte-order
    variants, so it rides as its raw uint16 words — no float conversion can
    perturb a migrated cache row."""
    a = np.ascontiguousarray(np.asarray(arr))
    name = a.dtype.name
    nb = name.encode("ascii")
    if len(nb) > 0xFF:
        raise CodecError(f"dtype name too long: {name!r}")
    if a.ndim > 0xFF:
        raise CodecError(f"array rank {a.ndim} too large")
    out.append(struct.pack(">B", len(nb)))
    out.append(nb)
    out.append(struct.pack(">B", a.ndim))
    if a.ndim:
        out.append(struct.pack(f">{a.ndim}I", *a.shape))
    if name == "bfloat16":
        raw = a.view(np.uint16).astype("<u2").tobytes()
    else:
        raw = a.astype(a.dtype.newbyteorder("<")).tobytes()
    out.append(struct.pack(">I", len(raw)))
    out.append(raw)


def _put_row(out: List[bytes], row: dict) -> None:
    """Flat name->array dict (a KV pool row from EngineCore.export_row)."""
    if len(row) > 0xFFFF:
        raise CodecError(f"row has too many leaves: {len(row)}")
    out.append(struct.pack(">H", len(row)))
    for name in sorted(row):
        nb = name.encode("utf-8")
        if len(nb) > 0xFFFF:
            raise CodecError(f"row leaf name too long: {name!r}")
        out.append(struct.pack(">H", len(nb)))
        out.append(nb)
        _put_array(out, row[name])


def _put_stream_state(out: List[bytes], s: StreamState) -> None:
    out.append(
        struct.pack(
            ">IIidIII",
            s.device_id,
            s.slot,
            s.prev_token,
            s.admitted_at,
            s.rounds,
            s.drafted,
            s.accepted,
        )
    )
    _put_tokens32(out, list(s.committed))
    _put_row(out, s.row)


class _Reader:
    """Bounds-checked cursor over a payload; raises CodecError on overrun."""

    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise CodecError(
                f"truncated payload: need {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}"
            )
        b = self.buf[self.pos : self.pos + n]
        self.pos += n
        return b

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack(">H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]

    def i32(self) -> int:
        return struct.unpack(">i", self.take(4))[0]

    def f32(self) -> float:
        return struct.unpack(">f", self.take(4))[0]

    def f64(self) -> float:
        return struct.unpack(">d", self.take(8))[0]

    def tokens(self) -> np.ndarray:
        n = self.u16()
        return np.frombuffer(self.take(4 * n), dtype="<i4").astype(np.int32)

    def tokens32(self) -> np.ndarray:
        n = self.u32()
        if 4 * n > len(self.buf) - self.pos:
            raise CodecError(f"token32 vector of {n} overruns the payload")
        return np.frombuffer(self.take(4 * n), dtype="<i4").astype(np.int32)

    def string(self) -> str:
        n = self.u32()
        try:
            return self.take(n).decode("utf-8")
        except UnicodeDecodeError as e:
            raise CodecError(f"malformed utf-8 string payload: {e}") from e

    def array(self) -> np.ndarray:
        name = self.take(self.u8()).decode("ascii")
        ndim = self.u8()
        shape = tuple(self.u32() for _ in range(ndim))
        nbytes = self.u32()
        raw = self.take(nbytes)
        dt = _np_dtype(name)
        n_items = 1
        for d in shape:
            n_items *= d
        if nbytes != n_items * dt.itemsize:
            raise CodecError(
                f"array payload of {nbytes} bytes does not match "
                f"{name}{list(shape)} ({n_items * dt.itemsize} expected)"
            )
        if name == "bfloat16":
            arr = np.frombuffer(raw, dtype="<u2").astype(np.uint16).view(dt)
        else:
            arr = np.frombuffer(raw, dtype=dt.newbyteorder("<")).astype(dt)
        return arr.reshape(shape)

    def row(self) -> dict:
        n = self.u16()
        row = {}
        for _ in range(n):
            name = self.take(self.u16()).decode("utf-8")
            row[name] = self.array()
        return row

    def stream_state(self) -> StreamState:
        dev, slot, prev = self.u32(), self.u32(), self.i32()
        admitted_at = self.f64()
        rounds, drafted, accepted = self.u32(), self.u32(), self.u32()
        committed = tuple(int(t) for t in self.tokens32())
        return StreamState(
            device_id=dev,
            slot=slot,
            prev_token=prev,
            committed=committed,
            admitted_at=admitted_at,
            rounds=rounds,
            drafted=drafted,
            accepted=accepted,
            row=self.row(),
        )

    def done(self) -> None:
        if self.pos != len(self.buf):
            raise CodecError(f"{len(self.buf) - self.pos} trailing bytes in payload")


# -- q payload (quantized probability row) -----------------------------------


def _encode_q(out: List[bytes], q: Optional[np.ndarray], qmode: str) -> None:
    if qmode not in QMODES:
        raise CodecError(f"unknown qmode {qmode!r}")
    out.append(bytes([QMODES.index(qmode)]))
    if qmode == "none":
        return
    if q is None:
        raise CodecError(f"qmode {qmode!r} requires a draft_q payload")
    q = np.asarray(q, np.float32).reshape(-1)
    out.append(struct.pack(">H", q.shape[0]))
    if qmode == "f32":
        out.append(q.astype("<f4").tobytes())
    elif qmode == "f16":
        out.append(q.astype("<f2").tobytes())
    else:  # int8: symmetric per-row scheme from quant/quantize.py
        qt = quantize(q[None, :], bits=8)
        out.append(struct.pack(">f", float(qt.scale[0, 0])))
        out.append(np.ascontiguousarray(qt.q[0]).astype("|i1").tobytes())


def _decode_q(r: _Reader):
    mode_id = r.u8()
    if mode_id >= len(QMODES):
        raise CodecError(f"unknown qmode id {mode_id}")
    qmode = QMODES[mode_id]
    if qmode == "none":
        return None, qmode
    n = r.u16()
    if qmode == "f32":
        q = np.frombuffer(r.take(4 * n), dtype="<f4").astype(np.float32)
    elif qmode == "f16":
        q = np.frombuffer(r.take(2 * n), dtype="<f2").astype(np.float32)
    else:
        scale = r.f32()
        raw = np.frombuffer(r.take(n), dtype="|i1")
        qt = QTensor(
            q=raw[None, :], scale=np.asarray([[scale]], np.float32), bits=8, shape=(1, n)
        )
        q = np.asarray(dequantize(qt, np.float32))[0]
    return q, qmode


# -- frame encode/decode -----------------------------------------------------


def encode_frame(msg: Message) -> bytes:
    out: List[bytes] = []
    if isinstance(msg, Hello):
        mtype = T_HELLO
        out.append(struct.pack(">I", msg.device_id))
        _put_tokens(out, msg.prompt)
    elif isinstance(msg, Admit):
        mtype = T_ADMIT
        out.append(struct.pack(">IBI", msg.device_id, int(msg.ok), msg.slot))
    elif isinstance(msg, DraftPacket):
        mtype = T_DRAFT
        out.append(struct.pack(">II", msg.device_id, msg.seq))
        _put_tokens(out, msg.tokens)
        _encode_q(out, msg.draft_q, msg.qmode)
    elif isinstance(msg, Verdict):
        mtype = T_VERDICT
        out.append(
            struct.pack(
                ">IIHiBfHff",
                msg.device_id,
                msg.seq,
                msg.n_accepted,
                msg.next_prev,
                msg.flags,
                float(msg.accept_rate),
                min(int(msg.queue_depth), 0xFFFF),
                float(msg.queue_s),
                float(msg.verify_s),
            )
        )
        _put_tokens(out, msg.tokens)
    elif isinstance(msg, Fallback):
        mtype = T_FALLBACK
        out.append(struct.pack(">II", msg.device_id, msg.seq))
        _put_tokens(out, msg.tokens)
    elif isinstance(msg, FallbackAck):
        mtype = T_FALLBACK_ACK
        out.append(struct.pack(">IIi", msg.device_id, msg.seq, msg.next_prev))
    elif isinstance(msg, Close):
        mtype = T_CLOSE
        out.append(struct.pack(">I", msg.device_id))
    elif isinstance(msg, PlaceReplica):
        mtype = T_PLACE
        _put_str(out, msg.spec_json)
    elif isinstance(msg, PlaceAck):
        mtype = T_PLACE_ACK
        out.append(
            struct.pack(
                ">BIIIBB",
                int(msg.ok),
                msg.n_slots,
                msg.k_max,
                msg.max_len,
                int(msg.greedy),
                int(msg.paged_attention),
            )
        )
        _put_str(out, msg.error)
    elif isinstance(msg, AdmitRequest):
        mtype = T_ADMIT_REQ
        out.append(struct.pack(">IId", msg.seq, msg.device_id, float(msg.now)))
        _put_tokens(out, msg.prompt)
    elif isinstance(msg, AdmitReply):
        mtype = T_ADMIT_REPLY
        out.append(
            struct.pack(">IBIi", msg.device_id, int(msg.ok), msg.slot, msg.prev_token)
        )
    elif isinstance(msg, SubmitRequest):
        mtype = T_SUBMIT
        out.append(struct.pack(">IId", msg.seq, msg.device_id, float(msg.now)))
        _put_tokens(out, msg.tokens)
        _encode_q(out, msg.draft_q, msg.qmode)
    elif isinstance(msg, SubmitAck):
        mtype = T_SUBMIT_ACK
        out.append(struct.pack(">I", msg.device_id))
    elif isinstance(msg, StepRequest):
        mtype = T_STEP
        out.append(struct.pack(">Id", msg.seq, float(msg.now)))
    elif isinstance(msg, StepReply):
        mtype = T_STEP_REPLY
        if len(msg.verdicts) > 0xFFFF:
            raise CodecError(f"too many verdicts in one step: {len(msg.verdicts)}")
        out.append(
            struct.pack(
                ">IIBd",
                msg.queue_depth,
                msg.n_free,
                int(msg.hint is not None),
                0.0 if msg.hint is None else float(msg.hint),
            )
        )
        out.append(struct.pack(">H", len(msg.verdicts)))
        for v in msg.verdicts:
            out.append(
                struct.pack(
                    ">IHifIff",
                    v.device_id,
                    v.n_accepted,
                    v.next_prev,
                    float(v.accept_rate),
                    v.queue_depth,
                    float(v.queue_s),
                    float(v.verify_s),
                )
            )
            _put_tokens(out, v.tokens)
    elif isinstance(msg, RetireRequest):
        mtype = T_RETIRE
        out.append(struct.pack(">II", msg.seq, msg.device_id))
    elif isinstance(msg, RetireReply):
        mtype = T_RETIRE_REPLY
        _put_stream_state(out, msg.stream)
    elif isinstance(msg, CancelRequest):
        mtype = T_CANCEL
        out.append(struct.pack(">II", msg.seq, msg.device_id))
    elif isinstance(msg, CancelReply):
        mtype = T_CANCEL_REPLY
        out.append(struct.pack(">IB", msg.device_id, int(msg.ok)))
    elif isinstance(msg, ForceExtendRequest):
        mtype = T_FORCE_EXTEND
        out.append(struct.pack(">II", msg.seq, msg.device_id))
        _put_tokens(out, msg.tokens)
    elif isinstance(msg, ForceExtendReply):
        mtype = T_FORCE_EXTEND_REPLY
        out.append(struct.pack(">Ii", msg.device_id, msg.next_prev))
    elif isinstance(msg, ExportStream):
        mtype = T_EXPORT
        out.append(struct.pack(">II", msg.seq, msg.device_id))
    elif isinstance(msg, ExportReply):
        mtype = T_EXPORT_REPLY
        _put_stream_state(out, msg.stream)
    elif isinstance(msg, ImportStream):
        mtype = T_IMPORT
        out.append(struct.pack(">I", msg.seq))
        _put_stream_state(out, msg.stream)
    elif isinstance(msg, ImportAck):
        mtype = T_IMPORT_ACK
        out.append(struct.pack(">II", msg.device_id, msg.slot))
    elif isinstance(msg, StatsRequest):
        mtype = T_STATS
        out.append(struct.pack(">dB", float(msg.now), int(msg.has_now)))
    elif isinstance(msg, ReplicaStats):
        mtype = T_REPLICA_STATS
        _put_str(out, msg.stats_json)
        _put_str(out, msg.telemetry_json)
    elif isinstance(msg, WarmupRequest):
        mtype = T_WARMUP
    elif isinstance(msg, WarmupReply):
        mtype = T_WARMUP_REPLY
        _put_str(out, msg.compile_json)
    elif isinstance(msg, Drain):
        mtype = T_DRAIN
    elif isinstance(msg, DrainAck):
        mtype = T_DRAIN_ACK
        out.append(struct.pack(">I", msg.streams_left))
    elif isinstance(msg, ErrorReply):
        mtype = T_ERROR
        _put_str(out, msg.message)
    elif isinstance(msg, Ping):
        mtype = T_PING
        out.append(struct.pack(">Id", msg.seq, float(msg.t)))
    elif isinstance(msg, Pong):
        mtype = T_PONG
        out.append(struct.pack(">Id", msg.seq, float(msg.t)))
    else:
        raise CodecError(f"cannot encode {type(msg).__name__}")
    payload = b"".join(out)
    return _HEADER.pack(MAGIC, VERSION, mtype, len(payload)) + payload


def decode_frame(buf: bytes) -> tuple:
    """Decode one frame from the head of ``buf``; returns (message, consumed).

    Raises CodecError on a malformed header or payload; an *incomplete* frame
    (fewer bytes than the header announces) also raises — stream transports
    should use FrameDecoder, which buffers instead.
    """
    if len(buf) < HEADER_SIZE:
        raise CodecError(f"truncated header: {len(buf)} < {HEADER_SIZE} bytes")
    magic, version, mtype, plen = _HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r}")
    if version != VERSION:
        raise CodecError(f"unsupported protocol version {version} (speak {VERSION})")
    if plen > MAX_PAYLOAD:
        raise CodecError(f"payload length {plen} exceeds cap {MAX_PAYLOAD}")
    if len(buf) < HEADER_SIZE + plen:
        raise CodecError(
            f"truncated frame: payload needs {plen} bytes, have {len(buf) - HEADER_SIZE}"
        )
    r = _Reader(bytes(buf[HEADER_SIZE : HEADER_SIZE + plen]))
    if mtype == T_HELLO:
        msg: Message = Hello(device_id=r.u32(), prompt=r.tokens())
    elif mtype == T_ADMIT:
        msg = Admit(device_id=r.u32(), ok=bool(r.u8()), slot=r.u32())
    elif mtype == T_DRAFT:
        dev, seq = r.u32(), r.u32()
        toks = r.tokens()
        q, qmode = _decode_q(r)
        if q is not None and q.shape[0] != toks.shape[0]:
            raise CodecError(f"draft_q length {q.shape[0]} != token count {toks.shape[0]}")
        msg = DraftPacket(device_id=dev, seq=seq, tokens=toks, draft_q=q, qmode=qmode)
    elif mtype == T_VERDICT:
        dev, seq, n_acc, nxt, flags = r.u32(), r.u32(), r.u16(), r.i32(), r.u8()
        accept_rate, queue_depth = r.f32(), r.u16()
        queue_s, verify_s = r.f32(), r.f32()
        msg = Verdict(
            device_id=dev,
            seq=seq,
            n_accepted=n_acc,
            tokens=r.tokens(),
            next_prev=nxt,
            flags=flags,
            accept_rate=accept_rate,
            queue_depth=queue_depth,
            queue_s=queue_s,
            verify_s=verify_s,
        )
    elif mtype == T_FALLBACK:
        msg = Fallback(device_id=r.u32(), seq=r.u32(), tokens=r.tokens())
    elif mtype == T_FALLBACK_ACK:
        msg = FallbackAck(device_id=r.u32(), seq=r.u32(), next_prev=r.i32())
    elif mtype == T_CLOSE:
        msg = Close(device_id=r.u32())
    elif mtype == T_PLACE:
        msg = PlaceReplica(spec_json=r.string())
    elif mtype == T_PLACE_ACK:
        ok, n_slots, k_max, max_len = bool(r.u8()), r.u32(), r.u32(), r.u32()
        greedy, paged = bool(r.u8()), bool(r.u8())
        msg = PlaceAck(
            ok=ok, n_slots=n_slots, k_max=k_max, max_len=max_len,
            greedy=greedy, paged_attention=paged, error=r.string(),
        )
    elif mtype == T_ADMIT_REQ:
        seq, dev, now = r.u32(), r.u32(), r.f64()
        msg = AdmitRequest(device_id=dev, prompt=r.tokens(), now=now, seq=seq)
    elif mtype == T_ADMIT_REPLY:
        msg = AdmitReply(
            device_id=r.u32(), ok=bool(r.u8()), slot=r.u32(), prev_token=r.i32()
        )
    elif mtype == T_SUBMIT:
        seq, dev, now = r.u32(), r.u32(), r.f64()
        toks = r.tokens()
        q, qmode = _decode_q(r)
        if q is not None and q.shape[0] != toks.shape[0]:
            raise CodecError(f"draft_q length {q.shape[0]} != token count {toks.shape[0]}")
        msg = SubmitRequest(
            device_id=dev, tokens=toks, now=now, draft_q=q, qmode=qmode, seq=seq
        )
    elif mtype == T_SUBMIT_ACK:
        msg = SubmitAck(device_id=r.u32())
    elif mtype == T_STEP:
        seq = r.u32()
        msg = StepRequest(now=r.f64(), seq=seq)
    elif mtype == T_STEP_REPLY:
        depth, n_free, has_hint, hint = r.u32(), r.u32(), r.u8(), r.f64()
        verdicts = []
        for _ in range(r.u16()):
            dev, n_acc, nxt, rate, vdepth = r.u32(), r.u16(), r.i32(), r.f32(), r.u32()
            vqueue_s, vverify_s = r.f32(), r.f32()
            verdicts.append(
                VerdictRec(
                    device_id=dev, n_accepted=n_acc, tokens=r.tokens(),
                    next_prev=nxt, accept_rate=rate, queue_depth=vdepth,
                    queue_s=vqueue_s, verify_s=vverify_s,
                )
            )
        msg = StepReply(
            verdicts=tuple(verdicts), queue_depth=depth, n_free=n_free,
            hint=hint if has_hint else None,
        )
    elif mtype == T_RETIRE:
        seq = r.u32()
        msg = RetireRequest(device_id=r.u32(), seq=seq)
    elif mtype == T_RETIRE_REPLY:
        msg = RetireReply(stream=r.stream_state())
    elif mtype == T_CANCEL:
        seq = r.u32()
        msg = CancelRequest(device_id=r.u32(), seq=seq)
    elif mtype == T_CANCEL_REPLY:
        msg = CancelReply(device_id=r.u32(), ok=bool(r.u8()))
    elif mtype == T_FORCE_EXTEND:
        seq = r.u32()
        msg = ForceExtendRequest(device_id=r.u32(), tokens=r.tokens(), seq=seq)
    elif mtype == T_FORCE_EXTEND_REPLY:
        msg = ForceExtendReply(device_id=r.u32(), next_prev=r.i32())
    elif mtype == T_EXPORT:
        seq = r.u32()
        msg = ExportStream(device_id=r.u32(), seq=seq)
    elif mtype == T_EXPORT_REPLY:
        msg = ExportReply(stream=r.stream_state())
    elif mtype == T_IMPORT:
        seq = r.u32()
        msg = ImportStream(stream=r.stream_state(), seq=seq)
    elif mtype == T_IMPORT_ACK:
        msg = ImportAck(device_id=r.u32(), slot=r.u32())
    elif mtype == T_STATS:
        msg = StatsRequest(now=r.f64(), has_now=bool(r.u8()))
    elif mtype == T_REPLICA_STATS:
        msg = ReplicaStats(stats_json=r.string(), telemetry_json=r.string())
    elif mtype == T_WARMUP:
        msg = WarmupRequest()
    elif mtype == T_WARMUP_REPLY:
        msg = WarmupReply(compile_json=r.string())
    elif mtype == T_DRAIN:
        msg = Drain()
    elif mtype == T_DRAIN_ACK:
        msg = DrainAck(streams_left=r.u32())
    elif mtype == T_ERROR:
        msg = ErrorReply(message=r.string())
    elif mtype == T_PING:
        msg = Ping(seq=r.u32(), t=r.f64())
    elif mtype == T_PONG:
        msg = Pong(seq=r.u32(), t=r.f64())
    else:
        raise CodecError(f"unknown message type {mtype}")
    r.done()
    return msg, HEADER_SIZE + plen


class FrameDecoder:
    """Incremental decoder for byte-stream transports: feed arbitrary chunks,
    iterate complete messages (partial frames wait for more bytes)."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    def next_raw(self) -> Optional[bytes]:
        """Pop the next COMPLETE frame as raw bytes (header+payload), or None
        when more bytes are needed.  Used by byte-stream endpoints
        (transport/links.py StreamEndpoint) that forward whole frames without
        decoding them; corrupt headers raise the precise CodecError."""
        if len(self._buf) < HEADER_SIZE:
            return None
        magic, version, _, plen = _HEADER.unpack_from(self._buf)
        if magic != MAGIC or version != VERSION or plen > MAX_PAYLOAD:
            decode_frame(bytes(self._buf))  # raises the precise error
        if len(self._buf) < HEADER_SIZE + plen:
            return None
        raw = bytes(self._buf[: HEADER_SIZE + plen])
        del self._buf[: HEADER_SIZE + plen]
        return raw

    def __iter__(self) -> Iterator[Message]:
        while True:
            if len(self._buf) < HEADER_SIZE:
                return
            magic, version, _, plen = _HEADER.unpack_from(self._buf)
            if magic != MAGIC or version != VERSION or plen > MAX_PAYLOAD:
                # corrupt stream: decode_frame raises the precise error
                decode_frame(bytes(self._buf))
            if len(self._buf) < HEADER_SIZE + plen:
                return
            msg, used = decode_frame(bytes(self._buf[: HEADER_SIZE + plen]))
            del self._buf[:used]
            yield msg
