"""Weight-only quantization (int8 / int4, per-output-channel scales).

The paper's Fig. 6 sweeps 16/8/4-bit precision on both edge devices and the
server; this module provides the numerics.  Matrix leaves (ndim >= 2) are
quantized along their last axis; norms/biases/scalars stay fp.

``quantize_pytree`` -> {leaf: QTensor}, ``dequantize_pytree`` -> bf16 pytree
(what the serving engine loads: memory footprint on HBM is bits/8 per param
— the roofline memory term uses this, see benchmarks/pareto.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array      # int8 payload ((bits=4) packs two nibbles per byte)
    scale: jax.Array  # fp32, per output channel
    bits: int
    shape: tuple


def _is_matrix(leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and leaf.dtype in (
        jnp.bfloat16, jnp.float32, jnp.float16,
    )


def quantize(w: jax.Array, bits: int) -> QTensor:
    assert bits in (4, 8)
    qmax = 127 if bits == 8 else 7
    w32 = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w32), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w32 / scale), -qmax, qmax).astype(jnp.int8)
    return QTensor(q=q, scale=scale, bits=bits, shape=tuple(w.shape))


def dequantize(t: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def quantize_pytree(params: Any, bits: int) -> Any:
    if bits >= 16:
        return params
    return jax.tree.map(
        lambda w: quantize(w, bits) if _is_matrix(w) else w, params
    )


def dequantize_pytree(qparams: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda t: dequantize(t, dtype) if isinstance(t, QTensor) else t,
        qparams,
        is_leaf=lambda x: isinstance(x, QTensor),
    )


def quantized_bytes(params: Any, bits: int) -> int:
    """Model-weight HBM footprint at the given precision."""
    total = 0
    for leaf in jax.tree.leaves(params):
        n = leaf.size
        if _is_matrix(leaf) and bits < 16:
            total += n * bits // 8 + leaf.shape[-2] * 4  # payload + scales
        else:
            total += n * leaf.dtype.itemsize
    return total


def quant_error(params: Any, bits: int) -> float:
    """Mean relative L2 error across matrix leaves (quality proxy)."""
    if bits >= 16:
        return 0.0
    errs = []
    for leaf in jax.tree.leaves(params):
        if _is_matrix(leaf):
            d = dequantize(quantize(leaf, bits), jnp.float32)
            w = leaf.astype(jnp.float32)
            errs.append(float(jnp.linalg.norm(d - w) / jnp.maximum(jnp.linalg.norm(w), 1e-9)))
    return sum(errs) / max(len(errs), 1)
