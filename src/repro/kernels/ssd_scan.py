"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

One program instance owns one (batch, head) pair and walks the sequence in
``chunk``-sized steps along the LAST grid axis (TPU grids iterate it
sequentially), carrying the (P, N) SSM state in fp32 VMEM scratch:

  * intra-chunk: the quadratic-in-chunk part is two MXU matmuls
    (C B^T ∘ decay) X — chunk x chunk scores never touch HBM;
  * inter-chunk: h <- exp(sum a) h + (decay-to-end ⊙ dt ⊙ B)^T X, again an
    MXU matmul, state stays resident in VMEM across the whole sequence;
  * per-chunk log-decay cumsums are computed in fp32 in VREGs.

This is the TPU-native re-blocking of the Mamba2 paper's GPU kernel: the
GPU version tiles over (chunk, head, batch) thread-blocks with warp-level
softplus/cumsum; here the systolic array does the two GEMMs and the VPU the
cumsum, with the sequential chunk axis mapped onto the grid instead of a
persistent CTA loop.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            h_ref, *, chunk: int):
    cidx = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(cidx == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0, 0]                               # scalar A_h (negative)
    Bm = b_ref[0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)             # (Q, N)

    alog = dt * a                                 # (Q,) per-step log decay
    cum = jnp.cumsum(alog)                        # (Q,)
    h = h_ref[...]                                # (P, N)

    # carry-in: y_off_i = exp(cum_i) * C_i . h
    y_off = jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                     # (Q, P)

    # intra-chunk: W_ij = (C_i.B_j) exp(cum_i - cum_j) dt_j for j <= i
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], x.shape[0]), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], x.shape[0]), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    W = cb * decay * dt[None, :]
    y_diag = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y_off + y_diag).astype(y_ref.dtype)

    # state update: h <- exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    d_end = jnp.exp(cum[-1] - cum) * dt           # (Q,)
    h_new = jax.lax.dot_general(
        x, Bm * d_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (P, N)
    h_ref[...] = jnp.exp(cum[-1]) * h + h_new

    @pl.when(cidx == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan_chunked(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    h0: jax.Array,   # (B, H, P, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, "pad sequence to a chunk multiple upstream"
    n_chunks = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),  # x
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),        # dt
            pl.BlockSpec((1, 1), lambda b, h, c: (0, h)),                  # A
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),        # C
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),  # y
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),      # h_out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(1, H), Bm, Cm, h0)
    return y, h
