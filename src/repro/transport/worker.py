"""Remote replica worker: one engine replica per OS process, behind a socket.

``repro worker --listen ADDR [--spec FILE]`` runs ONE verification replica
in its own process, listening on a TCP or UDS :class:`StreamEndpoint` for
codec v3 control frames from a cluster Router (cluster/remote.py's
``RemoteReplica`` is the dialing side).  The worker is the cross-process
half of the ROADMAP's "placement RPC is just a ServeSpec shipped to another
host" slice:

  * ``PlaceReplica`` carries a serialized ServeSpec subtree; the worker
    builds its engine from it through the same ``System.build`` front door
    as every in-process backend, so worker params are rebuilt
    deterministically from the spec's model seed — two processes placing the
    same spec hold bit-identical weights, which is what keeps cross-process
    serving token-identical to the in-process cluster;
  * every driver RPC (admit / submit / step / retire / cancel /
    force-extend / export / import / stats / warmup) mirrors the
    ServerEngine surface 1:1, and every ``now`` comes from the ROUTER's
    clock — the worker never consults its own, so cross-process batch
    scheduling is deterministic and clock skew cannot reorder rounds;
  * ``ExportStream``/``ImportStream`` move a stream's full server-side
    state plus a bit-exact KV row serialization, so the Router migrates
    streams across processes exactly as it does between in-process replicas;
  * ``Drain`` acks and exits the process.

The engine is wrapped in a :class:`~repro.transport.server.TransportServer`:
control connections drive the engine through :class:`WorkerCore` dispatch,
while a connection that opens with a data-plane frame (``Hello``) is handed
to the transport server instead — a worker can also serve edge devices
directly, one replica per port (do not mix router-driven stepping and
direct device service on one worker; the two step clocks are independent).

Dispatch is transport-free in :class:`WorkerCore` (message in, reply out),
so tests drive the full wire dispatch without sockets or subprocesses.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import logging
from collections import OrderedDict
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.transport import codec
from repro.transport.links import Endpoint, listen_addr

log = logging.getLogger(__name__)


def stream_to_state(stream, row: Optional[dict] = None) -> codec.StreamState:
    """Serialize a server-side DeviceStream (core/admission.py) for the wire."""
    return codec.StreamState(
        device_id=stream.device_id,
        slot=stream.slot,
        prev_token=int(stream.prev_token),
        committed=tuple(int(t) for t in stream.committed),
        admitted_at=float(stream.admitted_at),
        rounds=int(stream.rounds),
        drafted=int(stream.drafted),
        accepted=int(stream.accepted),
        row={} if row is None else {k: np.asarray(v) for k, v in row.items()},
    )


def state_to_stream(state: codec.StreamState):
    """Inverse of :func:`stream_to_state` (row travels separately)."""
    from repro.core.admission import DeviceStream

    return DeviceStream(
        device_id=state.device_id,
        slot=state.slot,
        prev_token=state.prev_token,
        committed=[int(t) for t in state.committed],
        admitted_at=state.admitted_at,
        rounds=state.rounds,
        drafted=state.drafted,
        accepted=state.accepted,
    )


def build_engine_from_spec(spec):
    """One engine replica from a ServeSpec subtree, through the same front
    door as every in-process backend (System.build), so construction
    semantics — paging fallback warnings included — cannot drift."""
    from repro.api.system import System

    if spec.backend != "engine":
        spec = spec.with_backend("engine")
    return System.build(spec).engine


class WorkerCore:
    """Control-frame dispatch against one engine: message in, reply out.

    Any handler exception becomes an :class:`~repro.transport.codec.ErrorReply`
    (the dialing side re-raises it); the connection survives, because a
    rejected RPC (say, an export refused while a request is in flight) is a
    protocol answer, not a worker crash.

    v4 replay protection: side-effectful requests carrying a non-zero
    ``seq`` are deduped through a bounded replay cache keyed by
    (frame type, device, seq).  A router that lost the reply to a link flap
    can reconnect and RESEND the same frame — the worker returns the
    original reply instead of double-applying the admit/submit/step/retire,
    which is what makes the dialing side's one-shot retry safe.
    """

    REPLAY_CAP = 512  # cached replies; enough to cover any in-flight window

    _REPLAYABLE = ()  # filled below (codec classes defined at module scope)

    def __init__(self, engine=None):
        self.engine = engine
        self.draining = False
        self._replay: "OrderedDict[tuple, codec.Message]" = OrderedDict()
        self.replay_hits = 0

    def _replay_key(self, msg: codec.Message) -> Optional[tuple]:
        if not isinstance(msg, WorkerCore._REPLAYABLE) or msg.seq == 0:
            return None
        if isinstance(msg, codec.ImportStream):
            dev = msg.stream.device_id
        else:
            dev = getattr(msg, "device_id", -1)
        return (type(msg).__name__, dev, msg.seq)

    def handle(self, msg: codec.Message) -> codec.Message:
        if isinstance(msg, codec.Ping):  # heartbeat: no engine, no side effects
            return codec.Pong(seq=msg.seq, t=msg.t)
        key = self._replay_key(msg)
        if key is not None and key in self._replay:
            self.replay_hits += 1
            telemetry.count("worker_replay_hits_total")
            return self._replay[key]
        try:
            reply = self._dispatch(msg)
        except Exception as e:  # surfaced to the router, not crashed here
            reply = codec.ErrorReply(f"{type(e).__name__}: {e}")
        if key is not None:
            self._replay[key] = reply
            while len(self._replay) > self.REPLAY_CAP:
                self._replay.popitem(last=False)
        return reply

    def _dispatch(self, msg: codec.Message) -> codec.Message:
        if isinstance(msg, codec.PlaceReplica):
            return self._place(msg)
        if isinstance(msg, codec.Drain):
            self.draining = True
            return codec.DrainAck(
                streams_left=0 if self.engine is None else len(self.engine.streams)
            )
        if self.engine is None:
            raise RuntimeError("worker has no engine yet (send PlaceReplica first)")
        engine = self.engine
        if isinstance(msg, codec.AdmitRequest):
            stream = engine.admit(
                msg.device_id, jnp.asarray(msg.prompt, jnp.int32), msg.now
            )
            if stream is None:
                return codec.AdmitReply(msg.device_id, ok=False)
            return codec.AdmitReply(
                msg.device_id, ok=True, slot=stream.slot,
                prev_token=int(stream.prev_token),
            )
        if isinstance(msg, codec.SubmitRequest):
            engine.submit(msg.device_id, msg.tokens, msg.now, draft_q=msg.draft_q)
            return codec.SubmitAck(msg.device_id)
        if isinstance(msg, codec.StepRequest):
            verdicts = engine.step(msg.now) or []
            recs = tuple(
                codec.VerdictRec(
                    device_id=v.device_id,
                    n_accepted=int(v.n_accepted),
                    tokens=np.asarray(v.tokens, np.int32),
                    next_prev=int(v.next_prev),
                    accept_rate=float(v.accept_rate),
                    queue_depth=int(v.queue_depth),
                    queue_s=float(v.queue_s),
                    verify_s=float(v.verify_s),
                )
                for v in verdicts
            )
            return codec.StepReply(
                verdicts=recs,
                queue_depth=engine.queue_depth,
                n_free=engine.pool.n_free,
                hint=engine.next_event_hint(msg.now),
            )
        if isinstance(msg, codec.RetireRequest):
            stream = engine.retire(msg.device_id)
            return codec.RetireReply(stream=stream_to_state(stream))
        if isinstance(msg, codec.CancelRequest):
            return codec.CancelReply(msg.device_id, ok=engine.cancel_request(msg.device_id))
        if isinstance(msg, codec.ForceExtendRequest):
            nxt = engine.force_extend(msg.device_id, msg.tokens)
            return codec.ForceExtendReply(msg.device_id, next_prev=int(nxt))
        if isinstance(msg, codec.ExportStream):
            stream, row = engine.export_stream(msg.device_id)
            return codec.ExportReply(stream=stream_to_state(stream, row))
        if isinstance(msg, codec.ImportStream):
            stream = state_to_stream(msg.stream)
            engine.import_stream(stream, dict(msg.stream.row))
            return codec.ImportAck(msg.stream.device_id, slot=stream.slot)
        if isinstance(msg, codec.StatsRequest):
            st = engine.stats(msg.now if msg.has_now else None)
            payload = engine.telemetry_payload() if hasattr(engine, "telemetry_payload") else {}
            return codec.ReplicaStats(
                stats_json=json.dumps(st.to_json()),
                telemetry_json=json.dumps(payload) if payload else "",
            )
        if isinstance(msg, codec.WarmupRequest):
            secs = engine.warmup()
            return codec.WarmupReply(
                compile_json=json.dumps({str(k): v for k, v in secs.items()})
            )
        raise codec.CodecError(f"worker cannot handle {type(msg).__name__}")

    def _place(self, msg: codec.PlaceReplica) -> codec.Message:
        from repro.api.spec import ServeSpec

        if self.engine is not None:
            return codec.PlaceAck(ok=False, error="worker already has an engine placed")
        try:
            spec = ServeSpec.from_json(msg.spec_json)
            self.engine = build_engine_from_spec(spec)
        except Exception as e:
            return codec.PlaceAck(ok=False, error=f"{type(e).__name__}: {e}")
        return codec.PlaceAck(
            ok=True,
            n_slots=self.engine.pool.n_slots,
            k_max=self.engine.k_max,
            max_len=self.engine.pool.max_len,
            greedy=self.engine.greedy,
            paged_attention=self.engine.paged_attention,
        )


WorkerCore._REPLAYABLE = (
    codec.AdmitRequest,
    codec.SubmitRequest,
    codec.StepRequest,
    codec.RetireRequest,
    codec.CancelRequest,
    codec.ForceExtendRequest,
    codec.ExportStream,
    codec.ImportStream,
)


class ReplicaWorker:
    """The worker process' accept loop: control sessions drive WorkerCore;
    a connection that opens with a data-plane ``Hello`` is attached to the
    TransportServer wrapping the engine instead (direct device service)."""

    def __init__(self, listen: str, *, engine=None):
        self.listen = listen
        self.core = WorkerCore(engine)
        self.resolved: Optional[str] = None
        self._drained = None  # asyncio.Event, created on the serve loop
        self._transport = None  # TransportServer, on first data-plane conn

    async def serve(self) -> None:
        self._drained = asyncio.Event()
        server, self.resolved = await listen_addr(self._serve_conn, self.listen)
        print(f"repro-worker listening on {self.resolved}", flush=True)
        log.info("worker listening on %s", self.resolved)
        try:
            await self._drained.wait()
        finally:
            if self._transport is not None:
                await self._transport.stop()
            server.close()
            await server.wait_closed()

    async def _serve_conn(self, ep: Endpoint) -> None:
        while True:
            frame = await ep.recv()
            if frame is None:
                return
            msg, _ = codec.decode_frame(frame)
            if isinstance(msg, (codec.Hello, codec.DraftPacket, codec.Fallback, codec.Close)):
                await self._serve_device(ep, msg)
                return
            reply = self.core.handle(msg)
            await ep.send(codec.encode_frame(reply))
            if isinstance(msg, codec.Drain):
                self._drained.set()
                return

    async def _serve_device(self, ep: Endpoint, first: codec.Message) -> None:
        """Hand a data-plane connection to the TransportServer wrapper."""
        from repro.transport.server import TransportServer

        if self.core.engine is None:
            raise RuntimeError("worker has no engine yet (send PlaceReplica or --spec)")
        if self._transport is None:
            self._transport = TransportServer(self.core.engine)
        srv = self._transport
        srv._endpoints.append(ep)  # wire stats: this conn counts in stats()
        await srv._dispatch(first, ep)
        if srv._stepper is None:
            srv._stepper = asyncio.get_running_loop().create_task(srv._step_loop())
        device_id = getattr(first, "device_id", None)
        while True:
            frame = await ep.recv()
            if frame is None:
                break
            msg, _ = codec.decode_frame(frame)
            device_id = msg.device_id
            await srv._dispatch(msg, ep)
        if device_id is not None and device_id in srv.engine.streams:
            await srv._retire(device_id)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro worker",
        description="Run one SLED engine replica behind a TCP/UDS control socket.",
    )
    ap.add_argument(
        "--listen", type=str, default="tcp:127.0.0.1:0",
        help="listen address: tcp:HOST:PORT (port 0 = free port) or uds:/path.sock",
    )
    ap.add_argument(
        "--spec", type=str, default="",
        help="optional ServeSpec JSON artifact: build the engine up front "
             "instead of waiting for a PlaceReplica frame",
    )
    ap.add_argument(
        "--log-level", type=str, default=None,
        help="repro.* logger level (debug/info/warning/error); "
             "falls back to REPRO_LOG_LEVEL, default warning",
    )
    args = ap.parse_args(argv)
    if args.log_level or not logging.getLogger("repro").handlers:
        # don't clobber a level the repro CLI's global --log-level already set
        telemetry.setup_logging(args.log_level)
    engine = None
    if args.spec:
        from repro.api.spec import ServeSpec

        with open(args.spec) as f:
            engine = build_engine_from_spec(ServeSpec.from_json(f.read()))
    asyncio.run(ReplicaWorker(args.listen, engine=engine).serve())


if __name__ == "__main__":
    main()
