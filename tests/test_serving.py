"""Serving layer: simulator physics, scheduler policies, fault tolerance."""
import dataclasses

import numpy as np
import pytest

from repro.core.scheduler import BatchPlanner, VerifyRequest
from repro.serving.devices import A100_X4, V5E_16
from repro.serving.simulator import SimConfig, capacity, simulate


def _base(**kw):
    d = dict(mode="sled", n_devices=8, device_rate=8.0, acceptance=0.9,
             spec_len=4, server_batch=8, batch_policy="deadline", sim_time=20.0)
    d.update(kw)
    return SimConfig(**d)


def test_sled_beats_centralized_capacity():
    """The paper's Table-I claim: >2x capacity at the same response rate."""
    sled = capacity(_base(), A100_X4, n_max=256)
    cent = capacity(_base(mode="centralized"), A100_X4, n_max=256)
    assert sled / max(cent, 1) > 2.0, (sled, cent)


@pytest.mark.slow
def test_wstgr_beats_centralized_at_saturation():
    """Fig. 4 claim: >2x system throughput at equal batch once the server is
    the binding resource for both systems.  (Below centralized capacity the
    centralized server simply streams faster than slow edge drafting — the
    paper's "identical conditions" comparison is at scale, where SLED's
    server does K+1 tokens per row per round.)"""
    cfg = _base(n_devices=1024, server_batch=16, sim_time=15.0)
    sled = simulate(cfg, A100_X4)
    cent = simulate(dataclasses.replace(cfg, mode="centralized"), A100_X4)
    assert cent.server_busy_frac > 0.9  # centralized saturated
    assert sled.wstgr / cent.wstgr > 2.0, (sled.wstgr, cent.wstgr)


def test_speclen_tradeoff_direction():
    """Fig. 5: longer speculation -> lower device rate, higher capacity."""
    r_short = simulate(_base(spec_len=1), A100_X4)
    r_long = simulate(_base(spec_len=16), A100_X4)
    assert r_long.per_device_rate < r_short.per_device_rate
    cap_short = capacity(_base(spec_len=1, sim_time=8.0), A100_X4, n_max=256)
    cap_long = capacity(_base(spec_len=16, sim_time=8.0), A100_X4, n_max=256)
    assert cap_long > cap_short


def test_timeout_fallback_keeps_devices_alive():
    """Paper §III-A: network loss triggers local-draft fallback, the system
    degrades gracefully instead of stalling (fault tolerance on the
    serving path)."""
    r = simulate(_base(drop_prob=0.5, verify_timeout=0.2), A100_X4)
    assert r.timeouts > 0
    assert r.fallback_tokens > 0
    assert r.wstgr > 0.2 * 8 * 8.0 * 0.5  # still makes real progress


def test_straggler_eviction():
    p = BatchPlanner(batch_size=4, k_max=4, policy="static", straggler_timeout=0.1)
    p.add(VerifyRequest(0, arrival=0.0, prev_token=0,
                        draft_tokens=np.zeros(2, np.int32)))
    p.add(VerifyRequest(1, arrival=5.0, prev_token=0,
                        draft_tokens=np.zeros(2, np.int32)))
    assert p.next_batch(5.01, True) is None  # static: batch not full
    assert len(p.dropped) == 1 and p.dropped[0].device_id == 0


def test_continuous_batching_beats_static_latency():
    """Beyond-paper scheduler: continuous batching cuts round latency when
    the server is underutilized."""
    st = simulate(_base(batch_policy="static", server_batch=8, n_devices=8), A100_X4)
    co = simulate(_base(batch_policy="continuous", server_batch=8, n_devices=8), A100_X4)
    assert co.mean_round_latency <= st.mean_round_latency * 1.05


def test_v5e_profile_serves():
    r = simulate(_base(), V5E_16)
    assert r.wstgr > 0


def test_dynamic_draft_lengths():
    r = simulate(_base(dynamic=True, c_th_mean_len=3.0), A100_X4)
    assert r.wstgr > 0
