"""The paper's own model pairs: LLaMA-style 1B/3B drafts, 11B/70B targets.

These drive the paper-reproduction benchmarks (Table I, Figs 3-6). They are
llama-3.2/3.1-shaped configs; notes start with "paper-" so they are excluded
from the assigned 40-cell dry-run grid (they get their own dry-run entries via
--arch on the launcher).
"""
from repro.configs.base import ModelConfig, register

LLAMA_1B_DRAFT = register(
    ModelConfig(
        name="llama-1b-draft",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta=500_000.0,
        notes="paper-draft: RPi/Jetson draft model (llama-3.2-1B shape)",
    )
)

LLAMA_3B_DRAFT = register(
    ModelConfig(
        name="llama-3b-draft",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        rope_theta=500_000.0,
        notes="paper-draft: llama-3.2-3B shape",
    )
)

LLAMA_11B_TARGET = register(
    ModelConfig(
        name="llama-11b-target",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        notes="paper-target: 11B verifier on the edge server",
    )
)

LLAMA_70B_TARGET = register(
    ModelConfig(
        name="llama-70b-target",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500_000.0,
        notes="paper-target: 70B verifier (llama-3.1-70B shape)",
    )
)
