"""Sequence-parallel (context-parallel) attention over a model-axis-sharded
KV cache — jax-native flash-decoding.

Why: GQA archs with few KV heads (granite-34b MQA kv=1, qwen3-moe kv=4,
llava kv=8, qwen1.5 whose 40 heads don't divide the 16-way model axis)
cannot head-shard their KV caches, and a 32k x 128-row cache replicated
over the model axis is tens of GB per device.  Sharding the cache's
SEQUENCE dim over the model axis fits it, at the price of a softmax
combine across shards:

  per shard:  (acc_r, m_r, l_r) = flash(q, K_r, V_r)    # local chunks only
  combine:    m* = pmax_r m_r;  c_r = exp(m_r - m*)
              out = psum_r(acc_r * c_r) / psum_r(l_r * c_r)

This is the TPU/shard_map version of flash-decoding's split-KV reduction
(maps the paper's "batched verification" onto a 2D (request, sequence)
decomposition).  The append of the K+1 fresh rows happens inside the same
shard_map: each shard scatters (mode="drop") the rows that land in its
sequence range — new rows may straddle a shard boundary.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.layers import MeshContext, flash_attention

# §Perf iteration A2: psum the flash-decoding partials in bf16 (halves the
# per-layer combine bytes).  fp32 default — the bf16 variant loses ~3
# decimal digits on the softmax accumulators, acceptable for greedy
# verification (argmax), measured via `dryrun --combine-bf16`.
COMBINE_DTYPE = None  # None -> fp32


def sp_append_attend(
    q: jax.Array,       # (B, Sq, Hq, D) — replicated over model axis
    k_cache: jax.Array,  # (B, S, Hkv, D) — S sharded over model axis
    v_cache: jax.Array,
    k_new: jax.Array,   # (B, Sq, Hkv, D) fresh rows (replicated)
    v_new: jax.Array,
    cache_len: jax.Array,   # (B,) committed lengths
    start: jax.Array,       # scalar: uniform insert position (padded batch)
    ctx: MeshContext,
    *,
    causal: bool = True,
    chunk: int = 1024,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out (B,Sq,Hq,D), k_cache', v_cache')."""
    ax = ctx.model_axis
    tp = ctx.tp
    B, Sq, Hq, D = q.shape
    S = k_cache.shape[1]
    S_loc = S // tp
    bspec = ctx.batch_axes if ctx.batch_axes else None
    chunk = min(chunk, S_loc)

    def f(q, kc, vc, kn, vn, clen, st):
        r = jax.lax.axis_index(ax)
        base = r * S_loc
        # scatter the fresh rows that land in this shard (straddle-safe);
        # negative locals would WRAP under jnp indexing, so route them to an
        # explicit OOB sentinel that mode="drop" discards
        pos = st + jnp.arange(Sq, dtype=jnp.int32) - base  # local positions
        pos = jnp.where((pos >= 0) & (pos < S_loc), pos, S_loc)
        from repro.models.layers import kv_quant
        kc = kc.at[:, pos].set(kv_quant(kn, kc.dtype), mode="drop")
        vc = vc.at[:, pos].set(kv_quant(vn, vc.dtype), mode="drop")
        # local flash with global position masking
        q_pos = clen[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
        kv_valid = clen + Sq
        acc, m, l = flash_attention(
            q, kc, vc, q_pos=q_pos, kv_valid=kv_valid, causal=causal,
            chunk=chunk, pos_offset=base, return_stats=True,
        )
        # flash-decoding combine across sequence shards
        m_g = jax.lax.pmax(m, ax)
        c = jnp.exp(m - m_g)
        cd = COMBINE_DTYPE
        l_g = jax.lax.psum((l * c).astype(cd) if cd else l * c, ax)
        acc_g = jax.lax.psum(
            (acc * c[..., None]).astype(cd) if cd else acc * c[..., None], ax)
        out = acc_g.astype(jnp.float32) / jnp.maximum(
            l_g.astype(jnp.float32), 1e-30)[..., None]  # (B, Sq, Hkv, G, D)
        return out.reshape(q.shape[0], Sq, Hq, D).astype(q.dtype), kc, vc

    out, kc, vc = shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(
            P(bspec, None, None, None),      # q
            P(bspec, ax, None, None),        # k_cache (S sharded)
            P(bspec, ax, None, None),        # v_cache
            P(bspec, None, None, None),      # k_new
            P(bspec, None, None, None),      # v_new
            P(bspec),                        # cache_len
            P(),                             # start
        ),
        out_specs=(
            P(bspec, None, None, None),
            P(bspec, ax, None, None),
            P(bspec, ax, None, None),
        ),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, cache_len, start)
    return out, kc, vc
