"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/<mesh>/*.json and prints the three terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction per
(arch x shape).  Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = "experiments/dryrun"


def load(mesh: str = "pod", tag: str = "") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        base = os.path.basename(path)[:-5]
        if tag and not base.endswith(f"__{tag}"):
            continue
        if not tag and base.count("__") > 1:
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({"cell": base, "status": rec.get("status", "?")})
            continue
        rows.append({
            "cell": base,
            "t_compute_ms": round(rec["t_compute"] * 1e3, 2),
            "t_memory_ms": round(rec["t_memory"] * 1e3, 2),
            "t_collective_ms": round(rec["t_collective"] * 1e3, 2),
            "bound": rec["bottleneck"],
            "useful_flops": round(rec["useful_flops_frac"], 3),
            "roofline_frac": round(rec["roofline_frac"], 4),
            "hbm_gb": round((rec["arg_bytes"] + rec["temp_bytes"]
                             + rec["out_bytes"] - rec["alias_bytes"]) / 1e9, 2),
        })
    return rows


def run(quick: bool = False) -> list:
    rows = load("pod")
    if not rows:
        rows = [{"note": "no dry-run artifacts; run repro.launch.dryrun first"}]
    emit(rows, "roofline")
    return rows


if __name__ == "__main__":
    run()
