"""Decoder-only transformer (dense / MoE / VLM) + whisper-style enc-dec.

Pure functions over parameter pytrees.  Layers are stacked along a leading
``L`` axis and executed with ``lax.scan`` so HLO size (and hence dry-run
compile time) is independent of depth.  The same ``decode_forward`` serves
prefill (S = prompt length, cache_len = 0) and SLED verification
(S = K draft tokens + 1, cache_len = committed length).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.kvcache import init_kv_cache, kv_cache_spec
from repro.models.layers import MeshContext, NO_MESH

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg, key) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(cfg.d_model, cfg.norm),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_norm(cfg.d_model, cfg.norm),
    }
    if cfg.family == "moe":
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _init_cross_layer(cfg, key) -> Params:
    p = _init_layer(cfg, key)
    ks = jax.random.split(key, 2)
    p["ln_x"] = L.init_norm(cfg.d_model, cfg.norm)
    p["xattn"] = L.init_attention(ks[1], cfg)
    return p


def _stack_init(init_fn, cfg, key, n) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(cfg, k))(keys)


def init_params(cfg, key, *, max_pos: int = 0) -> Params:
    """``max_pos`` sizes the learned position table (non-RoPE archs only)."""
    k_emb, k_layers, k_head, k_enc = jax.random.split(key, 4)
    p: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(
            jnp.bfloat16
        ),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    layer_init = _init_cross_layer if cfg.is_encdec else _init_layer
    p["layers"] = _stack_init(layer_init, cfg, k_layers, cfg.num_layers)
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * 0.02
        ).astype(jnp.bfloat16)
    if not cfg.use_rope:
        p["pos_embed"] = (
            jax.random.normal(k_head, (max(max_pos, 1), cfg.d_model)) * 0.01
        ).astype(jnp.bfloat16)
    if cfg.is_encdec:
        ke1, ke2 = jax.random.split(k_enc)
        p["enc"] = {
            "pos_embed": (
                jax.random.normal(ke1, (cfg.encoder_seq, cfg.d_model)) * 0.01
            ).astype(jnp.bfloat16),
            "layers": _stack_init(_init_layer, cfg, ke2, cfg.encoder_layers),
            "final_norm": L.init_norm(cfg.d_model, cfg.norm),
        }
    return p


def init_params_spec(cfg, *, max_pos: int = 0):
    """ShapeDtypeStruct pytree with the same structure (dry-run, no alloc)."""
    return jax.eval_shape(lambda k: init_params(cfg, k, max_pos=max_pos), jax.random.key(0))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block(
    x: jax.Array,
    lp: Params,
    cfg,
    ctx: MeshContext,
    *,
    positions: jax.Array,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
    cache_layer: Optional[jax.Array] = None,
    uniform_start: Optional[jax.Array] = None,
    causal: bool = True,
    cross: Optional[Tuple[jax.Array, jax.Array]] = None,
    cross_len: Optional[jax.Array] = None,
    cross_layer: Optional[jax.Array] = None,
    attn_chunk: int = 1024,
    flash_remat: bool = False,
    slots: Optional[jax.Array] = None,
    kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, ...]], jax.Array]:
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    a, new_kv = L.attention_block(
        h, lp["attn"], cfg,
        positions=positions, kv_cache=kv, cache_len=cache_len,
        cache_layer=cache_layer, uniform_start=uniform_start,
        causal=causal, chunk=attn_chunk, ctx=ctx, flash_remat=flash_remat,
        slots=slots, kv_scales=kv_scales,
    )
    x = x + a
    if cross is not None:
        h = L.apply_norm(x, lp["ln_x"], cfg.norm)
        a, _ = L.attention_block(
            h, lp["xattn"], cfg,
            positions=positions, cross_kv=cross, cross_len=cross_len,
            cross_layer=cross_layer, chunk=attn_chunk, slots=slots,
        )
        x = x + a
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        m, aux = L.moe_block(h, lp["moe"], cfg, ctx)
    else:
        m = L.mlp_block(h, lp["mlp"], cfg)
    return x + m, new_kv, aux


# ---------------------------------------------------------------------------
# Training / full-sequence forward (no cache)
# ---------------------------------------------------------------------------


def forward(
    cfg,
    params: Params,
    tokens: jax.Array,  # (B, S) int32
    ctx: MeshContext = NO_MESH,
    *,
    embeds_prefix: Optional[jax.Array] = None,  # (B, P, d) VLM patch embeddings
    enc_frames: Optional[jax.Array] = None,  # (B, F, d) whisper stub frontend
    remat: bool = False,
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B, S_total, d), aux_loss). Use lm_head() for logits."""
    x = L.embed_lookup(params["embed"], tokens, ctx)
    if embeds_prefix is not None:
        x = jnp.concatenate([embeds_prefix.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions]

    cross = cross_len = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, enc_frames, ctx, attn_chunk=attn_chunk)
        cross_len = jnp.full((B,), enc_out.shape[1], jnp.int32)
    else:
        enc_out = None

    def body(carry, lp):
        h, aux = carry
        if cfg.is_encdec:
            # cross K/V are layer-specific projections of the shared enc_out
            ck = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
            cv = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
            h, _, a = _block(
                h, lp, cfg, ctx, positions=positions,
                cross=(ck, cv), cross_len=cross_len, attn_chunk=attn_chunk,
                flash_remat=remat,
            )
        else:
            h, _, a = _block(h, lp, cfg, ctx, positions=positions,
                             attn_chunk=attn_chunk, flash_remat=remat)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    return x, aux


def encode(cfg, params, frames: jax.Array, ctx: MeshContext = NO_MESH, *, attn_chunk=1024):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    enc = params["enc"]
    B, F, _ = frames.shape
    x = frames.astype(jnp.bfloat16) + enc["pos_embed"][None, :F]
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(h, lp):
        h, _, _ = _block(h, lp, cfg, ctx, positions=positions, causal=False,
                         attn_chunk=attn_chunk)
        return h, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.apply_norm(x, enc["final_norm"], cfg.norm)


def lm_head(cfg, params: Params, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Cache-based forward: prefill + SLED verification
# ---------------------------------------------------------------------------


def make_cache(cfg, batch: int, max_len: int, *, spec_only: bool = False,
               attn_chunk: int = 1024, enc_len: int = 0, kv_dtype=jnp.bfloat16):
    """Cache buffer rounded up to a multiple of the attention chunk.

    ``kv_dtype=jnp.int8`` halves the cache stream/footprint (layers.kv_quant).
    """
    max_len = -(-max_len // attn_chunk) * attn_chunk
    fn = kv_cache_spec if spec_only else init_kv_cache
    cache = fn(cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim,
               dtype=kv_dtype)
    if kv_dtype == jnp.int8:
        # per-(layer, row, kv-head) dequant scales, fixed at prefill (see
        # layers.kv_fresh_scale); batch on axis 1 like every cache leaf, so
        # they ride gather_slots/scatter_slots/export untouched
        sshp = (cfg.num_layers, batch, cfg.num_kv_heads)
        mk = (lambda: jax.ShapeDtypeStruct(sshp, jnp.float32)) if spec_only \
            else (lambda: jnp.ones(sshp, jnp.float32))
        cache["k_scale"] = mk()
        cache["v_scale"] = mk()
    if cfg.is_encdec:
        shp = (cfg.num_layers, batch, enc_len or cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        if spec_only:
            cache["cross_k"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
            cache["cross_v"] = jax.ShapeDtypeStruct(shp, jnp.bfloat16)
        else:
            cache["cross_k"] = jnp.zeros(shp, jnp.bfloat16)
            cache["cross_v"] = jnp.zeros(shp, jnp.bfloat16)
    return cache


def decode_forward(
    cfg,
    params: Params,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,  # (B, S_new)
    ctx: MeshContext = NO_MESH,
    *,
    embeds: Optional[jax.Array] = None,  # override token embedding (VLM prefill)
    attn_chunk: int = 1024,
    uniform: bool = False,  # all rows share one insert position (padded static batch)
    slots: Optional[jax.Array] = None,  # (B,) cache is a PagedKVCache pool;
    # batch row b runs against pool row slots[b] (continuous batching)
) -> Tuple[jax.Array, Dict[str, jax.Array], jax.Array]:
    """Run S_new tokens against the cache starting at ``cache['length']``.

    Returns (hidden (B, S_new, d), cache', aux).  ``cache'`` has the new K/V
    written but ``length`` unchanged — callers commit via kvcache.rollback
    (for SLED: after the acceptance count is known).

    With ``slots``, cache leaves keep their pool shape (L, n_pool, S, H, D)
    end to end: per-row lengths come from ``length[slots]``, the K+1 fresh
    K/V rows are scattered straight into pool rows ``slots``, and attention
    streams slot-indexed chunks out of the stacked pool — no dense gathered
    sub-cache and no per-layer write-back ever exist.  This is the XLA
    mirror of the Pallas ``verify_attention_paged`` kernel's addressing.
    """
    x = L.embed_lookup(params["embed"], tokens, ctx) if embeds is None else embeds.astype(jnp.bfloat16)
    B, S, _ = x.shape
    cache_len = cache["length"] if slots is None else jnp.take(cache["length"], slots, axis=0)
    positions = cache_len[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions]
    cross_len = None
    if cfg.is_encdec:
        cross_len = jnp.full((B,), cache["cross_k"].shape[2], jnp.int32)
    uniform_start = cache_len[0] if uniform else None

    # fori_loop carrying the FULL cache buffers, updated in place: a scan
    # with cache xs/ys double-buffers the whole KV cache (2x HBM for the
    # largest tensor of the serving path).  Only the S new K/V rows are
    # scattered in, and attention streams chunks straight from the stacked
    # buffer — per-step cache traffic is one read + an O(B*S_new) write,
    # which is the roofline minimum for verification.
    def idx(a, l):
        return jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)

    quant = "k_scale" in cache  # int8 cache: thread the scale leaves too

    def body(l, carry):
        # slice the layer's cache out, append + attend, write back in place.
        # (Streaming chunks straight from the stacked buffer inside the
        # flash scan re-materialises the stack as a while-loop operand on
        # some backends — the per-layer slice is the portable fast path;
        # the "split" cache layout below removes even this copy.)
        if quant:
            h, k_all, v_all, ksc, vsc, aux = carry
        else:
            h, k_all, v_all, aux = carry
            ksc = vsc = None
        lp = jax.tree.map(lambda a: idx(a, l), params["layers"])
        cross = (idx(cache["cross_k"], l), idx(cache["cross_v"], l)) if cfg.is_encdec else None
        if slots is not None:
            # pool-resident path: hand the whole stacked pool to the block
            # (cache_layer addressing); fresh rows scatter into slot rows,
            # attention slot-indexes its chunks, nothing is written back
            # wholesale — the carry is updated only at the fresh rows.
            h, new_kv, a = _block(
                h, lp, cfg, ctx, positions=positions, kv=(k_all, v_all),
                cache_len=cache_len, cache_layer=l, slots=slots,
                cross=cross, cross_len=cross_len, attn_chunk=attn_chunk,
                kv_scales=(ksc, vsc) if quant else None,
            )
            if quant:
                return (h, new_kv[0], new_kv[1], new_kv[2], new_kv[3], aux + a)
            return (h, new_kv[0], new_kv[1], aux + a)
        h, new_kv, a = _block(
            h, lp, cfg, ctx, positions=positions, kv=(idx(k_all, l), idx(v_all, l)),
            cache_len=cache_len, uniform_start=uniform_start,
            cross=cross, cross_len=cross_len, attn_chunk=attn_chunk,
            kv_scales=(idx(ksc, l), idx(vsc, l)) if quant else None,
        )
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, new_kv[0], l, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, new_kv[1], l, 0)
        if quant:
            ksc = jax.lax.dynamic_update_index_in_dim(ksc, new_kv[2], l, 0)
            vsc = jax.lax.dynamic_update_index_in_dim(vsc, new_kv[3], l, 0)
            return (h, k_all, v_all, ksc, vsc, aux + a)
        return (h, k_all, v_all, aux + a)

    aux0 = jnp.zeros((), jnp.float32)
    if quant:
        init = (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"], aux0)
    else:
        init = (x, cache["k"], cache["v"], aux0)
    out = jax.lax.fori_loop(0, cfg.num_layers, body, init)
    if quant:
        x, k_all, v_all, ksc, vsc, aux = out
        new_cache = {**cache, "k": k_all, "v": v_all, "k_scale": ksc, "v_scale": vsc}
    else:
        x, k_all, v_all, aux = out
        new_cache = {**cache, "k": k_all, "v": v_all}
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    return x, new_cache, aux


def prefill(
    cfg,
    params: Params,
    tokens: jax.Array,  # (B, S_prompt)
    cache: Dict[str, jax.Array],
    ctx: MeshContext = NO_MESH,
    *,
    embeds_prefix: Optional[jax.Array] = None,
    enc_frames: Optional[jax.Array] = None,
    attn_chunk: int = 1024,
    uniform: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fill the cache from a prompt; returns (last-position logits, cache)."""
    B = tokens.shape[0]
    if cfg.is_encdec:
        enc_out = encode(cfg, params, enc_frames, ctx, attn_chunk=attn_chunk)
        cks, cvs = _map_layers_xkv(params["layers"], enc_out, cfg, B)
        cache = {**cache, "cross_k": cks, "cross_v": cvs}
    embeds = None
    if embeds_prefix is not None:
        tok_emb = params["embed"][tokens]
        embeds = jnp.concatenate([embeds_prefix.astype(tok_emb.dtype), tok_emb], axis=1)
    h, cache, _ = decode_forward(cfg, params, cache, tokens, ctx, embeds=embeds,
                                 attn_chunk=attn_chunk, uniform=uniform)
    S_total = h.shape[1]
    cache["length"] = cache["length"] + S_total
    logits = lm_head(cfg, params, h[:, -1:, :])
    return logits[:, 0], cache


def _map_layers_xkv(layers, enc_out, cfg, B):
    def one(lp):
        ck = (enc_out @ lp["xattn"]["wk"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
        cv = (enc_out @ lp["xattn"]["wv"]).reshape(B, -1, cfg.num_kv_heads, cfg.head_dim)
        return ck, cv

    return jax.lax.map(one, layers)
