"""Telemetry: metrics registry, traces, flight recorder, stats merging, and
the codec v3 server-timing / telemetry-payload wire fields."""

import dataclasses
import json
import logging

import numpy as np
import pytest

from repro import telemetry
from repro.core.engine import EngineStats
from repro.transport import codec
from repro.transport.client import ClientStats


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Telemetry state is process-global: every test starts and ends off+empty."""
    telemetry.enable(False)
    telemetry.registry().reset()
    yield
    telemetry.enable(False)
    telemetry.registry().reset()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = telemetry.MetricsRegistry()
    c = reg.counter("rounds_total")
    c.inc()
    c.inc(2.0)
    assert c.value == 3.0
    g = reg.gauge("queue_depth")
    g.set(5)
    g.inc(-2)
    assert g.value == 3.0
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 10.0):
        h.observe(v)
    assert h.count == 3
    assert h.counts == [1, 1, 1]  # one per bucket incl. +Inf
    assert h.sum == pytest.approx(10.55)


def test_registry_get_or_create_and_kind_conflict():
    reg = telemetry.MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.counter("x", labels={"a": 1}) is not reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    reg.reset()
    assert len(reg) == 0
    reg.gauge("x")  # after reset the name is free for another kind


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        telemetry.Histogram("bad", buckets=(1.0, 0.5))


def test_histogram_quantiles():
    h = telemetry.Histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5,) * 50 + (1.5,) * 50:
        h.observe(v)
    assert 0.0 < h.quantile(0.25) <= 1.0
    assert 1.0 < h.quantile(0.95) <= 2.0
    # +Inf overflow clamps to the last finite bound
    h2 = telemetry.Histogram("lat2", buckets=(1.0,))
    h2.observe(100.0)
    assert h2.quantile(0.99) == 1.0
    assert telemetry.Histogram("lat3", buckets=(1.0,)).quantile(0.5) == 0.0


def test_snapshot_shape_and_json_safety():
    reg = telemetry.MetricsRegistry()
    reg.counter("c").inc()
    reg.gauge("g", labels={"replica": 0}).set(2)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["c"] == 1.0
    assert snap["gauges"]['g{replica="0"}'] == 2.0
    h = snap["histograms"]["h"]
    assert h["count"] == 1 and h["sum"] == 1.5
    assert h["buckets"] == [[1.0, 0], [2.0, 1], ["+Inf", 1]]


def test_exposition_text_format():
    reg = telemetry.MetricsRegistry()
    reg.counter("rounds_total", help="total rounds").inc(4)
    reg.histogram("verify_seconds", buckets=(0.5, 1.0)).observe(0.7)
    text = reg.exposition()
    assert "# HELP repro_rounds_total total rounds" in text
    assert "# TYPE repro_rounds_total counter" in text
    assert "repro_rounds_total 4.0" in text
    assert 'repro_verify_seconds_bucket{le="0.5"} 0' in text
    assert 'repro_verify_seconds_bucket{le="+Inf"} 1' in text
    assert "repro_verify_seconds_count 1" in text


# ---------------------------------------------------------------------------
# enable gating: spans, observe, count
# ---------------------------------------------------------------------------


def test_span_is_noop_when_disabled():
    s1, s2 = telemetry.span("a"), telemetry.span("b")
    assert s1 is s2  # the shared null span: zero allocation when off
    with s1:
        pass
    assert len(telemetry.registry()) == 0


def test_span_records_when_enabled():
    telemetry.enable(True)
    with telemetry.span("engine_verify_seconds"):
        pass
    h = telemetry.registry().histogram("engine_verify_seconds")
    assert h.count == 1
    assert h.sum >= 0.0


def test_observe_and_count_gated():
    telemetry.observe("lat", 0.5)
    telemetry.count("c")
    assert len(telemetry.registry()) == 0
    telemetry.enable(True)
    telemetry.observe("lat", 0.5)
    telemetry.count("c", 2)
    assert telemetry.registry().counter("c").value == 2.0
    assert telemetry.registry().histogram("lat").count == 1


# ---------------------------------------------------------------------------
# trace events + flight recorder
# ---------------------------------------------------------------------------


def test_trace_event_round_trip():
    ev = telemetry.TraceEvent(
        device_id=3, round=7, t=1.25, k=4, n_accepted=2, n_commit=3,
        queue_s=0.5, verify_s=0.25, wire_s=0.125, draft_s=0.0625,
        replica=1, fallback=True,
    )
    d = ev.to_json()
    assert telemetry.TraceEvent.from_json(d) == ev
    # unknown keys (a newer producer) are ignored, not fatal
    d["future_field"] = 42
    assert telemetry.TraceEvent.from_json(d) == ev


def test_flight_recorder_is_bounded():
    fr = telemetry.FlightRecorder(capacity=4)
    fr.extend(
        telemetry.TraceEvent(device_id=0, round=i, t=float(i), k=1,
                             n_accepted=1, n_commit=2)
        for i in range(10)
    )
    assert len(fr) == 4
    rounds = [ev.round for ev in fr.events()]
    assert rounds == [6, 7, 8, 9]  # oldest evicted, dump oldest-first
    assert [d["round"] for d in fr.dump()] == rounds
    fr.clear()
    assert len(fr) == 0
    with pytest.raises(ValueError):
        telemetry.FlightRecorder(capacity=0)


# ---------------------------------------------------------------------------
# stats merge edge cases
# ---------------------------------------------------------------------------


def _engine_stats(**kw) -> EngineStats:
    base = dict(
        wstgr=0.0, per_device_rate=0.0, server_busy_frac=0.0, rounds=0,
        timeouts=0, fallback_tokens=0, mean_batch_fill=0.0,
        mean_round_latency=0.0, server_rounds_per_s=0.0,
    )
    base.update(kw)
    return EngineStats(**base)


def test_engine_stats_merge_empty_raises():
    with pytest.raises(ValueError):
        EngineStats.merge([])


def test_engine_stats_merge_single_is_identity_copy():
    st = _engine_stats(wstgr=10.0, per_device_rate=5.0, rounds=3,
                      mean_batch_fill=2.0, acceptance_rate=0.5)
    merged = EngineStats.merge([st])
    assert merged == st
    assert merged is not st  # a copy: mutating it can't corrupt the source


def test_engine_stats_merge_idle_replicas():
    """All-idle replicas (0 rounds) must not divide by zero; means fall back
    to the plain average."""
    a = _engine_stats(mean_batch_fill=2.0)
    b = _engine_stats(mean_batch_fill=4.0)
    merged = EngineStats.merge([a, b])
    assert merged.rounds == 0
    assert merged.mean_batch_fill == pytest.approx(3.0)
    assert merged.wstgr == 0.0


def test_engine_stats_merge_weighted_by_rounds():
    a = _engine_stats(wstgr=10.0, per_device_rate=5.0, rounds=30,
                      mean_batch_fill=3.0, acceptance_rate=0.9)
    idle = _engine_stats()  # an empty-field replica rides along harmlessly
    merged = EngineStats.merge([a, idle])
    assert merged.wstgr == 10.0
    assert merged.mean_batch_fill == pytest.approx(3.0)
    assert merged.acceptance_rate == pytest.approx(0.9)
    assert merged.rounds == 30


def test_client_stats_merge_empty_and_single():
    empty = ClientStats.merge([])
    assert empty.device_id == -1 and empty.rounds == 0
    one = ClientStats(device_id=4, rounds=7, committed=24, k_final=3,
                      k_mean=2.5, wall_seconds=1.5)
    merged = ClientStats.merge([one])
    assert merged.rounds == 7 and merged.committed == 24
    assert merged.k_final == 3 and merged.k_mean == 2.5
    assert merged.wall_seconds == 1.5
    assert merged.device_id == -1  # merged records are fleet-level


def test_client_stats_merge_zero_token_streams():
    """Streams that never committed anything merge without division errors."""
    zeros = [ClientStats(device_id=i) for i in range(3)]
    merged = ClientStats.merge(zeros)
    assert merged.committed == 0 and merged.rounds == 0
    assert merged.k_mean == 0.0 and merged.wall_seconds == 0.0


# ---------------------------------------------------------------------------
# codec v3: server-timing fields + telemetry payload, bit-exact round trips
# ---------------------------------------------------------------------------


def _round_trip(msg):
    decoded, consumed = codec.decode_frame(codec.encode_frame(msg))
    assert consumed == len(codec.encode_frame(msg))
    return decoded


def test_verdict_carries_server_timing_bit_exact():
    # f32-representable values survive the wire without rounding
    v = codec.Verdict(
        device_id=2, seq=5, n_accepted=3,
        tokens=np.asarray([7, 8, 9, 10], np.int32), next_prev=10,
        accept_rate=0.75, queue_depth=2, queue_s=0.5, verify_s=0.25,
    )
    out = _round_trip(v)
    assert out.queue_s == 0.5 and out.verify_s == 0.25
    assert out.n_accepted == 3 and list(out.tokens) == [7, 8, 9, 10]


def test_verdict_timing_defaults_to_zero():
    out = _round_trip(codec.Verdict(
        device_id=0, seq=0, n_accepted=1, tokens=np.asarray([1], np.int32),
        next_prev=1, accept_rate=1.0, queue_depth=0,
    ))
    assert out.queue_s == 0.0 and out.verify_s == 0.0


def test_step_reply_verdict_rec_timing():
    rec = codec.VerdictRec(
        device_id=1, n_accepted=2, tokens=np.asarray([3, 4, 5], np.int32),
        next_prev=5, accept_rate=0.5, queue_depth=1,
        queue_s=0.125, verify_s=0.0625,
    )
    out = _round_trip(codec.StepReply(verdicts=(rec,), queue_depth=1,
                                      n_free=2, hint=None))
    got = out.verdicts[0]
    assert got.queue_s == 0.125 and got.verify_s == 0.0625
    assert list(got.tokens) == [3, 4, 5]


def test_replica_stats_telemetry_payload_round_trip():
    payload = {
        "snapshot": {
            "counters": {"engine_fallback_rounds_total": 2.0},
            "gauges": {},
            "histograms": {
                "engine_verify_seconds": {
                    "sum": 0.75, "count": 3, "mean": 0.25,
                    "p50": 0.25, "p95": 0.5,
                    "buckets": [[0.5, 2], ["+Inf", 3]],
                },
            },
        },
        "flight": [telemetry.TraceEvent(device_id=0, round=1, t=0.5, k=4,
                                        n_accepted=3, n_commit=4).to_json()],
    }
    msg = codec.ReplicaStats(
        stats_json=json.dumps({"rounds": 3}),
        telemetry_json=json.dumps(payload),
    )
    out = _round_trip(msg)
    assert out.stats_json == msg.stats_json  # bit-exact: strings, not floats
    assert out.telemetry_json == msg.telemetry_json
    assert json.loads(out.telemetry_json) == payload


def test_replica_stats_empty_telemetry_default():
    out = _round_trip(codec.ReplicaStats(stats_json='{"rounds": 1}'))
    assert out.telemetry_json == ""


# ---------------------------------------------------------------------------
# logging setup
# ---------------------------------------------------------------------------


def test_setup_logging_idempotent_and_leveled():
    root = telemetry.setup_logging("debug")
    assert root.name == "repro"
    assert root.level == logging.DEBUG
    n = len(root.handlers)
    telemetry.setup_logging("info")
    assert len(root.handlers) == n  # no handler stacking
    assert root.level == logging.INFO
    assert not root.propagate
    with pytest.raises(ValueError):
        telemetry.setup_logging("chatty")


# ---------------------------------------------------------------------------
# end-to-end: tokens are identical with telemetry on, and the payload parses
# ---------------------------------------------------------------------------


def _tiny_spec(**kw):
    from repro.api import ModelSpec, ServeSpec

    return ServeSpec(
        backend="engine",
        model=ModelSpec(vocab_size=64, draft_layers=1, seed=0),
        devices=2, prompt_len=6, max_new=6, k_max=3, max_len=32,
        **kw,
    )


def test_serve_token_identical_with_telemetry_on():
    from repro.api import System, build_models

    models = build_models(_tiny_spec().model)
    telemetry.enable(False)
    off = System.build(_tiny_spec(), models=models).serve()
    on_sys = System.build(_tiny_spec(telemetry=True), models=models,
                          steps=None, kit=None)
    assert telemetry.enabled()  # the spec flipped collection on
    on = on_sys.serve()
    assert on.outputs == off.outputs  # observation-only: streams identical
    # the payload is a parseable snapshot with the engine spans populated
    snap = json.loads(json.dumps(on.telemetry))["snapshot"]
    assert snap["histograms"]["engine_verify_seconds"]["count"] > 0
    assert snap["histograms"]["engine_round_latency_seconds"]["count"] > 0
    # per-session traces attribute every round
    for s in on.sessions:
        assert len(s.trace) == s.rounds
        assert all(ev.verify_s > 0.0 for ev in s.trace)
    assert all(not s.trace for s in off.sessions)
    # registry text exposition renders and is prefixed
    text = telemetry.registry().exposition()
    assert "repro_engine_verify_seconds_count" in text
