"""Closed-loop adaptive speculation length (bounded AIMD).

SLED's ConfigSpec picks a static draft length per device class offline; the
heterogeneous-edge result (PAPERS.md, arXiv:2510.11331) is that the right
``k`` drifts at runtime with acceptance and server congestion.  The v2
Verdict frames feed back exactly those two signals — the round's
draft-acceptance ratio (per-round, so regime shifts register immediately;
this controller's EWMA does the smoothing) and the serving replica's queue
depth — and this controller closes the loop device-side:

  * additive increase  — acceptance high AND the replica queue shallow:
    speculation is paying, draft one more token per round (up to ``k_max``);
  * multiplicative decrease — acceptance low OR the queue deep: wrong drafts
    (or an oversubscribed replica) burn server verify compute, so halve the
    round length (down to ``k_min``).

AIMD keeps the control stable under the same argument as congestion control:
increases probe linearly, wrong guesses back off geometrically, and the
bounds make the worst case exactly the fixed-``k`` policies it replaces
(``k_min == k_max`` degenerates to fixed).  Acceptance is EWMA-smoothed so a
single unlucky round doesn't collapse ``k``.

Host-side and deterministic: the jitted draft scan always runs the fixed
``k_max`` shape and the proposal is truncated to ``k`` host-side
(EdgeDevice.draft(k=...)), so adapting never recompiles anything.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro import telemetry


@dataclasses.dataclass
class SpecLenController:
    """Bounded AIMD controller for the per-device speculation length ``k``.

    ``update(accept_rate, queue_depth)`` is called once per verdict and
    returns the length to draft next round.  All thresholds are plain
    constructor knobs so benchmarks can sweep them (ConfigSpec-style, but
    online).
    """

    k_max: int
    k_min: int = 1
    k_init: Optional[int] = None  # None: start at k_max (optimistic probe)
    increase: int = 1  # additive step up
    decrease: float = 0.5  # multiplicative back-off factor
    accept_hi: float = 0.7  # smoothed acceptance to justify a longer round
    accept_lo: float = 0.4  # below this, drafts are burning verify compute
    queue_hi: int = 2  # replica queue depth that reads as congestion
    ewma: float = 0.5  # smoothing on the acceptance feedback

    def __post_init__(self) -> None:
        if not (1 <= self.k_min <= self.k_max):
            raise ValueError(f"need 1 <= k_min <= k_max, got [{self.k_min}, {self.k_max}]")
        self.k = min(self.k_init or self.k_max, self.k_max)
        self.k = max(self.k, self.k_min)
        self._acc: Optional[float] = None
        self.updates = 0
        self.increases = 0
        self.decreases = 0

    @property
    def smoothed_accept(self) -> float:
        return self._acc if self._acc is not None else 1.0

    def update(self, accept_rate: float, queue_depth: int) -> int:
        """One feedback observation -> the next round's draft length."""
        a = float(accept_rate)
        self._acc = a if self._acc is None else self.ewma * a + (1 - self.ewma) * self._acc
        self.updates += 1
        congested = queue_depth > self.queue_hi
        if congested or self._acc < self.accept_lo:
            new_k = max(self.k_min, int(self.k * self.decrease))
            if new_k < self.k:
                self.decreases += 1
                telemetry.count("kctl_decrease_total")
            self.k = new_k
        elif self._acc >= self.accept_hi:
            new_k = min(self.k_max, self.k + self.increase)
            if new_k > self.k:
                self.increases += 1
                telemetry.count("kctl_increase_total")
            self.k = new_k
        telemetry.observe("kctl_k", self.k, buckets=telemetry.K_BUCKETS)
        return self.k


def make_controller(kctl: str, *, k_max: int, **kw) -> Optional[SpecLenController]:
    """``adaptive`` -> a controller, ``fixed`` -> None (draft k_max always)."""
    if kctl == "fixed":
        return None
    if kctl == "adaptive":
        return SpecLenController(k_max=k_max, **kw)
    raise ValueError(f"unknown kctl {kctl!r} (fixed | adaptive)")


@dataclasses.dataclass
class ConfidenceController:
    """Bounded additive controller for the drafting confidence ``c_th``.

    The dual of :class:`SpecLenController`: where ``k`` caps how MANY tokens
    a round may draft, ``c_th`` decides how SURE the draft model must be to
    keep going (Eq. 1 — drafting stops early once the proposal's confidence
    drops below the threshold).  Static since PR 4; this closes the loop from
    the same v2 Verdict feedback:

      * acceptance high AND the replica queue shallow — the draft model is
        trustworthy, so LOWER the bar and let rounds run deeper;
      * acceptance low OR the queue deep — low-confidence speculation is
        burning server verify compute, so RAISE the bar and only ship tokens
        the draft model is sure about.

    Additive steps in both directions (c_th lives on a bounded interval, so
    the AIMD asymmetry that stabilizes ``k`` is unnecessary); acceptance is
    EWMA-smoothed exactly like the k controller.  ``c_th`` feeds the jitted
    draft scan as a traced scalar argument, so adapting never recompiles.
    """

    c_init: float = 0.3
    c_min: float = 0.0
    c_max: float = 0.95
    step: float = 0.05  # additive step in both directions
    accept_hi: float = 0.75  # above: relax the bar, draft deeper rounds
    accept_lo: float = 0.45  # below: tighten, only confident tokens go out
    queue_hi: int = 2  # replica queue depth that reads as congestion
    ewma: float = 0.5  # smoothing on the acceptance feedback
    device_id: int = -1  # labels the per-device telemetry gauge (-1: unlabeled)

    def __post_init__(self) -> None:
        if not (0.0 <= self.c_min <= self.c_max <= 1.0):
            raise ValueError(
                f"need 0 <= c_min <= c_max <= 1, got [{self.c_min}, {self.c_max}]")
        self.c = min(max(self.c_init, self.c_min), self.c_max)
        self._acc: Optional[float] = None
        self.updates = 0
        self.raises = 0
        self.lowers = 0
        self._c_sum = 0.0

    @property
    def smoothed_accept(self) -> float:
        return self._acc if self._acc is not None else 1.0

    @property
    def c_mean(self) -> float:
        return self._c_sum / self.updates if self.updates else self.c

    def update(self, accept_rate: float, queue_depth: int) -> float:
        """One feedback observation -> the next round's confidence bar."""
        a = float(accept_rate)
        self._acc = a if self._acc is None else self.ewma * a + (1 - self.ewma) * self._acc
        self.updates += 1
        congested = queue_depth > self.queue_hi
        if congested or self._acc < self.accept_lo:
            new_c = min(self.c_max, self.c + self.step)
            if new_c > self.c:
                self.raises += 1
                telemetry.count("cctl_raise_total")
            self.c = new_c
        elif self._acc >= self.accept_hi:
            new_c = max(self.c_min, self.c - self.step)
            if new_c < self.c:
                self.lowers += 1
                telemetry.count("cctl_lower_total")
            self.c = new_c
        self._c_sum += self.c
        telemetry.observe("cctl_c_th", self.c, buckets=telemetry.C_TH_BUCKETS)
        if telemetry.enabled():
            telemetry.registry().gauge(
                "client_c_th",
                labels={"device": str(self.device_id)} if self.device_id >= 0 else None,
            ).set(self.c)
        return self.c


def make_confidence_controller(
    cctl: str, *, c_init: float, device_id: int = -1, **kw
) -> Optional[ConfidenceController]:
    """``adaptive`` -> a controller seeded at the spec's c_th, ``fixed`` -> None."""
    if cctl == "fixed":
        return None
    if cctl == "adaptive":
        return ConfidenceController(c_init=c_init, device_id=device_id, **kw)
    raise ValueError(f"unknown cctl {cctl!r} (fixed | adaptive)")
