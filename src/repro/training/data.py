"""Synthetic data pipeline: deterministic, shardable, restart-safe.

Real deployments swap in a tokenized corpus reader; the interface is the
same: ``batch_at(step)`` is a pure function of (seed, step, shape), so a
restarted/elastically-rescaled job regenerates exactly the batches it would
have seen — this is what makes checkpoint-resume bitwise reproducible and
straggler re-dispatch safe.

The generator is a Markov-ish token process (not uniform noise) so that
cross-entropy actually decreases during the example training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import numpy as np

from repro.models.model_zoo import frontend_stub


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_clusters: int = 32   # structure level of the synthetic distribution
    mode: str = "cluster"  # cluster | markov
    det_frac: float = 0.85  # markov mode: P(next token is the deterministic
    # successor) — controls the achievable model confidence, which the Fig. 3
    # benchmark needs spread across (0, 1)


def _batch_tokens(dcfg: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng((dcfg.seed << 20) ^ step)
    B, S, V = dcfg.global_batch, dcfg.seq_len, dcfg.vocab_size
    if dcfg.mode == "markov":
        # mostly-deterministic chain: next = f(cur) w.p. det_frac else uniform
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, (B,))
        det = rng.random((B, S)) < dcfg.det_frac
        jumps = rng.integers(0, V, (B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1] * 31 + 7) % V
            toks[:, t] = np.where(det[:, t], nxt, jumps[:, t])
        return toks.astype(np.int32)
    k = min(dcfg.n_clusters, V)
    # cluster-conditioned token stream: p(next | cluster) is low-entropy
    clusters = rng.integers(0, k, (B, 1))
    drift = rng.integers(0, k, (B, S)) == 0
    clusters = (clusters + np.cumsum(drift, axis=1)) % k
    centers = (clusters * (V // k)) % V
    offsets = rng.integers(0, max(V // k, 1), (B, S))
    return ((centers + offsets) % V).astype(np.int32)


def batch_at(dcfg: DataConfig, step: int, model_cfg=None) -> Dict[str, np.ndarray]:
    toks = _batch_tokens(dcfg, step)
    tokens, labels = toks[:, :-1], toks[:, 1:].astype(np.int32)
    batch = {"tokens": tokens, "labels": labels}
    if model_cfg is not None and model_cfg.family in ("encdec", "vlm"):
        stub = frontend_stub(model_cfg, dcfg.global_batch,
                             key=jax.random.key(dcfg.seed ^ (step + 1)))
        batch["frontend"] = np.asarray(stub)
    return batch


def data_iterator(dcfg: DataConfig, start_step: int = 0, model_cfg=None) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(dcfg, step, model_cfg)
        step += 1
