"""Single-replica verification engine: EngineCore + AdmissionControl composed.

This is the real-model counterpart of serving/simulator.py's server loop
(SLED §III-B): verification requests from heterogeneous edge devices queue
in a BatchPlanner, and whenever the policy fires the engine verifies the
scheduled SUBSET of device streams in one forward pass — partial fills,
heterogeneous draft lengths, devices joining and leaving mid-stream.

Layering (the engine-core refactor):

  core/engine.py      EngineCore — the pure verify stepper: PagedKVCache row
                      pool, jitted prefill/verify/extend steps (a shareable
                      VerifySteps bundle), bucket selection, warmup.
  core/admission.py   AdmissionControl — stream registry, one-inflight-round
                      queue discipline, BatchPlanner dispatch policies.
  here                ServerEngine — composes the two behind the original
                      single-replica API, and adds the serving stats.
  cluster/router.py   Router — N ServerEngine replicas behind a placement
                      policy (admission becomes a placement decision).

Per-round and aggregate stats mirror serving/simulator.SimResult field names
so discrete-event predictions can be cross-checked against real-model runs
(benchmarks/wstgr.py --engine does exactly that).

EdgeDeviceKit/EdgeDevice are the host-side stand-ins for device drafting
loops (batch-1 draft model per device, shared jitted step), used by
launch/serve.py, transport/client.py, and the tests.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import drafting, verification
from repro.core.admission import AdmissionControl, DeviceStream
from repro.core.engine import (
    EngineCore,
    EngineStats,
    RoundStats,
    Verdict,
    VerifySteps,
)
from repro.models.kvcache import SlotExhausted
from repro.models.layers import NO_MESH, MeshContext

__all__ = [
    "DeviceStream",
    "EdgeDevice",
    "EdgeDeviceKit",
    "EngineStats",
    "RoundStats",
    "ServerEngine",
    "Verdict",
]

log = logging.getLogger(__name__)


class ServerEngine:
    """Admission + step loop for ONE replica: PagedKVCache pool, BatchPlanner
    policies, bucketed slot-indexed verification.

    Typical driver loop (see launch/serve.py)::

        engine = ServerEngine(target, tp, n_slots=8, max_len=256, k_max=4)
        engine.admit(device_id, prompt, now)          # joins a free slot
        engine.submit(device_id, draft_tokens, now)   # device -> server hop
        verdicts = engine.step(now)                   # policy may dispatch
        engine.retire(device_id)                      # frees the slot

    Pass a shared :class:`~repro.core.engine.VerifySteps` via ``steps`` to
    make replicas of the same model share compiled executables
    (cluster/router.py does this for its whole replica set).
    """

    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        n_slots: int,
        max_len: int,
        k_max: int,
        policy: str = "continuous",
        batch_size: Optional[int] = None,
        max_wait: float = 0.050,
        straggler_timeout: float = 1.0,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
        ctx: MeshContext = NO_MESH,
        buckets: Optional[Sequence[int]] = None,
        paged_attention: bool = True,
        steps: Optional[VerifySteps] = None,
        kv_dtype: Any = "bf16",
    ):
        cap = batch_size or n_slots
        self.core = EngineCore(
            model,
            params,
            n_slots=n_slots,
            max_len=max_len,
            k_max=k_max,
            greedy=greedy,
            temperature=temperature,
            attn_chunk=attn_chunk,
            ctx=ctx,
            buckets=buckets,
            batch_cap=cap,
            paged_attention=paged_attention,
            steps=steps,
            kv_dtype=kv_dtype,
        )
        self.admission = AdmissionControl(
            batch_size=cap,
            k_max=k_max,
            policy=policy,
            max_wait=max_wait,
            straggler_timeout=straggler_timeout,
            greedy=greedy,
        )
        self.k_max = k_max
        self.greedy = greedy
        self._batch_cap = cap
        self.round_log: List[RoundStats] = []
        # telemetry: full per-round trace (grows only while telemetry is on)
        # plus the bounded flight recorder that crash/eviction/drain dumps
        self.trace: List[telemetry.TraceEvent] = []
        self.flight = telemetry.FlightRecorder()
        self._round_seq: Dict[int, int] = {}  # device_id -> next round seq
        self._t0: Optional[float] = None
        self._t_last = 0.0
        self._committed_total = 0
        self._busy_seconds = 0.0
        self._latencies: List[float] = []
        self._drafted = 0
        self._accepted = 0
        self._fallback_tokens = 0
        self._fallback_rounds = 0

    # -- composition surface (back-compat aliases) ---------------------------

    @property
    def model(self):
        return self.core.model

    @property
    def params(self):
        return self.core.params

    @property
    def pool(self):
        return self.core.pool

    @property
    def steps(self) -> VerifySteps:
        return self.core.steps

    @property
    def paged_attention(self) -> bool:
        return self.core.paged_attention

    @property
    def kv_dtype(self) -> str:
        return self.core.kv_dtype

    @property
    def buckets(self):
        return self.core.buckets

    @property
    def compile_log(self):
        return self.core.compile_log

    @property
    def planner(self):
        return self.admission.planner

    @property
    def streams(self) -> Dict[int, DeviceStream]:
        return self.admission.streams

    @property
    def drafted_tokens(self) -> int:
        """Lifetime draft tokens verified (benchmark calibration surface)."""
        return self._drafted

    @property
    def accepted_tokens(self) -> int:
        """Lifetime draft tokens accepted (benchmark calibration surface)."""
        return self._accepted

    @property
    def _timeouts(self) -> int:
        return self.admission.timeouts

    @property
    def _streams_served(self) -> int:
        return self.admission.streams_served

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> Dict[int, float]:
        return self.core.warmup(buckets)

    # -- admission -----------------------------------------------------------

    def admit(self, device_id: int, prompt: jax.Array, now: float = 0.0) -> Optional[DeviceStream]:
        """Prefill ``prompt`` into a free pool slot; None when the pool is full
        (the device retries once a stream retires)."""
        if device_id in self.streams:
            raise ValueError(f"device {device_id} already admitted")
        try:
            slot = self.core.alloc_slot()
        except SlotExhausted:
            return None
        prev = self.core.prefill_slot(slot, prompt)
        stream = self.admission.register(device_id, slot, prev, now)
        if self._t0 is None:
            self._t0 = now
        return stream

    def retire(self, device_id: int) -> DeviceStream:
        """Stream finished (or left): free its slot for the next admission.
        Any still-queued request from the device is discarded."""
        stream = self.admission.release(device_id, served=True)
        self.core.free_slot(stream.slot)
        self._round_seq.pop(device_id, None)
        return stream

    # -- stream migration (cluster router) -----------------------------------

    def export_stream(self, device_id: int):
        """Detach a quiescent stream for migration to another replica.

        Returns ``(stream, row_cache)`` — the server-side stream state plus a
        bit-exact dense copy of its pool row.  Refuses while a request is in
        flight (the verdict must land first; the row would otherwise change
        under the copy)."""
        if self.admission.has_inflight(device_id):
            raise ValueError(f"device {device_id} has a request in flight; cannot migrate")
        row = self.core.export_row(self.streams[device_id].slot)
        stream = self.admission.release(device_id, served=False)
        self.core.free_slot(stream.slot)
        return stream, row

    def import_stream(self, stream: DeviceStream, row_cache) -> DeviceStream:
        """Adopt a stream exported from another replica: allocate a slot,
        install the row bit-identically, register the stream."""
        slot = self.core.alloc_slot()  # raises SlotExhausted when full
        self.core.import_row(slot, row_cache)
        stream.slot = slot
        self.admission.adopt(stream)
        if self._t0 is None:
            self._t0 = stream.admitted_at
        return stream

    # -- request queue -------------------------------------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        self.admission.submit(device_id, draft_tokens, now, draft_q=draft_q)

    def cancel_request(self, device_id: int) -> bool:
        """Withdraw the device's queued request (transport fallback protocol:
        the device timed out and released its drafts locally).  Returns False
        when nothing is queued — i.e. the request was already verified and a
        verdict is on its way, which the caller must treat as authoritative."""
        return self.admission.cancel(device_id)

    def force_extend(self, device_id: int, tokens: np.ndarray) -> int:
        """Append ``tokens`` to the stream unverified (§III-A fallback resync:
        the device already released them to the user).  Returns the stream's
        new prev token; the device drafts from there next round."""
        stream = self.streams[device_id]
        if self.admission.has_inflight(device_id):
            raise ValueError(f"device {device_id} still has a request in flight")
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size == 0:
            return stream.prev_token
        if toks.size > self.k_max + 1:
            raise ValueError(f"fallback run of {toks.size} exceeds k_max+1")
        # KV invariant: the last committed token is never in the cache, so we
        # feed [prev, t_1 .. t_{n-1}] and the new prev becomes t_n
        feed = np.concatenate([[stream.prev_token], toks[:-1]]).astype(np.int32)
        self.core.force_extend(stream.slot, feed)
        stream.committed.extend(int(t) for t in toks)
        stream.prev_token = int(toks[-1])
        self._committed_total += toks.size
        self._fallback_tokens += toks.size
        self._fallback_rounds += 1
        if telemetry.enabled():
            telemetry.count("engine_fallback_rounds_total")
            seq = self._round_seq.get(device_id, 0)
            self._round_seq[device_id] = seq + 1
            ev = telemetry.TraceEvent(
                device_id=device_id, round=seq, t=self._t_last,
                k=0, n_accepted=0, n_commit=toks.size, fallback=True,
            )
            self.trace.append(ev)
            self.flight.record(ev)
        return stream.prev_token

    def has_inflight(self, device_id: int) -> bool:
        """True while the device has a queued (unverdicted) request."""
        return self.admission.has_inflight(device_id)

    @property
    def queue_depth(self) -> int:
        return self.admission.queue_depth

    def next_event_hint(self, now: float) -> Optional[float]:
        """Earliest future planner deadline/straggler event (step-loop wake)."""
        return self.admission.next_event_hint(now)

    # -- the serving hot loop ------------------------------------------------

    def step(self, now: float) -> Optional[List[Verdict]]:
        """Ask the planner for a batch; if the policy fires, verify that row
        subset and commit.  Returns per-request verdicts, or None."""
        batch = self.admission.next_batch(now)
        if batch is None:
            return None
        prev, toks, qs, lens = batch.padded_arrays()
        slots = np.asarray(
            [self.streams[r.device_id].slot for r in batch.requests], np.int32
        )
        res, bucket, step_seconds = self.core.verify(
            slots,
            prev,
            toks,
            qs if any(r.draft_q is not None for r in batch.requests) else None,
            lens,
        )

        out_tokens = np.asarray(res.out_tokens)
        n_accepted = np.asarray(res.n_accepted)
        n_commit = np.asarray(res.n_commit)
        extra = np.asarray(res.extra_token)
        depth_after = self.queue_depth
        verdicts = []
        committed_round = 0
        traced = telemetry.enabled()
        for i, req in enumerate(batch.requests):
            stream = self.streams[req.device_id]
            self.admission.resolve(req.device_id)
            self._drafted += int(lens[i])
            self._accepted += int(n_accepted[i])
            stream.drafted += int(lens[i])
            stream.accepted += int(n_accepted[i])
            n = int(n_commit[i])
            toks_i = out_tokens[i, :n]
            stream.committed.extend(int(t) for t in toks_i)
            stream.prev_token = int(extra[i])
            stream.rounds += 1
            committed_round += n
            queue_s = now - req.arrival
            self._latencies.append(queue_s)
            verdicts.append(
                Verdict(
                    device_id=req.device_id,
                    # per-ROUND acceptance, not the lifetime ratio: a lifetime
                    # average takes O(rounds) to register a regime shift, so
                    # the device-side controller would keep burning k_max
                    # verify tokens long after drafts stopped landing (the
                    # client's EWMA does the smoothing)
                    n_accepted=int(n_accepted[i]),
                    tokens=toks_i,
                    next_prev=int(extra[i]),
                    accept_rate=int(n_accepted[i]) / max(int(lens[i]), 1),
                    queue_depth=depth_after,
                    # server-timing breakdown: populated unconditionally (two
                    # host floats per request) so the client-side attribution
                    # works whether or not this process collects telemetry
                    queue_s=queue_s,
                    verify_s=step_seconds,
                )
            )
            if traced:
                seq = self._round_seq.get(req.device_id, 0)
                self._round_seq[req.device_id] = seq + 1
                ev = telemetry.TraceEvent(
                    device_id=req.device_id, round=seq, t=now,
                    k=int(lens[i]), n_accepted=int(n_accepted[i]), n_commit=n,
                    queue_s=queue_s, verify_s=step_seconds,
                )
                self.trace.append(ev)
                self.flight.record(ev)
                telemetry.observe("engine_round_latency_seconds", queue_s + step_seconds)
                telemetry.observe("engine_k", int(lens[i]), buckets=telemetry.K_BUCKETS)
        self._busy_seconds += step_seconds
        self._committed_total += committed_round
        self._t_last = max(self._t_last, now)
        self.round_log.append(
            RoundStats(
                time=now,
                size=batch.size,
                bucket=bucket,
                queue_depth=depth_after,
                n_commit=committed_round,
                step_seconds=step_seconds,
            )
        )
        return verdicts

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        elapsed = max((now if now is not None else self._t_last) - (self._t0 or 0.0), 1e-9)
        fills = [r.size for r in self.round_log]
        n_streams = max(self._streams_served + len(self.streams), 1)
        return EngineStats(
            wstgr=self._committed_total / elapsed,
            per_device_rate=self._committed_total / n_streams / elapsed,
            server_busy_frac=self._busy_seconds / elapsed,
            rounds=len(self.round_log),
            timeouts=self._timeouts,
            fallback_tokens=self._fallback_tokens,  # transport resyncs land here
            mean_batch_fill=float(np.mean(fills)) if fills else 0.0,
            mean_round_latency=float(np.mean(self._latencies)) if self._latencies else 0.0,
            server_rounds_per_s=len(self.round_log) / elapsed,
            partial_rounds=sum(1 for r in self.round_log if r.size < self._batch_cap),
            streams_served=self._streams_served,
            acceptance_rate=self._accepted / max(self._drafted, 1),
            mean_queue_depth=(
                float(np.mean([r.queue_depth for r in self.round_log]))
                if self.round_log
                else 0.0
            ),
            fallback_rounds=self._fallback_rounds,
        )

    def telemetry_payload(self) -> dict:
        """This replica's telemetry as one JSON-shaped record: the process
        metrics snapshot plus the flight recorder's last-N rounds.  Empty
        while telemetry is off — this is what a worker ships back inside
        codec v3 ``ReplicaStats.telemetry_json``."""
        if not telemetry.enabled():
            return {}
        # refresh the pool capacity gauges at read time: telemetry may have
        # been switched on after engine construction, and `repro top` reads
        # kv_pool_bytes / bytes_per_slot off this snapshot per replica
        reg = telemetry.registry()
        reg.gauge("engine_kv_pool_bytes").set(float(self.pool.pool_bytes()))
        reg.gauge("engine_bytes_per_slot").set(float(self.pool.bytes_per_slot()))
        return {
            "snapshot": reg.snapshot(),
            "flight": self.flight.dump(),
        }


# ---------------------------------------------------------------------------
# Device side: batch-1 drafting loops sharing one jitted step
# ---------------------------------------------------------------------------


class EdgeDeviceKit:
    """Shared jitted draft/prefill steps for a fleet of batch-1 edge devices.

    One kit per (draft model, drafting config): every EdgeDevice spawned from
    it reuses the same compiled functions, so a 64-device fleet costs the
    same compilation as one device.
    """

    def __init__(
        self,
        draft_model: Any,
        draft_params: Any,
        *,
        k_max: int,
        c_th: float = 0.0,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
    ):
        self.model = draft_model
        self.params = draft_params
        self.k_max = k_max
        self.c_th = float(c_th)
        self._prefill = jax.jit(
            verification.make_prefill_step(draft_model, attn_chunk=attn_chunk)
        )
        # c_th rides as a TRACED scalar argument (it only feeds a jnp compare
        # inside the scan), so the confidence controller can move the bar
        # round to round without ever triggering a recompile
        self._draft = jax.jit(
            lambda p, cache, prev, key, c_th: drafting.draft_round(
                draft_model,
                p,
                cache,
                prev,
                key,
                k_max=k_max,
                c_th=c_th,
                temperature=temperature,
                greedy=greedy,
                keep_q_full=not greedy,
                attn_chunk=attn_chunk,
            )
        )

        # greedy next-token peek (no cache commit): the device's own guess at
        # the bonus token, which seeds pipelined draft-ahead rounds
        def _peek_fn(p, cache, tok):
            h, _, _ = draft_model.decode_forward(p, cache, tok[:, None], attn_chunk=attn_chunk)
            return jnp.argmax(draft_model.lm_head(p, h)[:, 0], axis=-1).astype(jnp.int32)

        self._peek = jax.jit(_peek_fn)
        # draft-ahead replays the post-acceptance state exactly; attention
        # caches roll back by length, but ssm/hybrid recurrences would need
        # checkpoint surgery mid-round — those kits draft strictly in-order
        self.supports_pipeline = greedy and draft_model.cfg.family not in ("ssm", "hybrid")
        self._attn_chunk = attn_chunk

    def spawn(self, device_id: int, prompt: jax.Array, *, max_len: int, seed: int = 0):
        return EdgeDevice(self, device_id, prompt, max_len=max_len, seed=seed)


def _clamp_draft(dres: drafting.DraftResult, k: Optional[int]) -> drafting.DraftResult:
    """Cap a drafting round at ``k`` proposal tokens (adaptive spec length).

    The draft scan always runs the jitted fixed-``k_max`` shape; clamping
    ``lengths`` host-side truncates the *proposal* — greedy drafting is
    autoregressive, so the first ``k`` tokens are exactly what a k-length
    round would have produced, and rollback/resume key off ``lengths`` and
    ``n_accepted`` only, never off the extra scanned positions.
    """
    if k is None or k < 1:
        return dres
    return dataclasses.replace(dres, lengths=jnp.minimum(dres.lengths, jnp.int32(k)))


class EdgeDevice:
    """One edge device's drafting loop (SLED §III-A), batch size 1.

    Supports pipelined draft-ahead (SpecEdge-style): after submitting a round
    the device may keep drafting on the assumption that every token will be
    accepted, seeding the ahead round with its own greedy guess at the bonus
    token.  If the verdict confirms both (full acceptance AND the bonus guess
    was right), the pre-drafted round is submitted with zero draft latency —
    and because greedy drafting is deterministic from (cache, prev), those
    tokens are bit-identical to what a fresh round would have produced, so
    pipelining never changes outputs.  On any miss the ahead work is simply
    discarded (JAX caches are immutable pytrees; rollback is keeping the old
    reference).

    ``draft(k=...)`` caps the proposal length below the kit's ``k_max`` —
    the adaptive spec-length controller (serving/speclen.py) moves that cap
    round to round from the server's verdict feedback.
    """

    def __init__(self, kit: EdgeDeviceKit, device_id: int, prompt, *, max_len: int, seed: int):
        self.kit = kit
        self.device_id = device_id
        cache = kit.model.make_cache(1, max_len, attn_chunk=kit._attn_chunk)
        prompt = jnp.asarray(prompt, jnp.int32)
        _, self.cache, self.prev = kit._prefill(kit.params, cache, prompt[None, :])
        self.key = jax.random.key(seed)
        self.committed: List[int] = []
        self._pending: Optional[drafting.DraftResult] = None
        self._ahead: Optional[tuple] = None  # (bonus_guess, cache_acc, dres)
        self.pending_q: Optional[np.ndarray] = None
        self.pipeline_hits = 0
        self.pipeline_misses = 0
        self.fallback_tokens = 0
        self.drafted = 0
        self.draft_seconds = 0.0  # wall time inside draft() — calibrates
        # the simulator's device_rate against real measured drafting

    def draft(self, k: Optional[int] = None, c_th: Optional[float] = None) -> np.ndarray:
        """Draft up to min(k, k_max) tokens; returns the variable-length
        proposal.  ``pending_q`` holds the matching q(token) row for
        sampling-mode submits (engine.submit(..., draft_q=dev.pending_q)).
        ``c_th`` overrides the kit's confidence bar for this round (the
        adaptive confidence controller moves it from verdict feedback)."""
        assert self._pending is None, "previous round still awaiting a verdict"
        t = time.perf_counter()
        cc = self.kit.c_th if c_th is None else float(c_th)
        self.key, kk = jax.random.split(self.key)
        dres = _clamp_draft(self.kit._draft(self.kit.params, self.cache, self.prev, kk, cc), k)
        self._set_pending(dres)
        n = int(dres.lengths[0])
        toks = np.asarray(dres.tokens[0, :n])  # materialize: honest timing
        self.draft_seconds += time.perf_counter() - t
        self.drafted += n
        return toks

    def _set_pending(self, dres: drafting.DraftResult) -> None:
        self._pending = dres
        n = int(dres.lengths[0])
        self.pending_q = np.asarray(dres.q_sel[0, :n])

    def draft_ahead(
        self, k: Optional[int] = None, c_th: Optional[float] = None
    ) -> Optional[np.ndarray]:
        """Pre-draft the next round while the current one is in flight.

        Returns the ahead proposal (or None if unsupported); it becomes live
        only if on_verdict() confirms the speculation.
        """
        assert self._pending is not None, "draft_ahead needs a round in flight"
        if self._ahead is not None or not self.kit.supports_pipeline:
            return None
        cc = self.kit.c_th if c_th is None else float(c_th)
        pend = self._pending
        n = int(pend.lengths[0])
        last = pend.tokens[:, n - 1]
        # peek at the draft model's bonus-position prediction: feed d_n against
        # the cache rolled to just-before-d_n (no commit — logits only)
        peek_cache = {**pend.cache, "length": pend.base_length + n}
        bonus_guess = int(self.kit._peek(self.kit.params, peek_cache, last)[0])
        # state as if all n drafts were accepted; identical transform to the
        # full-acceptance verdict path, so a hit replays the exact fresh state
        cache_acc = drafting.resume_after_verify(self.kit.model, pend, jnp.asarray([n], jnp.int32))
        self.key, kk = jax.random.split(self.key)
        prev_guess = jnp.asarray([bonus_guess], jnp.int32)
        dres = _clamp_draft(self.kit._draft(self.kit.params, cache_acc, prev_guess, kk, cc), k)
        self._ahead = (bonus_guess, cache_acc, dres)
        m = int(dres.lengths[0])
        return np.asarray(dres.tokens[0, :m])

    def on_verdict(self, verdict: Verdict) -> Optional[np.ndarray]:
        """Roll the draft cache back to the verified prefix and resync.

        Returns the next round's proposal when pipelined draft-ahead was
        confirmed (submit it immediately — the device is already drafting
        ahead of the server), else None (call draft() as usual).
        """
        assert self._pending is not None
        pend = self._pending
        n = int(pend.lengths[0])
        self.committed.extend(int(t) for t in verdict.tokens)
        if self._ahead is not None:
            bonus_guess, cache_acc, ahead = self._ahead
            self._ahead = None
            if verdict.n_accepted == n and verdict.next_prev == bonus_guess:
                self.pipeline_hits += 1
                self.cache = cache_acc
                self.prev = jnp.asarray([bonus_guess], jnp.int32)
                self._set_pending(ahead)
                m = int(ahead.lengths[0])
                return np.asarray(ahead.tokens[0, :m])
            self.pipeline_misses += 1
        self.cache = drafting.resume_after_verify(
            self.kit.model, pend, jnp.asarray([verdict.n_accepted], jnp.int32)
        )
        self.prev = jnp.asarray([verdict.next_prev], jnp.int32)
        self._pending = None
        return None

    def fallback_release(self) -> np.ndarray:
        """§III-A timeout fallback: release the in-flight drafts locally and
        continue as if they were committed.  The caller must resync the
        server (engine.force_extend / transport Fallback frame) with the
        returned tokens before the next verification round."""
        assert self._pending is not None
        pend = self._pending
        n = int(pend.lengths[0])
        toks = np.asarray(pend.tokens[0, :n])
        # accept n-1 drafts cache-side, then the nth rides as prev_token —
        # preserving the "last committed token is never in the KV" invariant
        self.cache = drafting.resume_after_verify(
            self.kit.model, pend, jnp.asarray([n - 1], jnp.int32)
        )
        self.prev = jnp.asarray([int(toks[-1])], jnp.int32)
        self.committed.extend(int(t) for t in toks)
        self.fallback_tokens += n
        self._pending = None
        self._ahead = None
        return toks

    @property
    def awaiting(self) -> bool:
        return self._pending is not None
