"""Async edge<->server transport runtime (wire protocol + links + loops).

Decouples edge devices from the verification server behind an explicit,
versioned wire protocol so network effects — RTT, jitter, bandwidth,
stragglers, timeout fallback — are real runtime behaviour instead of
simulator-only abstractions:

  codec.py   — length-prefixed binary frames (DraftPacket / Verdict /
               admission + fallback control) with optional fp16/int8
               quantization of the draft-probability payload
  links.py   — channel abstraction: zero-latency loopback and a
               SimulatedLink imposing per-NetProfile latency/bandwidth/
               jitter/drop on every frame
  server.py  — asyncio TransportServer wrapping core.server_engine
  client.py  — asyncio EdgeClient: pipelined draft-ahead device loop
"""

from repro.transport.codec import (
    Admit,
    Close,
    CodecError,
    DraftPacket,
    Fallback,
    FallbackAck,
    FrameDecoder,
    Hello,
    Verdict,
    decode_frame,
    encode_frame,
)
from repro.transport.links import LinkStats, LoopbackLink, SimulatedLink, make_link

__all__ = [
    "Admit",
    "Close",
    "CodecError",
    "DraftPacket",
    "Fallback",
    "FallbackAck",
    "FrameDecoder",
    "Hello",
    "Verdict",
    "decode_frame",
    "encode_frame",
    "LinkStats",
    "LoopbackLink",
    "SimulatedLink",
    "make_link",
]
