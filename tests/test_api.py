"""repro.api: ServeSpec validation + JSON round-trip, the System/Session
facade, and the cross-backend equivalence ladder at the API level.

The load-bearing test extends the repo's equivalence ladder to its top
rung: ONE ServeSpec seed must commit token-identical streams through the
lock-step reference loop, the in-process engine, the transport runtime on
loopback links, and a 2-replica cluster router — the acceptance bar for
the unified front door.
"""

import json
import logging
import pathlib
import time

import numpy as np
import pytest

from repro.api import (
    ClusterSpec,
    DoneEvent,
    ModelSpec,
    RoundEvent,
    SchedulerSpec,
    ServeSpec,
    SpecError,
    System,
    TokenEvent,
    TransportSpec,
    build_models,
)
from repro.core.engine import EngineStats
from repro.core.engine_loop import sled_generate
from repro.transport.client import ClientStats

V = 64


def _spec(**kw) -> ServeSpec:
    base = dict(
        backend="engine",
        model=ModelSpec(vocab_size=V, target_layers=2, draft_layers=1, draft_noise=0.03),
        transport=TransportSpec(stagger_s=0.0),
        scheduler=SchedulerSpec(stagger_ticks=1),
        devices=3,
        prompt_len=8,
        max_new=8,
        k_max=4,
        c_th=0.3,
    )
    base.update(kw)
    return ServeSpec(**base)


# ---------------------------------------------------------------------------
# spec validation + serialization
# ---------------------------------------------------------------------------


def test_default_spec_valid():
    ServeSpec()  # __post_init__ validates


def test_json_round_trip():
    spec = _spec(
        backend="transport",
        transport=TransportSpec(link="sim", net="lte", qmode="int8", stagger_s=0.1),
        cluster=ClusterSpec(replicas=3, placement="affinity"),
        scheduler=SchedulerSpec(policy="deadline", max_wait=0.1, slots=2),
        kctl="adaptive",
    )
    assert ServeSpec.from_json(spec.to_json()) == spec  # dict form
    assert ServeSpec.from_json(spec.to_json_str()) == spec  # string form
    assert json.loads(spec.to_json_str()) == spec.to_json()


def test_from_json_rejects_unknown_keys():
    d = _spec().to_json()
    d["typo_field"] = 1
    with pytest.raises(SpecError, match="typo_field"):
        ServeSpec.from_json(d)
    d2 = _spec().to_json()
    d2["model"]["typo"] = 1
    with pytest.raises(SpecError, match="typo"):
        ServeSpec.from_json(d2)


@pytest.mark.parametrize(
    "changes",
    [
        dict(backend="bogus"),
        dict(backend="reference", cluster=ClusterSpec(replicas=2)),
        dict(backend="engine", cluster=ClusterSpec(replicas=2)),
        dict(backend="engine", kctl="adaptive"),
        dict(
            backend="transport",
            kctl="adaptive",
            transport=TransportSpec(codec_version=1),
        ),
        dict(transport=TransportSpec(qmode="f64")),
        dict(transport=TransportSpec(link="sim", net="bogus-net")),
        dict(transport=TransportSpec(link="loopback", net="bogus-net")),
        dict(scheduler=SchedulerSpec(policy="bogus")),
        dict(cluster=ClusterSpec(placement="bogus")),
        dict(model=ModelSpec(bits=5)),
        dict(devices=0),
        dict(max_new=0),
        dict(max_len=8, prompt_len=8),
        dict(max_new=120),  # prompt + budget + slack overflows the pool row
    ],
)
def test_invalid_combos_rejected(changes):
    with pytest.raises(SpecError):
        _spec(**changes)


def test_from_json_rejects_wrong_types():
    with pytest.raises(SpecError, match="vocab_size|bad"):
        ServeSpec.from_json('{"model": {"vocab_size": "128"}}')
    with pytest.raises(SpecError, match="not valid JSON"):
        ServeSpec.from_json("{not json")


def test_build_rejects_non_runtime_codec_version():
    from repro.transport import codec

    spec = _spec(backend="transport", transport=TransportSpec(codec_version=1))
    with pytest.raises(ValueError, match=f"codec v{codec.VERSION}"):
        System.build(spec)


def test_with_backend_normalizes():
    spec = _spec(backend="cluster", cluster=ClusterSpec(replicas=2))
    ref = spec.with_backend("reference")
    assert ref.backend == "reference" and ref.cluster.replicas == 1
    tr = _spec(backend="transport", kctl="adaptive")
    assert tr.with_backend("engine").kctl == "fixed"


def test_slots_per_replica():
    spec = _spec(backend="cluster", cluster=ClusterSpec(replicas=2), devices=5)
    assert spec.slots_per_replica == 3  # ceil(5/2)
    assert _spec(scheduler=SchedulerSpec(slots=7)).slots_per_replica == 7


def test_committed_spec_artifacts_round_trip():
    spec_dir = pathlib.Path(__file__).parent.parent / "examples" / "specs"
    paths = sorted(spec_dir.glob("*.json"))
    assert {p.stem for p in paths} >= {"reference", "engine", "transport", "cluster"}
    for p in paths:
        spec = ServeSpec.from_json(p.read_text())
        assert ServeSpec.from_json(spec.to_json_str()) == spec


def test_stats_to_json_uniform():
    e = EngineStats(
        wstgr=1.0, per_device_rate=0.5, server_busy_frac=0.1, rounds=2,
        timeouts=0, fallback_tokens=0, mean_batch_fill=1.0,
        mean_round_latency=0.0, server_rounds_per_s=1.0,
    )
    assert json.dumps(e.to_json()) and e.to_json() == e.as_dict()
    c = ClientStats(device_id=3, rounds=4)
    assert json.dumps(c.to_json()) and c.to_json()["rounds"] == 4


def test_cli_dump_spec(capsys):
    from repro.cli import main

    main(["serve", "--dump-spec", "--devices", "2", "--replicas", "2"])
    out = capsys.readouterr().out
    spec = ServeSpec.from_json(out[out.index("{"):])
    assert spec.backend == "transport" and spec.cluster.replicas == 2


# ---------------------------------------------------------------------------
# System facade: cross-backend token equivalence (the API-level ladder)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bundle():
    spec = _spec()
    return spec, build_models(spec.model)


@pytest.fixture(scope="module")
def ref_outputs(bundle):
    spec, models = bundle
    system = System.build(spec.with_backend("reference"), models=models)
    result = system.serve()
    # the reference backend must itself equal the algorithmic ground truth
    out, _, _ = sled_generate(
        models.draft, models.draft_params, models.target, models.target_params,
        system.prompts(),
        max_new=spec.max_new, k_max=spec.k_max, c_th=spec.c_th, greedy=True,
    )
    for i in range(spec.devices):
        assert result.outputs[i] == [int(t) for t in np.asarray(out)[i]]
    # per-session accounting is self-consistent
    for s in result.sessions:
        assert len(s.tokens) == spec.max_new and s.rounds > 0
    return result.outputs


@pytest.mark.parametrize(
    "backend,replicas",
    [
        ("engine", 1),
        pytest.param("cluster", 2, marks=pytest.mark.slow),
        pytest.param("transport", 1, marks=pytest.mark.slow),
    ],
)
def test_backend_equivalence(bundle, ref_outputs, backend, replicas):
    spec, models = bundle
    system = System.build(
        spec.with_backend(backend, cluster=ClusterSpec(replicas=replicas)),
        models=models,
    )
    result = system.serve()
    assert result.outputs == ref_outputs, f"{backend} diverged from the reference"
    assert json.dumps(result.to_json())  # uniform record is an artifact


def test_session_stream_consistency(bundle, ref_outputs):
    spec, models = bundle
    system = System.build(spec, models=models)
    session = system.open_session(device_id=0)
    tokens, rounds, done = [], 0, 0
    for ev in session.generate():
        if isinstance(ev, TokenEvent):
            assert ev.index == len(tokens)
            tokens.append(ev.token)
        elif isinstance(ev, RoundEvent):
            rounds += 1
        elif isinstance(ev, DoneEvent):
            done += 1
    assert done == 1
    assert tokens == session.result.tokens == ref_outputs[0]
    assert rounds == session.result.rounds
    assert session.result.accepted <= session.result.drafted


def test_interleaved_sessions_batch_together(bundle, ref_outputs):
    spec, models = bundle
    system = System.build(spec, models=models)
    s0 = system.open_session(device_id=0)
    s1 = system.open_session(device_id=1)
    g0, g1 = s0.generate(), s1.generate()
    for _ in range(100_000):
        if s0.done and s1.done:
            break
        next(g0, None)
        next(g1, None)
    assert s0.result.tokens == ref_outputs[0]
    assert s1.result.tokens == ref_outputs[1]
    # both streams rode shared engine batches at least once
    assert any(r.size > 1 for r in system.engine.round_log)


def test_paged_attention_fallback_warning(caplog):
    spec = _spec(
        model=ModelSpec(
            arch="mamba2-370m", vocab_size=V, target_layers=2, draft_layers=1
        ),
        devices=1,
    )
    with caplog.at_level(logging.WARNING, logger="repro.api.system"):
        System.build(spec)
    assert any(
        "gather/scatter" in r.getMessage() for r in caplog.records
    ), "System.build must name the paging fallback for SSM/hybrid families"


def test_reference_rejects_ragged_prompts(bundle):
    spec, models = bundle
    system = System.build(spec.with_backend("reference"), models=models)
    s0 = system.open_session(np.arange(8), device_id=0)
    s1 = system.open_session(np.arange(12), device_id=1)
    with pytest.raises(ValueError, match="equal prompt lengths"):
        next(system._reference_rounds([s0, s1]))


def test_serve_requires_fresh_system(bundle):
    spec, models = bundle
    system = System.build(spec, models=models)
    system.open_session(device_id=0)
    with pytest.raises(RuntimeError, match="fresh System"):
        system.serve()


def test_serve_twice_same_ids_same_tokens(bundle):
    """Repeated serve() on one System reuses device ids 0..N-1 and commits
    the same tokens — runs from one spec artifact stay comparable."""
    spec, models = bundle
    system = System.build(spec, models=models)
    r1 = system.serve()
    r2 = system.serve()
    assert sorted(r1.outputs) == sorted(r2.outputs) == list(range(spec.devices))
    assert r1.outputs == r2.outputs


def test_open_session_rejects_row_overflow(bundle):
    spec, models = bundle
    system = System.build(spec, models=models)
    with pytest.raises(ValueError, match="max_len"):
        system.open_session(device_id=0, max_new=spec.max_len)


@pytest.mark.slow
def test_transport_stream_cancel(bundle):
    """Closing a transport session's generator early cancels the background
    run promptly and frees the stream's pool slot best-effort."""
    spec, models = bundle
    system = System.build(spec.with_backend("transport"), models=models)
    session = system.open_session(device_id=0)
    gen = session.generate()
    assert next(gen) is not None  # stream is live
    t0 = time.time()
    gen.close()
    assert time.time() - t0 < 30.0, "early close must not ride out the full run"
    for _ in range(200):  # cancellation cleanup is asynchronous
        if not system.engine.streams:
            break
        time.sleep(0.05)
    assert not system.engine.streams, "cancelled stream must release its slot"
