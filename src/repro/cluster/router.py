"""Cluster router: replica-sharded verification behind one serving surface.

SLED's capacity story (paper Table I) is one shared target model serving many
heterogeneous drafters; at production scale that target tier is N engine
replicas behind a placement layer, not one engine object.  The
:class:`Router` owns N :class:`~repro.core.server_engine.ServerEngine`
replicas — each a full single-replica stack (pool + admission + planner) —
and turns admission into a placement decision:

  * **placement** — a pluggable :class:`PlacementPolicy` (BatchPlanner-style
    registry: ``least-loaded`` / ``affinity`` / ``round-robin``) picks the
    replica for each new stream among those with a free pool slot;
  * **migration** — when a stream retires and frees a slot, the router may
    migrate an active stream over from the most-loaded replica
    (``migrate_on_retire``).  Replicas share the model parameters and the
    jitted step bundle, and a migrated KV row is copied bit-exactly
    (``export_stream``/``import_stream``), so migration never changes a
    stream's tokens — only which replica's batches it rides in;
  * **aggregation** — cluster stats are ``EngineStats.merge`` over replicas,
    and verdicts carry each stream's replica-local queue-depth feedback.

The router mirrors the full ServerEngine driver surface (admit / submit /
step / retire / cancel_request / force_extend / stats / warmup), so the
transport server and the in-process serving loops drive a replica fleet by
holding a Router where they held an engine.  Replicas share one VerifySteps
bundle (same compiled executables), so a fleet costs one engine's XLA
compilation.  In-process today; one Router in front of per-host
TransportServers over the TCP endpoint is the recorded follow-on.
"""

from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Dict, Iterator, List, Optional, Sequence

import jax
import numpy as np

from repro.core.admission import DeviceStream
from repro.core.engine import EngineStats, Verdict
from repro.core.server_engine import ServerEngine


class PlacementPolicy:
    """Chooses the replica for a new stream; None when every pool is full."""

    name = "base"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        raise NotImplementedError

    @staticmethod
    def _open(router: "Router") -> List[int]:
        return [i for i, e in enumerate(router.replicas) if e.pool.n_free > 0]


class LeastLoadedPlacement(PlacementPolicy):
    """Fewest active streams wins (ties break toward the lowest replica id):
    keeps per-replica batch fill even under staggered arrivals."""

    name = "least-loaded"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        open_ = self._open(router)
        if not open_:
            return None
        return min(open_, key=lambda i: (len(router.replicas[i].streams), i))


class AffinityPlacement(PlacementPolicy):
    """Deterministic device->replica hash (session/cache affinity); falls
    over to least-loaded when the home replica is full."""

    name = "affinity"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        home = device_id % len(router.replicas)
        if router.replicas[home].pool.n_free > 0:
            return home
        return LeastLoadedPlacement().choose(router, device_id)


class RoundRobinPlacement(PlacementPolicy):
    """Cycle through replicas, skipping full pools."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        n = len(router.replicas)
        for off in range(n):
            i = (self._next + off) % n
            if router.replicas[i].pool.n_free > 0:
                self._next = i + 1
                return i
        return None


PLACEMENT_POLICIES = {
    p.name: p for p in (LeastLoadedPlacement, AffinityPlacement, RoundRobinPlacement)
}


def make_placement(policy: str) -> PlacementPolicy:
    if policy not in PLACEMENT_POLICIES:
        raise ValueError(
            f"unknown placement policy {policy!r} (one of {sorted(PLACEMENT_POLICIES)})"
        )
    return PLACEMENT_POLICIES[policy]()


class _StreamView(Mapping):
    """Read-only dict-like view over every replica's streams.

    Membership and lookup go through the router's placement map (O(1) per
    frame in the transport hot path) instead of merging N dicts per access.
    """

    def __init__(self, router: "Router"):
        self._router = router

    def __contains__(self, device_id) -> bool:
        return device_id in self._router._where

    def __getitem__(self, device_id) -> DeviceStream:
        return self._router._engine(device_id).streams[device_id]

    def __iter__(self) -> Iterator[int]:
        return iter(self._router._where)

    def __len__(self) -> int:
        return len(self._router._where)


class Router:
    """N engine replicas + placement: the cluster-level serving object."""

    def __init__(
        self,
        replicas: Sequence[ServerEngine],
        *,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
    ):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        k_maxes = {e.k_max for e in replicas}
        max_lens = {e.pool.max_len for e in replicas}
        if len(k_maxes) > 1 or len(max_lens) > 1:
            raise ValueError(
                f"replicas must be homogeneous for migration: k_max {k_maxes}, "
                f"max_len {max_lens}"
            )
        self.replicas: List[ServerEngine] = list(replicas)
        self.placement = (
            placement if isinstance(placement, PlacementPolicy) else make_placement(placement)
        )
        self.migrate_on_retire = migrate_on_retire
        self.migrations = 0
        self._where: Dict[int, int] = {}  # device_id -> replica index

    @classmethod
    def build(
        cls,
        model: Any,
        params: Any,
        *,
        replicas: int,
        n_slots: int,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
        **engine_kw,
    ) -> "Router":
        """N homogeneous replicas (``n_slots`` rows each) sharing one jitted
        VerifySteps bundle — the fleet compiles once.  Pass ``steps=`` to
        share an ALREADY-compiled bundle from another homogeneous fleet
        (spec sweeps build every replica count on the same executables)."""
        if replicas < 1:
            raise ValueError(f"need at least 1 replica, got {replicas}")
        steps = engine_kw.pop("steps", None)
        first = ServerEngine(model, params, n_slots=n_slots, steps=steps, **engine_kw)
        rest = [
            ServerEngine(model, params, n_slots=n_slots, steps=first.steps, **engine_kw)
            for _ in range(replicas - 1)
        ]
        return cls(
            [first, *rest], placement=placement, migrate_on_retire=migrate_on_retire
        )

    # -- introspection -------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def k_max(self) -> int:
        return self.replicas[0].k_max

    @property
    def paged_attention(self) -> bool:
        return self.replicas[0].paged_attention

    @property
    def streams(self) -> Mapping:
        """Lazy device->stream mapping across replicas (read-only): O(1)
        membership/lookup via the placement map, no per-access dict merge."""
        return _StreamView(self)

    @property
    def queue_depth(self) -> int:
        return sum(e.queue_depth for e in self.replicas)

    @property
    def n_free(self) -> int:
        return sum(e.pool.n_free for e in self.replicas)

    def replica_of(self, device_id: int) -> int:
        return self._where[device_id]

    def loads(self) -> List[int]:
        """Active stream count per replica (placement test surface)."""
        return [len(e.streams) for e in self.replicas]

    def _engine(self, device_id: int) -> ServerEngine:
        return self.replicas[self._where[device_id]]

    # -- admission as placement ----------------------------------------------

    def admit(self, device_id: int, prompt: jax.Array, now: float = 0.0) -> Optional[DeviceStream]:
        """Place the stream on a replica chosen by the policy; None when
        every replica's pool is full (caller queues and retries on retire)."""
        if device_id in self._where:
            raise ValueError(f"device {device_id} already admitted")
        idx = self.placement.choose(self, device_id)
        if idx is None:
            return None
        stream = self.replicas[idx].admit(device_id, prompt, now)
        if stream is None:  # policy raced a concurrent admit; treat as full
            return None
        self._where[device_id] = idx
        return stream

    def retire(self, device_id: int) -> DeviceStream:
        idx = self._where.pop(device_id)
        stream = self.replicas[idx].retire(device_id)
        if self.migrate_on_retire:
            self._rebalance_into(idx)
        return stream

    def migrate(self, device_id: int, dst: int) -> None:
        """Move a quiescent stream to replica ``dst`` bit-identically: the
        KV row is copied exactly and both replicas share params + compiled
        steps, so the stream's future tokens are unchanged — only its
        batch-mates are."""
        src = self._where[device_id]
        if src == dst:
            return
        stream, row = self.replicas[src].export_stream(device_id)
        try:
            self.replicas[dst].import_stream(stream, row)
        except Exception:
            # roll back: the stream must never be lost mid-migration
            self.replicas[src].import_stream(stream, row)
            raise
        self._where[device_id] = dst
        self.migrations += 1

    def _rebalance_into(self, dst: int) -> None:
        """After a retirement freed a slot on ``dst``: pull one quiescent
        stream over from the most-loaded replica when the imbalance is ≥2
        (moving one stream then strictly improves balance)."""
        if self.replicas[dst].pool.n_free == 0:
            return
        loads = self.loads()
        src = max(range(len(loads)), key=lambda i: (loads[i], -i))
        if loads[src] - loads[dst] < 2:
            return
        engine = self.replicas[src]
        movable = [d for d in engine.streams if not engine.has_inflight(d)]
        if not movable:
            return
        self.migrate(movable[0], dst)

    # -- request path (delegated via placement map) --------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        self._engine(device_id).submit(device_id, draft_tokens, now, draft_q=draft_q)

    def cancel_request(self, device_id: int) -> bool:
        return self._engine(device_id).cancel_request(device_id)

    def force_extend(self, device_id: int, tokens: np.ndarray) -> int:
        return self._engine(device_id).force_extend(device_id, tokens)

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._where and self._engine(device_id).has_inflight(device_id)

    def next_event_hint(self, now: float) -> Optional[float]:
        hints = [h for e in self.replicas if (h := e.next_event_hint(now)) is not None]
        return min(hints) if hints else None

    # -- the serving hot loop ------------------------------------------------

    def step(self, now: float) -> Optional[List[Verdict]]:
        """Step every replica whose policy fires; one merged verdict list.

        Replicas step back to back in this process (single host); each
        verdict's queue-depth feedback stays replica-local — that is the
        congestion signal for the streams riding that replica.
        """
        verdicts: List[Verdict] = []
        for engine in self.replicas:
            out = engine.step(now)
            if out:
                verdicts.extend(out)
        return verdicts or None

    def warmup(self, buckets=None) -> Dict[int, float]:
        """Warm replica 0 only: the fleet shares one VerifySteps bundle and
        identical shapes, so the compiled executables are already hot for
        every other replica — re-running the per-bucket warmup there would
        be (R-1)*buckets of dead verify executions at startup."""
        return self.replicas[0].warmup(buckets)

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        return EngineStats.merge([e.stats(now) for e in self.replicas])

    def replica_stats(self, now: Optional[float] = None) -> List[EngineStats]:
        return [e.stats(now) for e in self.replicas]
