"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(assignment requirement: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATTN_SHAPES = [
    # (B, Sq, Hq, Hkv, Skv, D, block_k)
    (2, 5, 8, 2, 128, 64, 32),
    (1, 3, 4, 4, 64, 32, 64),      # MHA, block_k == Skv
    (3, 5, 12, 1, 256, 16, 64),    # MQA
    (2, 1, 8, 8, 128, 64, 32),     # plain decode (Sq=1)
    (1, 8, 16, 2, 512, 128, 128),  # deep GQA group
    (2, 5, 8, 2, 80, 64, 64),      # Skv % block_k != 0 (partial tail chunk)
    (1, 4, 8, 4, 100, 32, 32),     # partial tail chunk, GQA
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_matches_oracle(shape, dtype):
    B, Sq, Hq, Hkv, Skv, D, blk = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    kv_valid = jax.random.randint(ks[3], (B,), Sq, Skv + 1)
    out = ops.verify_attention(q, k, v, kv_valid, block_k=blk)
    want = ref.verify_attention_ref(q, k, v, kv_valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_verify_attention_matches_model_flash():
    """The kernel and the model's XLA flash path agree on cache semantics."""
    from repro.models.layers import flash_attention
    B, Sq, Hq, Hkv, Skv, D = 2, 5, 8, 2, 128, 32
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D))
    kv_valid = jnp.array([40, 90], jnp.int32)
    q_pos = kv_valid[:, None] - Sq + jnp.arange(Sq)[None]
    a = flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid, chunk=32)
    b = ops.verify_attention(q, k, v, kv_valid, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


PAGED_SHAPES = [
    # (n_slots, B, Sq, Hq, Hkv, Skv, D, block_k)
    (6, 3, 5, 8, 2, 128, 64, 32),    # GQA, bucket < pool
    (4, 2, 4, 4, 4, 96, 32, 64),     # MHA, Skv % block_k != 0
    (5, 4, 5, 12, 1, 160, 16, 64),   # MQA, partial tail chunk
    (3, 3, 2, 16, 2, 64, 32, 64),    # deep GQA group, block_k == Skv
]


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_paged_equivalence_sweep(shape, dtype):
    """Slot-indexed pool kernel == gather + packed kernel == XLA reference,
    across uneven per-slot lengths, duplicate scratch-slot padding rows, and
    GQA/MQA head counts (interpret mode)."""
    n_slots, B, Sq, Hq, Hkv, Skv, D, blk = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 5)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k_pool = jax.random.normal(ks[1], (n_slots + 1, Skv, Hkv, D), dtype)
    v_pool = jax.random.normal(ks[2], (n_slots + 1, Skv, Hkv, D), dtype)
    # real rows out of order + the last TWO entries padded with the
    # duplicated scratch slot (the engine's partial-fill convention)
    real = jax.random.permutation(ks[3], n_slots)[: max(B - 2, 1)]
    slots = jnp.concatenate(
        [real, jnp.full((B - real.shape[0],), n_slots)]
    ).astype(jnp.int32)
    kv_valid = jax.random.randint(ks[4], (B,), Sq, Skv + 1)

    out_paged = ops.verify_attention_paged(q, k_pool, v_pool, slots, kv_valid, block_k=blk)
    out_gather = ops.verify_attention(
        q, k_pool[slots], v_pool[slots], kv_valid, block_k=blk
    )
    want = ref.verify_attention_paged_ref(q, k_pool, v_pool, slots, kv_valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out_paged, np.float32),
                               np.asarray(out_gather, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(out_paged, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_verify_attention_paged_int8_equivalence_sweep(shape):
    """Dequant-in-kernel int8 pool == XLA oracle (dequantized gather) ==
    dequantize-then-bf16-kernel, across uneven per-slot lengths, duplicate
    scratch-slot padding, and per-(slot, head) scales (interpret mode)."""
    n_slots, B, Sq, Hq, Hkv, Skv, D, blk = shape
    ks = jax.random.split(jax.random.key(sum(shape) + 17), 7)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.bfloat16)
    kf = jax.random.normal(ks[1], (n_slots + 1, Skv, Hkv, D))
    vf = jax.random.normal(ks[2], (n_slots + 1, Skv, Hkv, D))
    # per-(slot, head) symmetric scales, deliberately non-uniform
    k_scale = jnp.abs(kf).max(axis=(1, 3)) / 127.0 + 1e-6
    v_scale = jnp.abs(vf).max(axis=(1, 3)) / 127.0 + 1e-6
    k_pool = jnp.clip(jnp.round(kf / k_scale[:, None, :, None]), -127, 127).astype(jnp.int8)
    v_pool = jnp.clip(jnp.round(vf / v_scale[:, None, :, None]), -127, 127).astype(jnp.int8)
    real = jax.random.permutation(ks[3], n_slots)[: max(B - 2, 1)]
    slots = jnp.concatenate(
        [real, jnp.full((B - real.shape[0],), n_slots)]
    ).astype(jnp.int32)
    kv_valid = jax.random.randint(ks[4], (B,), Sq, Skv + 1)

    out = ops.verify_attention_paged(
        q, k_pool, v_pool, slots, kv_valid, k_scale, v_scale, block_k=blk
    )
    want = ref.verify_attention_paged_ref(
        q, k_pool, v_pool, slots, kv_valid, k_scale=k_scale, v_scale=v_scale
    )
    # dequantize the gathered rows up front, run the bf16 packed kernel:
    # the in-kernel dequant must change nothing but the HBM stream width
    kd = (k_pool[slots].astype(jnp.float32)
          * k_scale[slots][:, None, :, None]).astype(jnp.bfloat16)
    vd = (v_pool[slots].astype(jnp.float32)
          * v_scale[slots][:, None, :, None]).astype(jnp.bfloat16)
    out_dq = ops.verify_attention(q, kd, vd, kv_valid, block_k=blk)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_dq, np.float32), rtol=2e-2, atol=2e-2)


def test_verify_attention_paged_int8_requires_scales():
    n_slots, B, Sq, Hq, Hkv, Skv, D = 3, 2, 2, 4, 2, 64, 32
    q = jnp.zeros((B, Sq, Hq, D), jnp.bfloat16)
    pool = jnp.zeros((n_slots + 1, Skv, Hkv, D), jnp.int8)
    slots = jnp.zeros((B,), jnp.int32)
    kv_valid = jnp.full((B,), Sq, jnp.int32)
    with pytest.raises(ValueError, match="k_scale"):
        ops.verify_attention_paged(q, pool, pool, slots, kv_valid)


def test_verify_attention_partial_tail_chunk_finite():
    """A cache length that is not a block multiple must degrade to masking,
    not crash or leak NaN from the out-of-bounds tail lanes."""
    B, Sq, Hq, Hkv, Skv, D = 2, 5, 8, 2, 80, 32
    ks = jax.random.split(jax.random.key(11), 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D))
    kv_valid = jnp.asarray([Skv, Sq], jnp.int32)  # full row + minimal row
    out = ops.verify_attention(q, k, v, kv_valid, block_k=64)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    want = ref.verify_attention_ref(q, k, v, kv_valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-3)


SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (2, 64, 4, 16, 32, 16),
    (1, 128, 2, 8, 16, 32),
    (2, 32, 1, 32, 8, 32),   # single head, chunk == S
    (1, 96, 3, 16, 64, 24),  # odd-ish chunking
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracle(shape, dtype):
    B, S, H, P, N, chunk = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    y, hf = ops.ssd_scan(x, dt, A, Bm, Cm, h0, chunk=chunk)
    yw, hw = ref.ssd_scan_ref(x, dt, A, Bm, Cm, h0)
    tol = 4e-2 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yw, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hw), rtol=tol, atol=tol)


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel == the model's pure-jnp chunked SSD (mamba2.ssd_chunked)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 64, 4, 16, 32
    ks = jax.random.split(jax.random.key(9), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jax.random.normal(ks[5], (B, H, P, N))
    y1, h1 = ops.ssd_scan(x, dt, A, Bm, Cm, h0, chunk=16)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, 16, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=3e-3, atol=3e-3)
