"""Training launcher with fault tolerance + elastic resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 40 \
        --smoke --fail-at 20    # inject a crash, then rerun to resume

Production-mesh training is validated by launch/dryrun.py (train_4k cells);
this launcher runs REAL steps at reduced scale and demonstrates the
fault-tolerance loop: periodic async checkpoints, crash -> resume with
bitwise-identical trajectory (restart-safe data pipeline), optional elastic
re-shard on a different mesh (checkpoint/checkpoint.py restore(shardings=)).

XLA latency-hiding knobs for the real TPU deployment are listed in FLAGS —
they overlap the FSDP all-gathers and the cross-pod gradient all-reduce
with compute (documented here because the CPU container can't measure them).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.models.model_zoo import build_model
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step

# TPU deployment flags (documented; no-ops on CPU):
FLAGS = [
    "--xla_tpu_enable_latency_hiding_scheduler=true",   # overlap comm/compute
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance demo)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg.reduced(), vocab_size=512)
    model = build_model(cfg)
    ckpt_dir = args.ckpt_dir or f"experiments/train_{cfg.name}"

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
        remat=True, loss_chunk=64, attn_chunk=64,
        grad_accum=args.grad_accum, compress_grads=args.compress_grads,
    )
    step_fn = jax.jit(make_train_step(model, tcfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
                      global_batch=args.batch)

    kw = {"max_pos": args.seq + 8} if not cfg.use_rope else {}
    start = ckpt.latest_step(ckpt_dir)
    if start is not None:
        spec = {"params": model.init_params_spec(**kw),
                "opt": jax.eval_shape(adamw_init, model.init_params_spec(**kw))}
        state, _ = ckpt.restore(ckpt_dir, spec)
        params, opt = state["params"], state["opt"]
        print(f"[resume] restored step {start} from {ckpt_dir}")
    else:
        params = model.init_params(jax.random.key(0), **kw)
        opt = adamw_init(params)
        start = 0

    err, pending = None, None
    for s in range(start, args.steps):
        if args.fail_at is not None and s == args.fail_at:
            raise SystemExit(f"[fault-injection] simulated node failure at step {s} "
                             f"— rerun the same command to resume")
        batch = {k: jnp.asarray(v) for k, v in batch_at(dcfg, s, cfg).items()}
        params, opt, err, metrics = step_fn(params, opt, err, batch)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if s and s % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save(ckpt_dir, s, {"params": params, "opt": opt},
                                async_save=True)
    if pending is not None:
        pending.join()
    ckpt.save(ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done.")


if __name__ == "__main__":
    main()
