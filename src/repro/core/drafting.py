"""Device-side dynamic drafting (SLED §III-A).

The edge device drafts up to ``k_max`` tokens with its local draft model and
stops early when the draft confidence ``c_i`` drops below ``c_th`` (paper
Eq. 1): a low-confidence token is still *included* in the verification
request (it is precisely the token that needs checking), but no further
tokens are drafted behind it.

Implemented as a fixed-K scan with per-row active masks — rows that stopped
early carry padding, matching the paper's padded static batches.

Rollback protocol (device side, mirrors core/verification.py):
  * attention-family drafts: the draft KV cache rolls back by setting
    ``length = base + 1 + n_accepted``; stale entries are overwritten.
  * ssm/hybrid drafts: recurrences cannot be un-applied, so the scan emits a
    per-step cache checkpoint; ``resume_after_verify`` selects checkpoint
    ``n_accepted`` (state after consuming prev_token + accepted drafts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.speculative import sample_token
from repro.models.layers import MeshContext, NO_MESH


@dataclasses.dataclass
class DraftResult:
    tokens: jax.Array       # (B, K) drafted tokens (padding past length)
    q_sel: jax.Array        # (B, K) q(token)
    q_full: Optional[jax.Array]  # (B, K, V) full draft dists (exact residual)
    lengths: jax.Array      # (B,) dynamic draft lengths in [1, K]
    confidence: jax.Array   # (B, K)
    cache: Any              # cache after the drafting scan (uncommitted)
    cache_ckpts: Any        # per-step cache checkpoints (ssm/hybrid) or None
    base_length: jax.Array  # (B,) cache length before the round


jax.tree_util.register_dataclass(
    DraftResult,
    data_fields=["tokens", "q_sel", "q_full", "lengths", "confidence",
                 "cache", "cache_ckpts", "base_length"],
    meta_fields=[],
)


def draft_round(
    model,
    params,
    cache: Dict[str, jax.Array],
    prev_token: jax.Array,  # (B,) last committed token (cache has not seen it)
    key: jax.Array,
    *,
    k_max: int,
    c_th: float = 0.0,  # 0.0 -> fixed-length drafting
    temperature: float = 1.0,
    greedy: bool = False,
    keep_q_full: bool = False,
    ctx: MeshContext = NO_MESH,
    attn_chunk: int = 1024,
) -> DraftResult:
    """One drafting round: feed prev_token, then draft up to k_max tokens."""
    B = prev_token.shape[0]
    is_ssm = model.cfg.family in ("ssm", "hybrid")
    base_length = cache["length"]

    def step(carry, _):
        cache, tok, active, key = carry
        key, k_s = jax.random.split(key)
        h, ck, _ = model.decode_forward(params, cache, tok[:, None], ctx,
                                        attn_chunk=attn_chunk)
        # consume exactly this one token into the cache
        cache = model.commit(ck, jnp.ones((B,), jnp.int32))
        logits = model.lm_head(params, h)[:, 0]
        nxt, q, dist = sample_token(logits, k_s, temperature, greedy)
        conf = jnp.max(dist, axis=-1)
        emitted = active
        keep_drafting = active & (conf >= c_th)
        ckpt = cache if is_ssm else None
        out = (
            jnp.where(emitted, nxt, 0),
            jnp.where(emitted, q, 0.0),
            dist if keep_q_full else jnp.zeros((B, 0), jnp.float32),
            emitted,
            jnp.where(emitted, conf.astype(jnp.float32), 0.0),
            ckpt,
        )
        new_tok = jnp.where(emitted, nxt, tok)
        return (cache, new_tok, keep_drafting, key), out

    carry0 = (cache, prev_token, jnp.ones((B,), bool), key)
    (cache, _, _, _), (toks, qs, qf, emitted, confs, ckpts) = jax.lax.scan(
        step, carry0, None, length=k_max
    )
    toks = jnp.moveaxis(toks, 0, 1).astype(jnp.int32)
    qs = jnp.moveaxis(qs, 0, 1)
    emitted = jnp.moveaxis(emitted, 0, 1)
    confs = jnp.moveaxis(confs, 0, 1)
    lengths = emitted.sum(axis=1).astype(jnp.int32)
    return DraftResult(
        tokens=toks,
        q_sel=qs,
        q_full=jnp.moveaxis(qf, 0, 1) if keep_q_full else None,
        lengths=lengths,
        confidence=confs,
        cache=cache,
        cache_ckpts=ckpts if is_ssm else None,
        base_length=base_length,
    )


def resume_after_verify(model, draft: DraftResult, n_accepted: jax.Array):
    """Roll the device cache back to the server-verified prefix.

    Returns a cache whose committed length is ``base + 1 + n_accepted``
    (prev_token + accepted drafts); the next round feeds the server's
    correction/bonus token as ``prev_token``.
    """
    B = n_accepted.shape[0]
    new_len = draft.base_length + 1 + n_accepted.astype(jnp.int32)
    if draft.cache_ckpts is None:
        return {**draft.cache, "length": new_len}
    # ssm/hybrid: select per-row checkpoint n_accepted (leading axis = step).
    # Cache leaves are (L_or_napps, B, ...) plus length (B,); checkpointed
    # leaves gain a leading K axis, so: length -> (K, B), rest -> (K, L, B, ...).
    b = jnp.arange(B)

    def sel(a):
        if a.ndim == 2:  # length: (K, B)
            return a[n_accepted, b]
        return jnp.moveaxis(a[n_accepted, :, b], 0, 1)  # -> (L, B, ...)

    cache = jax.tree.map(sel, draft.cache_ckpts)
    return {**cache, "length": new_len}
