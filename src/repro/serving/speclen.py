"""Closed-loop adaptive speculation length (bounded AIMD).

SLED's ConfigSpec picks a static draft length per device class offline; the
heterogeneous-edge result (PAPERS.md, arXiv:2510.11331) is that the right
``k`` drifts at runtime with acceptance and server congestion.  The v2
Verdict frames feed back exactly those two signals — the round's
draft-acceptance ratio (per-round, so regime shifts register immediately;
this controller's EWMA does the smoothing) and the serving replica's queue
depth — and this controller closes the loop device-side:

  * additive increase  — acceptance high AND the replica queue shallow:
    speculation is paying, draft one more token per round (up to ``k_max``);
  * multiplicative decrease — acceptance low OR the queue deep: wrong drafts
    (or an oversubscribed replica) burn server verify compute, so halve the
    round length (down to ``k_min``).

AIMD keeps the control stable under the same argument as congestion control:
increases probe linearly, wrong guesses back off geometrically, and the
bounds make the worst case exactly the fixed-``k`` policies it replaces
(``k_min == k_max`` degenerates to fixed).  Acceptance is EWMA-smoothed so a
single unlucky round doesn't collapse ``k``.

Host-side and deterministic: the jitted draft scan always runs the fixed
``k_max`` shape and the proposal is truncated to ``k`` host-side
(EdgeDevice.draft(k=...)), so adapting never recompiles anything.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro import telemetry


@dataclasses.dataclass
class SpecLenController:
    """Bounded AIMD controller for the per-device speculation length ``k``.

    ``update(accept_rate, queue_depth)`` is called once per verdict and
    returns the length to draft next round.  All thresholds are plain
    constructor knobs so benchmarks can sweep them (ConfigSpec-style, but
    online).
    """

    k_max: int
    k_min: int = 1
    k_init: Optional[int] = None  # None: start at k_max (optimistic probe)
    increase: int = 1  # additive step up
    decrease: float = 0.5  # multiplicative back-off factor
    accept_hi: float = 0.7  # smoothed acceptance to justify a longer round
    accept_lo: float = 0.4  # below this, drafts are burning verify compute
    queue_hi: int = 2  # replica queue depth that reads as congestion
    ewma: float = 0.5  # smoothing on the acceptance feedback

    def __post_init__(self) -> None:
        if not (1 <= self.k_min <= self.k_max):
            raise ValueError(f"need 1 <= k_min <= k_max, got [{self.k_min}, {self.k_max}]")
        self.k = min(self.k_init or self.k_max, self.k_max)
        self.k = max(self.k, self.k_min)
        self._acc: Optional[float] = None
        self.updates = 0
        self.increases = 0
        self.decreases = 0

    @property
    def smoothed_accept(self) -> float:
        return self._acc if self._acc is not None else 1.0

    def update(self, accept_rate: float, queue_depth: int) -> int:
        """One feedback observation -> the next round's draft length."""
        a = float(accept_rate)
        self._acc = a if self._acc is None else self.ewma * a + (1 - self.ewma) * self._acc
        self.updates += 1
        congested = queue_depth > self.queue_hi
        if congested or self._acc < self.accept_lo:
            new_k = max(self.k_min, int(self.k * self.decrease))
            if new_k < self.k:
                self.decreases += 1
                telemetry.count("kctl_decrease_total")
            self.k = new_k
        elif self._acc >= self.accept_hi:
            new_k = min(self.k_max, self.k + self.increase)
            if new_k > self.k:
                self.increases += 1
                telemetry.count("kctl_increase_total")
            self.k = new_k
        telemetry.observe("kctl_k", self.k, buckets=telemetry.K_BUCKETS)
        return self.k


def make_controller(kctl: str, *, k_max: int, **kw) -> Optional[SpecLenController]:
    """``adaptive`` -> a controller, ``fixed`` -> None (draft k_max always)."""
    if kctl == "fixed":
        return None
    if kctl == "adaptive":
        return SpecLenController(k_max=k_max, **kw)
    raise ValueError(f"unknown kctl {kctl!r} (fixed | adaptive)")
