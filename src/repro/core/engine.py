"""Engine core: the pure single-replica verify stepper (no admission logic).

This is the bottom layer of the serving stack (SLED §III-B compute only):
a :class:`PagedKVCache` row pool plus the jitted prefill / bucketed
slot-indexed verify / force-extend steps that run against it.  Everything
policy-shaped — who is admitted, which requests batch together, when the
planner fires — lives one layer up (core/admission.py + core/server_engine.py),
and replica placement lives above that (cluster/router.py).  The core only
answers "verify THESE slots with THIS padded batch" and "append THESE tokens
to THAT slot", which is exactly the unit a cluster router schedules.

The jitted step bundle (:class:`VerifySteps`) is deliberately separable from
the pool so N replicas of the same model share one set of compiled
executables: compiled shapes depend only on (bucket, k_max, pool geometry),
so a replica fleet costs the same XLA compilation as one engine.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import verification
from repro.models.kvcache import PagedKVCache, gather_slots, supports_paged_attention
from repro.models.layers import NO_MESH, MeshContext

log = logging.getLogger(__name__)

# pool storage dtypes the engine understands; "int8" adds per-(slot, head)
# dequant-scale leaves and roughly halves bytes-per-slot (models/layers.py)
KV_DTYPES = {"bf16": jnp.bfloat16, "int8": jnp.int8}


def kv_dtype_name(kv_dtype) -> str:
    """Normalise a ``kv_dtype`` (spec string or jnp dtype) to its spec name."""
    if isinstance(kv_dtype, str):
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {kv_dtype!r} (one of {sorted(KV_DTYPES)})"
            )
        return kv_dtype
    return "int8" if kv_dtype == jnp.int8 else "bf16"


@dataclasses.dataclass
class Verdict:
    """Per-request outcome of one engine round (device resume protocol).

    ``accept_rate`` / ``queue_depth`` are the closed-loop feedback fields:
    THIS round's draft-acceptance ratio and the replica's planner queue
    depth right after dispatch.  Devices use them to adapt their speculation
    length online (serving/speclen.py — its EWMA does the smoothing, so the
    raw per-round signal stays responsive to regime shifts); they ride the
    wire in Verdict frames (transport/codec.py).
    """

    device_id: int
    n_accepted: int
    tokens: np.ndarray  # committed this round: accepted drafts + extra
    next_prev: int  # correction/bonus token the device feeds next round
    accept_rate: float = 0.0  # this round's accepted/drafted
    queue_depth: int = 0  # replica queue depth after this dispatch
    # server-timing breakdown (always populated — cheap host floats): how
    # long this round waited in the admission queue and how long its verify
    # step took, so receivers can attribute latency to queue vs verify vs wire
    queue_s: float = 0.0
    verify_s: float = 0.0


@dataclasses.dataclass
class RoundStats:
    time: float
    size: int  # batch fill (requests verified)
    bucket: int  # padded jit batch size
    queue_depth: int  # planner queue after dispatch
    n_commit: int  # tokens committed this round
    step_seconds: float  # wall time of the verify call


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving stats; field names mirror simulator.SimResult.

    The wire fields (bytes/frames both directions, drops) are zero for the
    in-process driver and filled in by transport.server.TransportServer from
    its link stats, so benchmarks emit one uniform record either way.
    """

    wstgr: float
    per_device_rate: float
    server_busy_frac: float
    rounds: int
    timeouts: int
    fallback_tokens: int
    mean_batch_fill: float
    mean_round_latency: float
    server_rounds_per_s: float
    partial_rounds: int = 0
    streams_served: int = 0
    acceptance_rate: float = 0.0
    mean_queue_depth: float = 0.0
    # wire stats (transport runtime only)
    bytes_tx: int = 0
    bytes_rx: int = 0
    frames_tx: int = 0
    frames_rx: int = 0
    frames_dropped: int = 0
    fallback_rounds: int = 0
    replicas: int = 1  # >1 only for cluster-merged records

    def to_json(self) -> dict:
        """The uniform stats record (json.dumps-safe) every driver and BENCH
        artifact emits — same shape in-process, over the wire, or merged."""
        return dataclasses.asdict(self)

    def as_dict(self):
        return self.to_json()

    @classmethod
    def merge(cls, stats: Sequence["EngineStats"]) -> "EngineStats":
        """Aggregate per-replica stats into one cluster-level record.

        Replicas serve concurrently, so count and throughput fields
        (rounds, wstgr, server_rounds_per_s, wire bytes/frames) sum; mean
        fields (batch fill, round latency, queue depth) and acceptance_rate
        are weighted by each replica's round count; busy fractions sum and
        are capped at 1.0 only in the sense that callers interpret >1 as
        "more than one replica's worth of compute" (single event loop runs
        them back to back).  ``per_device_rate`` is recomputed from the
        merged throughput over the summed stream counts (reconstructed from
        wstgr / per_device_rate per replica, falling back to streams_served).
        """
        stats = list(stats)
        if not stats:
            raise ValueError("EngineStats.merge needs at least one record")
        if len(stats) == 1:
            return dataclasses.replace(stats[0])
        rounds = [s.rounds for s in stats]
        total_rounds = sum(rounds)

        def wmean(vals):
            # idle replicas (0 rounds) carry no weight in the means
            if total_rounds == 0:
                return float(sum(vals) / len(vals))
            return float(sum(v * r for v, r in zip(vals, rounds)) / total_rounds)

        n_streams = []
        for s in stats:
            if s.per_device_rate > 0:
                n_streams.append(s.wstgr / s.per_device_rate)
            else:  # idle replica: contributes its served count (possibly 0)
                n_streams.append(float(s.streams_served))
        wstgr = sum(s.wstgr for s in stats)
        return cls(
            wstgr=wstgr,
            per_device_rate=wstgr / max(sum(n_streams), 1e-9),
            server_busy_frac=sum(s.server_busy_frac for s in stats),
            rounds=sum(rounds),
            timeouts=sum(s.timeouts for s in stats),
            fallback_tokens=sum(s.fallback_tokens for s in stats),
            mean_batch_fill=wmean([s.mean_batch_fill for s in stats]),
            mean_round_latency=wmean([s.mean_round_latency for s in stats]),
            server_rounds_per_s=sum(s.server_rounds_per_s for s in stats),
            partial_rounds=sum(s.partial_rounds for s in stats),
            streams_served=sum(s.streams_served for s in stats),
            acceptance_rate=wmean([s.acceptance_rate for s in stats]),
            mean_queue_depth=wmean([s.mean_queue_depth for s in stats]),
            bytes_tx=sum(s.bytes_tx for s in stats),
            bytes_rx=sum(s.bytes_rx for s in stats),
            frames_tx=sum(s.frames_tx for s in stats),
            frames_rx=sum(s.frames_rx for s in stats),
            frames_dropped=sum(s.frames_dropped for s in stats),
            fallback_rounds=sum(s.fallback_rounds for s in stats),
            replicas=sum(s.replicas for s in stats),
        )


def _pad_to(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    if a.shape[0] == n:
        return a
    pad = np.full((n - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad], axis=0)


class VerifySteps:
    """The jitted step bundle for one (model, serving config): prefill,
    bucketed slot-indexed verify, force-extend.

    Build it once and hand it to every :class:`EngineCore` replica of that
    model — jax.jit caches on the wrapped closure, so replicas sharing a
    bundle share compiled executables (same shapes, same functions) instead
    of each paying the full warmup.
    """

    def __init__(
        self,
        model: Any,
        *,
        scratch_slot: int,
        ctx: MeshContext = NO_MESH,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
        paged_attention: bool = True,
        kv_dtype: Any = "bf16",
    ):
        self.model = model
        self.greedy = greedy
        self.temperature = temperature
        self.scratch_slot = scratch_slot
        self.attn_chunk = attn_chunk
        # recorded for shared-bundle validation only: the jitted steps are
        # dtype-polymorphic (they retrace on leaf dtypes), but a fleet mixing
        # pool dtypes behind one bundle would silently compile everything
        # twice, defeating the shared-warmup contract
        self.kv_dtype = kv_dtype_name(kv_dtype)
        # slot-indexed verify attention straight out of the pool; SSM/hybrid
        # caches fall back to gather/scatter (their recurrent state leaves
        # are not position-indexed K/V — see models/kvcache.py)
        self.paged_attention = bool(paged_attention) and supports_paged_attention(model.cfg)
        self.verify = jax.jit(
            verification.make_paged_verify_step(
                model,
                scratch_slot=scratch_slot,
                ctx=ctx,
                greedy=greedy,
                temperature=temperature,
                attn_chunk=attn_chunk,
                paged_attention=self.paged_attention,
            )
        )
        self.prefill = jax.jit(
            verification.make_prefill_step(model, ctx=ctx, attn_chunk=attn_chunk)
        )
        self.extend = jax.jit(
            verification.make_force_extend_step(
                model,
                ctx=ctx,
                attn_chunk=attn_chunk,
                paged_attention=self.paged_attention,
            )
        )


class EngineCore:
    """Pure single-replica verify stepper: row pool + bucketed verification.

    Owns the :class:`PagedKVCache` pool and runs padded verify batches
    against arbitrary slot subsets.  It knows nothing about device streams,
    admission, planners, or policies — callers hand it slot ids and padded
    request arrays and get a VerifyResult back.  That separation is what
    lets a cluster router treat replicas as schedulable capacity.
    """

    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        n_slots: int,
        max_len: int,
        k_max: int,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
        ctx: MeshContext = NO_MESH,
        buckets: Optional[Sequence[int]] = None,
        batch_cap: Optional[int] = None,
        paged_attention: bool = True,
        steps: Optional[VerifySteps] = None,
        kv_dtype: Any = "bf16",
    ):
        self.model = model
        self.params = params
        self.k_max = k_max
        self.greedy = greedy
        self.kv_dtype = kv_dtype_name(kv_dtype)
        cache_kw: Dict[str, Any] = {"attn_chunk": attn_chunk}
        if self.kv_dtype == "int8":
            if not supports_paged_attention(model.cfg):
                raise ValueError(
                    f"kv_dtype='int8' is not supported for the "
                    f"{model.cfg.family!r} family: its recurrent-state cache "
                    "leaves ride the gather/scatter fallback "
                    "(models/kvcache.py), which has no quantized layout — "
                    "serve it with kv_dtype='bf16'"
                )
            cache_kw["kv_dtype"] = KV_DTYPES["int8"]
        self.pool = PagedKVCache(model, n_slots, max_len, **cache_kw)
        if steps is not None:
            # a mismatched shared bundle would fail (or recompile every
            # bucket behind warmup's back) deep inside step(); fail at the
            # constructor with the actual disagreement instead
            want_paged = bool(paged_attention) and supports_paged_attention(model.cfg)
            mismatches = [
                (name, got, want)
                for name, got, want in (
                    ("scratch_slot", steps.scratch_slot, self.pool.scratch_slot),
                    ("model", steps.model, model),
                    ("greedy", steps.greedy, greedy),
                    ("temperature", steps.temperature, temperature),
                    ("attn_chunk", steps.attn_chunk, attn_chunk),
                    ("paged_attention", steps.paged_attention, want_paged),
                    ("kv_dtype", steps.kv_dtype, self.kv_dtype),
                )
                if got is not want and got != want
            ]
            if mismatches:
                raise ValueError(
                    "shared VerifySteps bundle does not match this engine "
                    "(replicas must be homogeneous to share compiled steps): "
                    + ", ".join(f"{n}: bundle={g!r} engine={w!r}" for n, g, w in mismatches)
                )
        self.steps = steps or VerifySteps(
            model,
            scratch_slot=self.pool.scratch_slot,
            ctx=ctx,
            greedy=greedy,
            temperature=temperature,
            attn_chunk=attn_chunk,
            paged_attention=paged_attention,
            kv_dtype=self.kv_dtype,
        )
        self.paged_attention = self.steps.paged_attention
        if telemetry.enabled():
            # pool capacity gauges: the memory-ceiling story (ISSUE: int8
            # roughly halves bytes_per_slot, doubling slots per HBM byte)
            reg = telemetry.registry()
            reg.gauge("engine_kv_pool_bytes").set(float(self.pool.pool_bytes()))
            reg.gauge("engine_bytes_per_slot").set(float(self.pool.bytes_per_slot()))
        cap = batch_cap or n_slots
        self.batch_cap = cap
        if buckets is None:
            buckets, b = [], 1
            while b < cap:
                buckets.append(b)
                b *= 2
            buckets.append(cap)
        self.buckets = sorted(set(buckets))
        self.compile_log: Dict[int, float] = {}  # bucket -> warmup seconds
        self._seed = 0

    # -- slot lifecycle ------------------------------------------------------

    def alloc_slot(self) -> int:
        """Free pool row for a new stream; raises SlotExhausted when full."""
        return self.pool.alloc()

    def free_slot(self, slot: int) -> None:
        self.pool.free(slot)

    @property
    def n_free(self) -> int:
        return self.pool.n_free

    def prefill_slot(self, slot: int, prompt: jax.Array) -> int:
        """Prefill ``prompt`` into pool row ``slot``; returns the last prompt
        token (the stream's first ``prev_token``)."""
        with telemetry.span("engine_prefill_seconds"):
            row = self.pool.make_row_cache()
            prompt = jnp.asarray(prompt, jnp.int32)
            _, row, prev = self.steps.prefill(self.params, row, prompt[None, :])
            self.pool.write_slot(slot, row)
            return int(prev[0])

    def export_row(self, slot: int) -> Dict[str, jax.Array]:
        """Dense batch-1 copy of pool row ``slot`` (stream migration: the
        row moves to another replica's pool bit-identically)."""
        return gather_slots(self.pool.cache, jnp.asarray([slot], jnp.int32))

    def import_row(self, slot: int, row_cache: Dict[str, jax.Array]) -> None:
        """Install an exported row into pool row ``slot``."""
        self.pool.write_slot(slot, row_cache)

    # -- compute -------------------------------------------------------------

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> Dict[int, float]:
        """Compile the verify step for bucket sizes up front (batches of
        scratch-slot rows), so measured runs never pay a mid-serving compile.
        Safe anytime: scratch contents are never read as committed state.

        ``buckets`` selects a subset of ``self.buckets`` (deployments budget
        startup by warming only the fills they expect; the rest compile
        lazily on first dispatch).  Returns ``{bucket: compile_seconds}``
        for this call — also accumulated in ``self.compile_log`` and logged
        at INFO so startup budgets are observable (ROADMAP "bucket
        compilation budget")."""
        if buckets is None:
            selected = list(self.buckets)
        else:
            selected = sorted(set(int(b) for b in buckets))
            unknown = [b for b in selected if b not in self.buckets]
            if unknown:
                raise ValueError(
                    f"unknown warmup buckets {unknown}; engine buckets are {self.buckets}"
                )
        times: Dict[int, float] = {}
        for b in selected:
            t0 = time.perf_counter()
            vb = verification.make_verify_batch(
                jnp.zeros((b,), jnp.int32),
                jnp.zeros((b, self.k_max), jnp.int32),
                jnp.zeros((b,), jnp.int32),
                draft_q=None if self.greedy else jnp.zeros((b, self.k_max), jnp.float32),
                seed=np.uint32(0),
            )
            slots = jnp.full((b,), self.pool.scratch_slot, jnp.int32)
            _, self.pool.cache = self.steps.verify(self.params, self.pool.cache, slots, vb)
            jax.block_until_ready(self.pool.cache["length"])
            times[b] = time.perf_counter() - t0
            log.info("warmup: bucket %d verify step ready in %.2fs", b, times[b])
        self.compile_log.update(times)
        return times

    def verify(
        self,
        slots: np.ndarray,
        prev: np.ndarray,
        toks: np.ndarray,
        qs: Optional[np.ndarray],
        lens: np.ndarray,
    ) -> Tuple[Any, int, float]:
        """One bucketed verify pass over pool rows ``slots``.

        Inputs are the un-padded per-request arrays; the core pads them to
        the enclosing bucket (scratch-slot rows for the fill) and commits
        the accepted prefixes into the pool.  Returns
        ``(VerifyResult, bucket, step_seconds)``.
        """
        t_wall = time.perf_counter()
        bucket = self.bucket_for(slots.shape[0])
        slots_p = _pad_to(np.asarray(slots, np.int32), bucket, fill=self.pool.scratch_slot)
        vb = verification.make_verify_batch(
            jnp.asarray(_pad_to(prev, bucket)),
            jnp.asarray(_pad_to(toks, bucket)),
            jnp.asarray(_pad_to(lens, bucket)),
            draft_q=jnp.asarray(_pad_to(qs, bucket)) if qs is not None else None,
            seed=np.uint32(self._seed),
        )
        res, self.pool.cache = self.steps.verify(
            self.params, self.pool.cache, jnp.asarray(slots_p), vb
        )
        self._seed += 1
        step_seconds = time.perf_counter() - t_wall
        if telemetry.enabled():
            telemetry.observe("engine_verify_seconds", step_seconds)
            telemetry.observe(
                "engine_verify_fill", slots.shape[0], buckets=telemetry.K_BUCKETS
            )
        return res, bucket, step_seconds

    def force_extend(self, slot: int, feed: np.ndarray) -> None:
        """Append ``feed`` (already shifted to satisfy the KV invariant) to
        pool row ``slot`` without verification (§III-A fallback resync)."""
        with telemetry.span("engine_commit_seconds"):
            self._force_extend(slot, feed)

    def _force_extend(self, slot: int, feed: np.ndarray) -> None:
        padded = np.zeros((self.k_max + 1,), np.int32)
        padded[: feed.size] = feed
        self.pool.cache = self.steps.extend(
            self.params,
            self.pool.cache,
            jnp.asarray([slot], jnp.int32),
            jnp.asarray(padded[None, :]),
            jnp.asarray([feed.size], jnp.int32),
        )
