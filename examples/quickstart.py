"""Quickstart: the ``repro.api`` front door in a dozen lines.

    PYTHONPATH=src python examples/quickstart.py

One declarative ServeSpec builds the whole stack; a Session streams typed
events; the reference backend (lock-step sled_generate loop) run on the
same spec proves the served stream is lossless.
"""
from repro.api import ModelSpec, ServeSpec, System, TokenEvent

spec = ServeSpec(
    backend="engine",
    model=ModelSpec(vocab_size=128, target_layers=2, draft_noise=0.03),
    devices=2, prompt_len=8, max_new=16,
)


def main() -> None:
    system = System.build(spec)
    session = system.open_session()
    tokens = [ev.token for ev in session.generate() if isinstance(ev, TokenEvent)]
    r = session.result
    print(f"streamed {len(tokens)} tokens: {tokens}")
    print(f"rounds {r.rounds}, acceptance {r.acceptance_rate:.2f}")
    ref = System.build(spec.with_backend("reference"), models=system.models).serve()
    lossless = ref.outputs[session.device_id] == r.tokens
    print(f"lossless vs lock-step reference: {lossless}")
    assert lossless


if __name__ == "__main__":
    main()
