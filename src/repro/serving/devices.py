"""Edge-device + server hardware profiles (paper §IV setup).

Drafting rates are llama.cpp-style decode tokens/s for the paper's draft
models at each weight precision — calibrated to public llama.cpp benchmarks
on the same boards (RPi 4B/5, Jetson Orin Nano).  Power/price numbers follow
the paper's cost model sources: RPi 5 at $80 [34] drawing 8 W [35],
industrial electricity at 0.083 $/kWh [36], 3-year amortisation at 70%
utilisation [32].

The server profile covers both the paper's testbed (4x A100-80GB) and our
deployment target (TPU v5e pod slice) — the v5e verification latency can
also be taken directly from the dry-run roofline (benchmarks wire that up).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class NetProfile:
    """Edge<->server link characteristics (paper §IV service-area network).

    One profile drives BOTH the discrete-event simulator's RTT model and the
    transport runtime's SimulatedLink (transport/links.py), so predictions and
    measurements share a single network configuration.
    """

    name: str
    rtt_mean: float          # seconds, full round trip
    rtt_jitter: float        # gaussian sigma on the round trip
    bandwidth_bps: float     # per-direction serialization rate
    drop_prob: float = 0.0   # per-frame loss -> exercises §III-A fallback

    @property
    def one_way(self) -> float:
        return self.rtt_mean / 2


ETHERNET = NetProfile("ethernet", rtt_mean=0.001, rtt_jitter=0.0001, bandwidth_bps=1e9)
WLAN = NetProfile("wlan", rtt_mean=0.020, rtt_jitter=0.005, bandwidth_bps=100e6)
LTE = NetProfile("lte", rtt_mean=0.050, rtt_jitter=0.015, bandwidth_bps=20e6, drop_prob=0.005)
LOSSY_WLAN = NetProfile(
    "lossy-wlan", rtt_mean=0.020, rtt_jitter=0.005, bandwidth_bps=100e6, drop_prob=0.05
)

NETS = {n.name: n for n in (ETHERNET, WLAN, LTE, LOSSY_WLAN)}


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    price_usd: float
    power_w: float
    # decode tokens/s by (draft model, bits)
    draft_rate: Dict[Tuple[str, int], float]
    # how this device class reaches the edge server (paper testbed: WLAN)
    net: NetProfile = WLAN

    def rate(self, model: str = "llama-1b-draft", bits: int = 4) -> float:
        try:
            return self.draft_rate[(model, bits)]
        except KeyError:
            combos = ", ".join(
                f"({m!r}, {b})" for m, b in sorted(self.draft_rate)
            )
            raise KeyError(
                f"device class {self.name!r} has no draft rate for "
                f"(model={model!r}, bits={bits}); available combos: {combos}"
            ) from None


RPI4B = DeviceProfile(
    name="rpi4b", price_usd=55.0, power_w=6.0,
    draft_rate={
        ("llama-1b-draft", 16): 0.9, ("llama-1b-draft", 8): 1.7,
        ("llama-1b-draft", 4): 3.1,
        ("llama-3b-draft", 16): 0.3, ("llama-3b-draft", 8): 0.6,
        ("llama-3b-draft", 4): 1.1,
    },
)

RPI5 = DeviceProfile(
    name="rpi5", price_usd=80.0, power_w=8.0,
    draft_rate={
        ("llama-1b-draft", 16): 2.3, ("llama-1b-draft", 8): 4.4,
        ("llama-1b-draft", 4): 8.2,
        ("llama-3b-draft", 16): 0.9, ("llama-3b-draft", 8): 1.8,
        ("llama-3b-draft", 4): 3.4,
    },
)

JETSON_ORIN_NANO = DeviceProfile(
    name="jetson-orin-nano", price_usd=249.0, power_w=15.0,
    draft_rate={
        ("llama-1b-draft", 16): 6.5, ("llama-1b-draft", 8): 12.0,
        ("llama-1b-draft", 4): 21.0,
        ("llama-3b-draft", 16): 2.4, ("llama-3b-draft", 8): 4.6,
        ("llama-3b-draft", 4): 8.8,
    },
)

DEVICES = {d.name: d for d in (RPI4B, RPI5, JETSON_ORIN_NANO)}


@dataclasses.dataclass(frozen=True)
class ServerProfile:
    name: str
    price_usd: float
    power_w: float
    peak_flops: float       # aggregate
    hbm_bw: float           # aggregate bytes/s
    launch_overhead_s: float  # fixed per-batch overhead (driver/launch)

    def verify_latency(self, target_params: float, batch: int, k1: int,
                       cache_tokens: int = 1024, kv_bytes_per_tok: float = 2 * 8 * 128 * 2,
                       bits: int = 16) -> float:
        """One batched verification forward: max(weight stream, compute, kv).

        Weight-streaming dominates at small batch (the reason batching pays:
        Fig. 4's rising WSTGR); compute takes over at large batch x K.
        """
        wbytes = target_params * bits / 8
        t_mem = wbytes / self.hbm_bw + batch * cache_tokens * kv_bytes_per_tok / self.hbm_bw
        t_compute = 2.0 * target_params * batch * k1 / self.peak_flops
        return self.launch_overhead_s + max(t_mem, t_compute)

    def decode_latency(self, target_params: float, batch: int, **kw) -> float:
        """Centralized autoregressive decoding: one token per request."""
        return self.verify_latency(target_params, batch, 1, **kw)


A100_X4 = ServerProfile(
    name="a100x4", price_usd=60_000.0, power_w=2_200.0,
    peak_flops=4 * 312e12, hbm_bw=4 * 2.0e12, launch_overhead_s=0.004,
)

# TPU v5e 16-chip slice (1 row of the pod): assignment constants
V5E_16 = ServerProfile(
    name="v5e-16", price_usd=40_000.0, power_w=16 * 200.0,
    peak_flops=16 * 197e12, hbm_bw=16 * 819e9, launch_overhead_s=0.002,
)

SERVERS = {s.name: s for s in (A100_X4, V5E_16)}

ELECTRICITY_USD_PER_KWH = 0.083  # EIA industrial rate [36]
