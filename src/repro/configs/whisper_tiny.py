"""whisper-tiny [audio]: enc-dec, conv frontend stubbed as frame embeddings.

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register

WHISPER_TINY = register(
    ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,  # decoder layers
        encoder_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        act="gelu",
        norm="layernorm",
        use_rope=False,  # whisper uses learned/sinusoidal positions
        qkv_bias=True,
        is_encdec=True,
        encoder_seq=1500,  # 30 s of audio -> 1500 frames (stub frontend)
        notes="enc-dec; conv frontend is a stub (precomputed frame embeddings)",
    )
)
