"""Continuous-batching verification engine over a paged KV-cache pool.

This is the real-model counterpart of serving/simulator.py's server loop
(SLED §III-B): verification requests from heterogeneous edge devices queue
in a BatchPlanner, and whenever the policy fires the engine verifies the
scheduled SUBSET of device streams in one forward pass — partial fills,
heterogeneous draft lengths, devices joining and leaving mid-stream — by
gathering their pool rows into a dense bucket-sized batch (models/kvcache.py)
and scattering committed state back.  The seed's serve loop could only
verify the full device set in lock-step; this engine is what lets the
``continuous`` and ``deadline`` policies run against real models.

Per-round and aggregate stats mirror serving/simulator.SimResult field names
so discrete-event predictions can be cross-checked against real-model runs
(benchmarks/wstgr.py --engine does exactly that).

Layering: ServerEngine is verification-side only.  EdgeDeviceKit/EdgeDevice
are the host-side stand-ins for device drafting loops (batch-1 draft model
per device, shared jitted step), used by launch/serve.py and the tests.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drafting, verification
from repro.core.scheduler import BatchPlanner, VerifyRequest
from repro.models.kvcache import PagedKVCache, SlotExhausted, supports_paged_attention
from repro.models.layers import NO_MESH, MeshContext

log = logging.getLogger(__name__)


@dataclasses.dataclass
class DeviceStream:
    """Server-side state of one admitted device stream."""

    device_id: int
    slot: int
    prev_token: int
    committed: List[int] = dataclasses.field(default_factory=list)
    admitted_at: float = 0.0
    rounds: int = 0


@dataclasses.dataclass
class Verdict:
    """Per-request outcome of one engine round (device resume protocol)."""

    device_id: int
    n_accepted: int
    tokens: np.ndarray  # committed this round: accepted drafts + extra
    next_prev: int  # correction/bonus token the device feeds next round


@dataclasses.dataclass
class RoundStats:
    time: float
    size: int  # batch fill (requests verified)
    bucket: int  # padded jit batch size
    queue_depth: int  # planner queue after dispatch
    n_commit: int  # tokens committed this round
    step_seconds: float  # wall time of the verify call


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving stats; field names mirror simulator.SimResult.

    The wire fields (bytes/frames both directions, drops) are zero for the
    in-process driver and filled in by transport.server.TransportServer from
    its link stats, so benchmarks emit one uniform record either way.
    """

    wstgr: float
    per_device_rate: float
    server_busy_frac: float
    rounds: int
    timeouts: int
    fallback_tokens: int
    mean_batch_fill: float
    mean_round_latency: float
    server_rounds_per_s: float
    partial_rounds: int = 0
    streams_served: int = 0
    acceptance_rate: float = 0.0
    mean_queue_depth: float = 0.0
    # wire stats (transport runtime only)
    bytes_tx: int = 0
    bytes_rx: int = 0
    frames_tx: int = 0
    frames_rx: int = 0
    frames_dropped: int = 0
    fallback_rounds: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def _pad_to(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    if a.shape[0] == n:
        return a
    pad = np.full((n - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad], axis=0)


class ServerEngine:
    """Admission + step loop: PagedKVCache pool, BatchPlanner policies,
    bucketed slot-indexed verification.

    Typical driver loop (see launch/serve.py)::

        engine = ServerEngine(target, tp, n_slots=8, max_len=256, k_max=4)
        engine.admit(device_id, prompt, now)          # joins a free slot
        engine.submit(device_id, draft_tokens, now)   # device -> server hop
        verdicts = engine.step(now)                   # policy may dispatch
        engine.retire(device_id)                      # frees the slot
    """

    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        n_slots: int,
        max_len: int,
        k_max: int,
        policy: str = "continuous",
        batch_size: Optional[int] = None,
        max_wait: float = 0.050,
        straggler_timeout: float = 1.0,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
        ctx: MeshContext = NO_MESH,
        buckets: Optional[Sequence[int]] = None,
        paged_attention: bool = True,
    ):
        self.model = model
        self.params = params
        self.k_max = k_max
        self.greedy = greedy
        # slot-indexed verify attention straight out of the pool; SSM/hybrid
        # caches fall back to gather/scatter (their recurrent state leaves
        # are not position-indexed K/V — see models/kvcache.py)
        self.paged_attention = bool(paged_attention) and supports_paged_attention(model.cfg)
        self.pool = PagedKVCache(model, n_slots, max_len, attn_chunk=attn_chunk)
        cap = batch_size or n_slots
        self._batch_cap = cap
        self.planner = BatchPlanner(
            batch_size=cap,
            k_max=k_max,
            policy=policy,
            max_wait=max_wait,
            straggler_timeout=straggler_timeout,
        )
        if buckets is None:
            buckets, b = [], 1
            while b < cap:
                buckets.append(b)
                b *= 2
            buckets.append(cap)
        self.buckets = sorted(set(buckets))
        self._verify = jax.jit(
            verification.make_paged_verify_step(
                model,
                scratch_slot=self.pool.scratch_slot,
                ctx=ctx,
                greedy=greedy,
                temperature=temperature,
                attn_chunk=attn_chunk,
                paged_attention=self.paged_attention,
            )
        )
        self._prefill = jax.jit(
            verification.make_prefill_step(model, ctx=ctx, attn_chunk=attn_chunk)
        )
        self._extend = jax.jit(
            verification.make_force_extend_step(
                model,
                ctx=ctx,
                attn_chunk=attn_chunk,
                paged_attention=self.paged_attention,
            )
        )
        self.compile_log: Dict[int, float] = {}  # bucket -> warmup seconds
        self.streams: Dict[int, DeviceStream] = {}
        self.round_log: List[RoundStats] = []
        self._inflight: set = set()  # device_ids with a queued request
        self._timeouts = 0
        self._seed = 0
        self._req_id = 0
        self._t0: Optional[float] = None
        self._t_last = 0.0
        self._committed_total = 0
        self._streams_served = 0
        self._busy_seconds = 0.0
        self._latencies: List[float] = []
        self._drafted = 0
        self._accepted = 0
        self._fallback_tokens = 0
        self._fallback_rounds = 0

    # -- admission -----------------------------------------------------------

    def admit(self, device_id: int, prompt: jax.Array, now: float = 0.0) -> Optional[DeviceStream]:
        """Prefill ``prompt`` into a free pool slot; None when the pool is full
        (the device retries once a stream retires)."""
        if device_id in self.streams:
            raise ValueError(f"device {device_id} already admitted")
        try:
            slot = self.pool.alloc()
        except SlotExhausted:
            return None
        row = self.pool.make_row_cache()
        prompt = jnp.asarray(prompt, jnp.int32)
        _, row, prev = self._prefill(self.params, row, prompt[None, :])
        self.pool.write_slot(slot, row)
        stream = DeviceStream(device_id, slot, int(prev[0]), admitted_at=now)
        self.streams[device_id] = stream
        if self._t0 is None:
            self._t0 = now
        return stream

    def retire(self, device_id: int) -> DeviceStream:
        """Stream finished (or left): free its slot for the next admission.
        Any still-queued request from the device is discarded."""
        stream = self.streams.pop(device_id)
        if device_id in self._inflight:
            self.planner.queue = type(self.planner.queue)(
                r for r in self.planner.queue if r.device_id != device_id
            )
            self._inflight.discard(device_id)
        self.pool.free(stream.slot)
        self._streams_served += 1
        return stream

    # -- request queue -------------------------------------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        stream = self.streams[device_id]
        if device_id in self._inflight:
            # a second in-flight request would put the same cache row twice
            # in one scatter (undefined winner) — the device must wait for
            # its verdict (EdgeDevice.awaiting mirrors this server-side)
            raise ValueError(f"device {device_id} already has a request in flight")
        if not self.greedy and draft_q is None:
            raise ValueError("sampling mode needs per-request draft_q")
        if self.greedy:
            # greedy verification ignores q — and feeding it anyway would
            # change the jitted verify batch's pytree structure and recompile
            # every bucket behind warmup()'s back
            draft_q = None
        self.planner.add(
            VerifyRequest(
                device_id=device_id,
                arrival=now,
                prev_token=stream.prev_token,
                draft_tokens=np.asarray(draft_tokens),
                draft_q=draft_q,
                request_id=self._req_id,
            )
        )
        self._inflight.add(device_id)
        self._req_id += 1

    def cancel_request(self, device_id: int) -> bool:
        """Withdraw the device's queued request (transport fallback protocol:
        the device timed out and released its drafts locally).  Returns False
        when nothing is queued — i.e. the request was already verified and a
        verdict is on its way, which the caller must treat as authoritative."""
        if device_id not in self._inflight:
            return False
        self.planner.queue = type(self.planner.queue)(
            r for r in self.planner.queue if r.device_id != device_id
        )
        self._inflight.discard(device_id)
        return True

    def force_extend(self, device_id: int, tokens: np.ndarray) -> int:
        """Append ``tokens`` to the stream unverified (§III-A fallback resync:
        the device already released them to the user).  Returns the stream's
        new prev token; the device drafts from there next round."""
        stream = self.streams[device_id]
        if device_id in self._inflight:
            raise ValueError(f"device {device_id} still has a request in flight")
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size == 0:
            return stream.prev_token
        if toks.size > self.k_max + 1:
            raise ValueError(f"fallback run of {toks.size} exceeds k_max+1")
        # KV invariant: the last committed token is never in the cache, so we
        # feed [prev, t_1 .. t_{n-1}] and the new prev becomes t_n
        feed = np.concatenate([[stream.prev_token], toks[:-1]]).astype(np.int32)
        padded = np.zeros((self.k_max + 1,), np.int32)
        padded[: feed.size] = feed
        self.pool.cache = self._extend(
            self.params,
            self.pool.cache,
            jnp.asarray([stream.slot], jnp.int32),
            jnp.asarray(padded[None, :]),
            jnp.asarray([feed.size], jnp.int32),
        )
        stream.committed.extend(int(t) for t in toks)
        stream.prev_token = int(toks[-1])
        self._committed_total += toks.size
        self._fallback_tokens += toks.size
        self._fallback_rounds += 1
        return stream.prev_token

    def has_inflight(self, device_id: int) -> bool:
        """True while the device has a queued (unverdicted) request."""
        return device_id in self._inflight

    @property
    def queue_depth(self) -> int:
        return len(self.planner.queue)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> Dict[int, float]:
        """Compile the verify step for bucket sizes up front (batches of
        scratch-slot rows), so measured runs never pay a mid-serving compile.
        Safe anytime: scratch contents are never read as committed state.

        ``buckets`` selects a subset of ``self.buckets`` (deployments budget
        startup by warming only the fills they expect; the rest compile
        lazily on first dispatch).  Returns ``{bucket: compile_seconds}``
        for this call — also accumulated in ``self.compile_log`` and logged
        at INFO so startup budgets are observable (ROADMAP "bucket
        compilation budget")."""
        if buckets is None:
            selected = list(self.buckets)
        else:
            selected = sorted(set(int(b) for b in buckets))
            unknown = [b for b in selected if b not in self.buckets]
            if unknown:
                raise ValueError(
                    f"unknown warmup buckets {unknown}; engine buckets are {self.buckets}"
                )
        times: Dict[int, float] = {}
        for b in selected:
            t0 = time.perf_counter()
            vb = verification.make_verify_batch(
                jnp.zeros((b,), jnp.int32),
                jnp.zeros((b, self.k_max), jnp.int32),
                jnp.zeros((b,), jnp.int32),
                draft_q=None if self.greedy else jnp.zeros((b, self.k_max), jnp.float32),
                seed=np.uint32(0),
            )
            slots = jnp.full((b,), self.pool.scratch_slot, jnp.int32)
            _, self.pool.cache = self._verify(self.params, self.pool.cache, slots, vb)
            jax.block_until_ready(self.pool.cache["length"])
            times[b] = time.perf_counter() - t0
            log.info("warmup: bucket %d verify step ready in %.2fs", b, times[b])
        self.compile_log.update(times)
        return times

    # -- the serving hot loop ------------------------------------------------

    def step(self, now: float) -> Optional[List[Verdict]]:
        """Ask the planner for a batch; if the policy fires, verify that row
        subset and commit.  Returns per-request verdicts, or None."""
        # closed loop: never wait for more requests than there are active
        # streams (mirrors the simulator's eff_batch cap) — otherwise the
        # static policy deadlocks as soon as the first stream retires
        self.planner.batch_size = max(1, min(self._batch_cap, len(self.streams) or 1))
        batch = self.planner.next_batch(now, server_idle=True)
        # straggler-evicted requests from still-active streams are requeued
        # with a fresh arrival; a device that gave up instead cancels via
        # cancel_request + force_extend (the transport fallback protocol) —
        # in-process drivers never abandon, so requeueing is always safe here
        if self.planner.dropped:
            for req in self.planner.dropped:
                if req.device_id in self.streams:
                    self._timeouts += 1
                    req.arrival = now
                    self.planner.add(req)
                else:
                    self._inflight.discard(req.device_id)
            self.planner.dropped = []
        if batch is None:
            return None
        t_wall = time.perf_counter()
        prev, toks, qs, lens = batch.padded_arrays()
        bucket = self._bucket(batch.size)
        slots = np.asarray(
            [self.streams[r.device_id].slot for r in batch.requests], np.int32
        )
        slots = _pad_to(slots, bucket, fill=self.pool.scratch_slot)
        vb = verification.make_verify_batch(
            jnp.asarray(_pad_to(prev, bucket)),
            jnp.asarray(_pad_to(toks, bucket)),
            jnp.asarray(_pad_to(lens, bucket)),
            draft_q=(
                jnp.asarray(_pad_to(qs, bucket))
                if any(r.draft_q is not None for r in batch.requests)
                else None
            ),
            seed=np.uint32(self._seed),
        )
        res, self.pool.cache = self._verify(
            self.params, self.pool.cache, jnp.asarray(slots), vb
        )
        self._seed += 1

        out_tokens = np.asarray(res.out_tokens)
        n_accepted = np.asarray(res.n_accepted)
        n_commit = np.asarray(res.n_commit)
        extra = np.asarray(res.extra_token)
        verdicts = []
        committed_round = 0
        for i, req in enumerate(batch.requests):
            stream = self.streams[req.device_id]
            self._inflight.discard(req.device_id)
            self._drafted += int(lens[i])
            self._accepted += int(n_accepted[i])
            n = int(n_commit[i])
            toks_i = out_tokens[i, :n]
            stream.committed.extend(int(t) for t in toks_i)
            stream.prev_token = int(extra[i])
            stream.rounds += 1
            committed_round += n
            self._latencies.append(now - req.arrival)
            verdicts.append(
                Verdict(
                    device_id=req.device_id,
                    n_accepted=int(n_accepted[i]),
                    tokens=toks_i,
                    next_prev=int(extra[i]),
                )
            )
        step_seconds = time.perf_counter() - t_wall
        self._busy_seconds += step_seconds
        self._committed_total += committed_round
        self._t_last = max(self._t_last, now)
        self.round_log.append(
            RoundStats(
                time=now,
                size=batch.size,
                bucket=bucket,
                queue_depth=len(self.planner.queue),
                n_commit=committed_round,
                step_seconds=step_seconds,
            )
        )
        return verdicts

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        elapsed = max((now if now is not None else self._t_last) - (self._t0 or 0.0), 1e-9)
        fills = [r.size for r in self.round_log]
        n_streams = max(self._streams_served + len(self.streams), 1)
        return EngineStats(
            wstgr=self._committed_total / elapsed,
            per_device_rate=self._committed_total / n_streams / elapsed,
            server_busy_frac=self._busy_seconds / elapsed,
            rounds=len(self.round_log),
            timeouts=self._timeouts,
            fallback_tokens=self._fallback_tokens,  # transport resyncs land here
            mean_batch_fill=float(np.mean(fills)) if fills else 0.0,
            mean_round_latency=float(np.mean(self._latencies)) if self._latencies else 0.0,
            server_rounds_per_s=len(self.round_log) / elapsed,
            partial_rounds=sum(1 for r in self.round_log if r.size < self._batch_cap),
            streams_served=self._streams_served,
            acceptance_rate=self._accepted / max(self._drafted, 1),
            mean_queue_depth=(
                float(np.mean([r.queue_depth for r in self.round_log]))
                if self.round_log
                else 0.0
            ),
            fallback_rounds=self._fallback_rounds,
        )


# ---------------------------------------------------------------------------
# Device side: batch-1 drafting loops sharing one jitted step
# ---------------------------------------------------------------------------


class EdgeDeviceKit:
    """Shared jitted draft/prefill steps for a fleet of batch-1 edge devices.

    One kit per (draft model, drafting config): every EdgeDevice spawned from
    it reuses the same compiled functions, so a 64-device fleet costs the
    same compilation as one device.
    """

    def __init__(
        self,
        draft_model: Any,
        draft_params: Any,
        *,
        k_max: int,
        c_th: float = 0.0,
        greedy: bool = True,
        temperature: float = 1.0,
        attn_chunk: int = 32,
    ):
        self.model = draft_model
        self.params = draft_params
        self.k_max = k_max
        self._prefill = jax.jit(
            verification.make_prefill_step(draft_model, attn_chunk=attn_chunk)
        )
        self._draft = jax.jit(
            lambda p, cache, prev, key: drafting.draft_round(
                draft_model,
                p,
                cache,
                prev,
                key,
                k_max=k_max,
                c_th=c_th,
                temperature=temperature,
                greedy=greedy,
                keep_q_full=not greedy,
                attn_chunk=attn_chunk,
            )
        )

        # greedy next-token peek (no cache commit): the device's own guess at
        # the bonus token, which seeds pipelined draft-ahead rounds
        def _peek_fn(p, cache, tok):
            h, _, _ = draft_model.decode_forward(p, cache, tok[:, None], attn_chunk=attn_chunk)
            return jnp.argmax(draft_model.lm_head(p, h)[:, 0], axis=-1).astype(jnp.int32)

        self._peek = jax.jit(_peek_fn)
        # draft-ahead replays the post-acceptance state exactly; attention
        # caches roll back by length, but ssm/hybrid recurrences would need
        # checkpoint surgery mid-round — those kits draft strictly in-order
        self.supports_pipeline = greedy and draft_model.cfg.family not in ("ssm", "hybrid")
        self._attn_chunk = attn_chunk

    def spawn(self, device_id: int, prompt: jax.Array, *, max_len: int, seed: int = 0):
        return EdgeDevice(self, device_id, prompt, max_len=max_len, seed=seed)


class EdgeDevice:
    """One edge device's drafting loop (SLED §III-A), batch size 1.

    Supports pipelined draft-ahead (SpecEdge-style): after submitting a round
    the device may keep drafting on the assumption that every token will be
    accepted, seeding the ahead round with its own greedy guess at the bonus
    token.  If the verdict confirms both (full acceptance AND the bonus guess
    was right), the pre-drafted round is submitted with zero draft latency —
    and because greedy drafting is deterministic from (cache, prev), those
    tokens are bit-identical to what a fresh round would have produced, so
    pipelining never changes outputs.  On any miss the ahead work is simply
    discarded (JAX caches are immutable pytrees; rollback is keeping the old
    reference).
    """

    def __init__(self, kit: EdgeDeviceKit, device_id: int, prompt, *, max_len: int, seed: int):
        self.kit = kit
        self.device_id = device_id
        cache = kit.model.make_cache(1, max_len, attn_chunk=kit._attn_chunk)
        prompt = jnp.asarray(prompt, jnp.int32)
        _, self.cache, self.prev = kit._prefill(kit.params, cache, prompt[None, :])
        self.key = jax.random.key(seed)
        self.committed: List[int] = []
        self._pending: Optional[drafting.DraftResult] = None
        self._ahead: Optional[tuple] = None  # (bonus_guess, cache_acc, dres)
        self.pending_q: Optional[np.ndarray] = None
        self.pipeline_hits = 0
        self.pipeline_misses = 0
        self.fallback_tokens = 0
        self.drafted = 0
        self.draft_seconds = 0.0  # wall time inside draft() — calibrates
        # the simulator's device_rate against real measured drafting

    def draft(self) -> np.ndarray:
        """Draft up to k_max tokens; returns the variable-length proposal.
        ``pending_q`` holds the matching q(token) row for sampling-mode
        submits (engine.submit(..., draft_q=dev.pending_q))."""
        assert self._pending is None, "previous round still awaiting a verdict"
        t = time.perf_counter()
        self.key, k = jax.random.split(self.key)
        dres = self.kit._draft(self.kit.params, self.cache, self.prev, k)
        self._set_pending(dres)
        n = int(dres.lengths[0])
        toks = np.asarray(dres.tokens[0, :n])  # materialize: honest timing
        self.draft_seconds += time.perf_counter() - t
        self.drafted += n
        return toks

    def _set_pending(self, dres: drafting.DraftResult) -> None:
        self._pending = dres
        n = int(dres.lengths[0])
        self.pending_q = np.asarray(dres.q_sel[0, :n])

    def draft_ahead(self) -> Optional[np.ndarray]:
        """Pre-draft the next round while the current one is in flight.

        Returns the ahead proposal (or None if unsupported); it becomes live
        only if on_verdict() confirms the speculation.
        """
        assert self._pending is not None, "draft_ahead needs a round in flight"
        if self._ahead is not None or not self.kit.supports_pipeline:
            return None
        pend = self._pending
        n = int(pend.lengths[0])
        last = pend.tokens[:, n - 1]
        # peek at the draft model's bonus-position prediction: feed d_n against
        # the cache rolled to just-before-d_n (no commit — logits only)
        peek_cache = {**pend.cache, "length": pend.base_length + n}
        bonus_guess = int(self.kit._peek(self.kit.params, peek_cache, last)[0])
        # state as if all n drafts were accepted; identical transform to the
        # full-acceptance verdict path, so a hit replays the exact fresh state
        cache_acc = drafting.resume_after_verify(self.kit.model, pend, jnp.asarray([n], jnp.int32))
        self.key, k = jax.random.split(self.key)
        prev_guess = jnp.asarray([bonus_guess], jnp.int32)
        dres = self.kit._draft(self.kit.params, cache_acc, prev_guess, k)
        self._ahead = (bonus_guess, cache_acc, dres)
        m = int(dres.lengths[0])
        return np.asarray(dres.tokens[0, :m])

    def on_verdict(self, verdict: Verdict) -> Optional[np.ndarray]:
        """Roll the draft cache back to the verified prefix and resync.

        Returns the next round's proposal when pipelined draft-ahead was
        confirmed (submit it immediately — the device is already drafting
        ahead of the server), else None (call draft() as usual).
        """
        assert self._pending is not None
        pend = self._pending
        n = int(pend.lengths[0])
        self.committed.extend(int(t) for t in verdict.tokens)
        if self._ahead is not None:
            bonus_guess, cache_acc, ahead = self._ahead
            self._ahead = None
            if verdict.n_accepted == n and verdict.next_prev == bonus_guess:
                self.pipeline_hits += 1
                self.cache = cache_acc
                self.prev = jnp.asarray([bonus_guess], jnp.int32)
                self._set_pending(ahead)
                m = int(ahead.lengths[0])
                return np.asarray(ahead.tokens[0, :m])
            self.pipeline_misses += 1
        self.cache = drafting.resume_after_verify(
            self.kit.model, pend, jnp.asarray([verdict.n_accepted], jnp.int32)
        )
        self.prev = jnp.asarray([verdict.next_prev], jnp.int32)
        self._pending = None
        return None

    def fallback_release(self) -> np.ndarray:
        """§III-A timeout fallback: release the in-flight drafts locally and
        continue as if they were committed.  The caller must resync the
        server (engine.force_extend / transport Fallback frame) with the
        returned tokens before the next verification round."""
        assert self._pending is not None
        pend = self._pending
        n = int(pend.lengths[0])
        toks = np.asarray(pend.tokens[0, :n])
        # accept n-1 drafts cache-side, then the nth rides as prev_token —
        # preserving the "last committed token is never in the KV" invariant
        self.cache = drafting.resume_after_verify(
            self.kit.model, pend, jnp.asarray([n - 1], jnp.int32)
        )
        self.prev = jnp.asarray([int(toks[-1])], jnp.int32)
        self.committed.extend(int(t) for t in toks)
        self.fallback_tokens += n
        self._pending = None
        self._ahead = None
        return toks

    @property
    def awaiting(self) -> bool:
        return self._pending is not None
