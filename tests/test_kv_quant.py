"""int8 KV cache (beyond-paper): fidelity + end-to-end serve-path checks."""
import dataclasses

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.api import (
    ClusterSpec,
    FaultSpec,
    ModelSpec,
    SchedulerSpec,
    ServeSpec,
    SpecError,
    System,
    build_models,
)
from repro.configs.base import get_config
from repro.core.server_engine import ServerEngine
from repro.models.kvcache import PagedKVCache
from repro.models.model_zoo import build_model
from repro.transport import codec

V = 128


def _model():
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    m = build_model(cfg)
    return m, m.init_params(jax.random.key(0))


def test_int8_kv_close_to_bf16():
    m, p = _model()
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, V)
    c16 = m.make_cache(2, 64, attn_chunk=16)
    c8 = m.make_cache(2, 64, attn_chunk=16, kv_dtype=jnp.int8)
    lg16, c16 = m.prefill(p, toks, c16, attn_chunk=16)
    lg8, c8 = m.prefill(p, toks, c8, attn_chunk=16)
    assert c8["k"].dtype == jnp.int8
    rel = float(jnp.abs(lg16 - lg8).max() / (jnp.abs(lg16).max() + 1e-9))
    assert rel < 0.1, rel
    # verify path still works and commits
    h8, ck, _ = m.decode_forward(p, c8, toks[:, :3], attn_chunk=16)
    assert bool(jnp.isfinite(h8).all())
    committed = m.commit(ck, jnp.array([2, 3], jnp.int32))
    assert committed["k"].dtype == jnp.int8
    assert committed["length"].tolist() == [18, 19]


def test_int8_kv_footprint_halves():
    m, _ = _model()
    c16 = m.make_cache(2, 64, attn_chunk=16, spec_only=True)
    c8 = m.make_cache(2, 64, attn_chunk=16, spec_only=True, kv_dtype=jnp.int8)
    b16 = c16["k"].size * c16["k"].dtype.itemsize
    b8 = c8["k"].size * c8["k"].dtype.itemsize
    assert b8 * 2 == b16


# ---------------------------------------------------------------------------
# quantized paged pool: spec plumbing, pool fidelity, migration, recovery
# ---------------------------------------------------------------------------


def _spec(**kw) -> ServeSpec:
    base = dict(
        backend="engine",
        model=ModelSpec(vocab_size=64, target_layers=2, draft_layers=1,
                        draft_noise=0.03),
        scheduler=SchedulerSpec(slots=2, stagger_ticks=1),
        devices=2,
        prompt_len=6,
        max_new=6,
        k_max=3,
        c_th=0.3,
    )
    base.update(kw)
    return ServeSpec(**base)


@pytest.fixture(scope="module")
def models():
    return build_models(_spec().model)


def test_spec_kv_dtype_validated_and_round_trips():
    with pytest.raises(SpecError, match="kv_dtype"):
        _spec(kv_dtype="fp8")
    spec = _spec(kv_dtype="int8")
    assert ServeSpec.from_json(spec.to_json()).kv_dtype == "int8"
    # with_backend placement specs carry the dtype to remote workers
    assert spec.with_backend("engine").kv_dtype == "int8"


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-1.2b"])
def test_int8_rejected_loudly_for_ssm_and_hybrid(arch):
    spec = _spec(
        kv_dtype="int8",
        model=ModelSpec(arch=arch, draft_arch=arch, vocab_size=64,
                        target_layers=2, draft_layers=1),
    )
    with pytest.raises(ValueError, match="gather/scatter"):
        System.build(spec)


def test_pool_resident_int8_close_to_bf16(models):
    """Pool-level fidelity: the slot-indexed forward over an int8 pool must
    track the bf16 pool's hidden states within a small relative error."""
    m, p = models.target, models.target_params
    toks = jax.random.randint(jax.random.key(2), (2, 12), 0, 64)
    hidden = {}
    for name, kw in (("bf16", {}), ("int8", {"kv_dtype": jnp.int8})):
        pool = PagedKVCache(m, 2, 64, attn_chunk=16, **kw)
        for b in range(2):
            row = pool.make_row_cache()
            _, row = m.prefill(p, toks[b:b + 1], row, attn_chunk=16)
            pool.write_slot(b, row)
        slots = jnp.arange(2, dtype=jnp.int32)
        h, _, _ = m.decode_forward(p, pool.cache, toks[:, :4],
                                   attn_chunk=16, slots=slots)
        hidden[name] = h
    rel = float(jnp.abs(hidden["bf16"] - hidden["int8"]).max()
                / (jnp.abs(hidden["bf16"]).max() + 1e-9))
    assert rel < 0.1, rel


def test_int8_acceptance_rate_near_bf16(models):
    """Seeded workload through the engine backend: quantizing the server
    pool must not move the acceptance rate materially."""
    acc = {}
    for dt in ("bf16", "int8"):
        result = System.build(_spec(kv_dtype=dt), models=models).serve()
        acc[dt] = result.engine.acceptance_rate
        assert all(len(s.tokens) == 6 for s in result.sessions)
    assert abs(acc["int8"] - acc["bf16"]) < 0.15, acc


def _int8_engine(models, **kw):
    return ServerEngine(
        models.target, models.target_params, n_slots=2, max_len=64,
        k_max=3, greedy=True, attn_chunk=32, kv_dtype="int8", **kw,
    )


def test_int8_row_rides_codec_bit_exactly(models):
    """ExportStream/ImportStream frames must carry int8 rows + f32 scale
    leaves bit-exactly — migration at kv_dtype=int8 is only safe if the
    quantized words and their dequant scales survive the wire unchanged."""
    a = _int8_engine(models)
    prompt = jax.random.randint(jax.random.key(6), (9,), 0, 64)
    a.admit(7, prompt, 0.0)
    stream, row = a.export_stream(7)
    row = {k: np.asarray(v) for k, v in row.items()}
    assert row["k"].dtype == np.int8 and row["v"].dtype == np.int8
    assert row["k_scale"].dtype == np.float32
    assert row["v_scale"].dtype == np.float32

    state = codec.StreamState(
        device_id=7, slot=stream.slot, prev_token=stream.prev_token,
        committed=tuple(stream.committed), admitted_at=stream.admitted_at,
        rounds=stream.rounds, drafted=stream.drafted,
        accepted=stream.accepted, row=row,
    )
    wire, _ = codec.decode_frame(codec.encode_frame(codec.ImportStream(stream=state)))
    got = wire.stream.row
    assert sorted(got) == sorted(row)
    for k in row:
        assert got[k].dtype == row[k].dtype and got[k].shape == row[k].shape
        np.testing.assert_array_equal(
            got[k].view(np.uint16) if got[k].dtype == ml_dtypes.bfloat16 else got[k],
            row[k].view(np.uint16) if row[k].dtype == ml_dtypes.bfloat16 else row[k],
        )

    # and the decoded row installs into a sibling engine bit-identically
    b = _int8_engine(models, steps=a.steps)
    b.import_stream(stream, got)
    back = b.core.export_row(b.streams[7].slot)
    for k in row:
        np.testing.assert_array_equal(np.asarray(back[k]), row[k])


@pytest.fixture(scope="module")
def int8_ref_outputs(models):
    spec = _spec(kv_dtype="int8").with_backend("reference")
    return System.build(spec, models=models).serve().outputs


@pytest.mark.parametrize(
    "backend,replicas",
    [
        ("engine", 1),
        pytest.param("cluster", 2, marks=pytest.mark.slow),
        pytest.param("transport", 1, marks=pytest.mark.slow),
    ],
)
def test_backend_token_identity_at_int8(models, int8_ref_outputs, backend, replicas):
    spec = _spec(kv_dtype="int8").with_backend(
        backend, cluster=ClusterSpec(replicas=replicas)
    )
    result = System.build(spec, models=models).serve()
    assert result.outputs == int8_ref_outputs, \
        f"{backend} diverged from the int8 reference"


def test_chaos_kill_recovery_int8_token_identical(models):
    """Kill 1 of 2 replicas mid-serve at kv_dtype=int8 with respawn +
    device-replay recovery on: every session must complete with exactly the
    fault-free twin's tokens.  Replay re-prefills the original prompt, so
    the recomputed quantization scales are deterministic — this is the
    determinism contract the scale layout was designed for."""
    spec = _spec(
        backend="cluster",
        kv_dtype="int8",
        devices=4,
        cluster=ClusterSpec(
            replicas=2,
            faults={
                "respawn": True, "recover_streams": True,
                "backoff_base_s": 0.01, "backoff_max_s": 0.05,
            },
        ),
        faults=FaultSpec(events=({"kind": "kill", "replica": 1, "round": 5},)),
    )
    want = System.build(
        dataclasses.replace(spec, faults=FaultSpec()), models=models
    ).serve().outputs

    system = System.build(spec, models=models)
    result = system.serve()
    assert system.engine.evictions == 1 and system.engine.respawns == 1
    assert result.lost_devices == [] and not any(s.shed for s in result.sessions)
    assert result.outputs == want, "int8 recovery diverged from fault-free run"
