"""RemoteReplica: the Router's proxy for a worker process on the far side
of a socket.

The Router (cluster/router.py) drives every replica through one synchronous
surface — admit / submit / step / retire / export / import / stats / warmup.
A :class:`RemoteReplica` implements that surface by proxying each call over
codec v3 control frames on a blocking :class:`ControlChannel` (plain socket
+ FrameDecoder; the Router stays synchronous, and concurrency across
workers comes from the Router stepping its remotes on a thread pool).

Client-side SHADOW state keeps the hot paths local: the replica mirrors
each stream's server-side record (slot, prev token, committed tokens,
lifetime counters) from admit/verdict/retire traffic, so placement
decisions (``n_free``, ``streams``, ``has_inflight``) never pay a round
trip — only actual engine work (admit's prefill, step's verification,
migration's row copy) crosses the wire.

Supervision is reconnect-or-evict: a transport failure on a SIDE-EFFECT-FREE
RPC (stats) is retried once over a fresh connection; a failure on a
side-effectful RPC (admit / submit / step / retire / migration) raises
:class:`ReplicaGone` immediately — the worker may or may not have applied
it, so retrying could double-apply a round — and the Router evicts the
replica.  A worker-side handler error arrives as an ErrorReply and raises
:class:`WorkerError` (the worker is alive; the request was just invalid).
"""

from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import time
import uuid
from typing import Dict, List, Optional

import numpy as np

from repro.core.admission import DeviceStream
from repro.core.engine import EngineStats, Verdict
from repro.transport import codec
from repro.transport.links import parse_addr

DEFAULT_TIMEOUT = 120.0  # control RPCs; crash shows up as EOF, not timeout
WARMUP_TIMEOUT = 900.0  # warmup compiles every verify bucket


class ReplicaGone(ConnectionError):
    """The worker is unreachable (crash, kill, network partition)."""


class WorkerError(ValueError):
    """The worker handled the request and rejected it (engine-level error)."""


class ControlChannel:
    """Blocking request/reply frame channel to one worker (TCP or UDS)."""

    def __init__(self, address: str, *, timeout: float = DEFAULT_TIMEOUT):
        self.address = address
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None
        self._decoder = codec.FrameDecoder()

    def connect(self) -> None:
        parsed = parse_addr(self.address)
        try:
            if parsed[0] == "uds":
                sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                sock.settimeout(self.timeout)
                sock.connect(parsed[1])
            else:
                sock = socket.create_connection(
                    (parsed[1], parsed[2]), timeout=self.timeout
                )
        except OSError as e:
            raise ReplicaGone(f"cannot dial worker at {self.address}: {e}") from e
        self._sock = sock
        self._decoder = codec.FrameDecoder()

    @property
    def connected(self) -> bool:
        return self._sock is not None

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def reconnect(self) -> None:
        self.close()
        self.connect()

    def request(self, msg: codec.Message, *, timeout: Optional[float] = None):
        """Send one frame, block for its reply.  ErrorReply -> WorkerError;
        any transport failure -> ReplicaGone (this channel is closed)."""
        if self._sock is None:
            self.connect()
        sock = self._sock
        try:
            if timeout is not None:
                sock.settimeout(timeout)
            sock.sendall(codec.encode_frame(msg))
            while True:
                raw = self._decoder.next_raw()
                if raw is not None:
                    break
                data = sock.recv(65536)
                if not data:
                    raise ReplicaGone(
                        f"worker at {self.address} closed the control connection"
                    )
                self._decoder.feed(data)
        except ReplicaGone:
            self.close()
            raise
        except (OSError, codec.CodecError) as e:
            self.close()
            raise ReplicaGone(f"worker at {self.address} failed: {e}") from e
        finally:
            if timeout is not None and self._sock is not None:
                self._sock.settimeout(self.timeout)
        reply, _ = codec.decode_frame(raw)
        if isinstance(reply, codec.ErrorReply):
            raise WorkerError(reply.message)
        return reply


def repro_python_env() -> dict:
    """Env for a spawned worker: this interpreter's repro must be importable
    even when the parent runs from a source tree via PYTHONPATH=src."""
    import repro

    env = dict(os.environ)
    pkg_dir = (  # namespace packages have __file__=None; __path__ still points in
        os.path.dirname(repro.__file__) if getattr(repro, "__file__", None)
        else list(repro.__path__)[0]
    )
    src_root = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src_root] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    return env


def spawn_worker(
    address: Optional[str] = None,
    *,
    spec_path: str = "",
    startup_timeout: float = 120.0,
):
    """Start a ``repro worker`` subprocess and wait until it accepts a dial.

    Returns ``(proc, address)``.  Without an explicit address the worker
    listens on a fresh UDS socket under a private temp dir (no port to
    guess, no parsing of the worker's stdout)."""
    if address is None:
        sock_dir = tempfile.mkdtemp(prefix="repro-worker-")
        address = f"uds:{os.path.join(sock_dir, uuid.uuid4().hex[:8] + '.sock')}"
    cmd = [sys.executable, "-m", "repro.cli", "worker", "--listen", address]
    if spec_path:
        cmd += ["--spec", spec_path]
    proc = subprocess.Popen(
        cmd, env=repro_python_env(), stdout=subprocess.DEVNULL
    )
    deadline = time.time() + startup_timeout
    probe = ControlChannel(address, timeout=5.0)
    while True:
        if proc.poll() is not None:
            raise RuntimeError(
                f"worker exited with code {proc.returncode} during startup "
                f"(cmd: {' '.join(cmd)})"
            )
        try:
            probe.connect()
            probe.close()
            return proc, address
        except ReplicaGone:
            if time.time() > deadline:
                proc.terminate()
                raise RuntimeError(
                    f"worker at {address} did not come up within {startup_timeout}s"
                ) from None
            time.sleep(0.05)


class RemoteReplica:
    """One worker process behind the replica driver surface.

    Mirrors the parts of :class:`~repro.core.server_engine.ServerEngine`
    the Router and the serving loops touch; see the module docstring for
    the shadow-state and supervision rules.
    """

    flavor = "remote"

    def __init__(
        self,
        channel: ControlChannel,
        *,
        address: str = "",
        proc: Optional[subprocess.Popen] = None,
    ):
        self.channel = channel
        self.address = address or channel.address
        self.proc = proc  # set when this replica spawned its worker
        self.dead = False
        self._placed = False
        self._n_slots = 0
        self.k_max = 0
        self.max_len = 0
        self.greedy = True
        self.paged_attention = True
        self._streams: Dict[int, DeviceStream] = {}
        self._pending: Dict[int, int] = {}  # device -> tokens in flight
        self._queue_depth = 0
        self._hint: Optional[float] = None
        self.last_telemetry: Optional[dict] = None  # worker payload from stats()

    @classmethod
    def dial(cls, address: str, *, timeout: float = DEFAULT_TIMEOUT) -> "RemoteReplica":
        channel = ControlChannel(address, timeout=timeout)
        channel.connect()
        return cls(channel, address=address)

    # -- placement -----------------------------------------------------------

    def place(self, spec) -> None:
        """Ship the ServeSpec subtree; the worker builds its engine from it."""
        ack = self.channel.request(
            codec.PlaceReplica(spec.to_json_str()), timeout=WARMUP_TIMEOUT
        )
        if not isinstance(ack, codec.PlaceAck):
            raise WorkerError(f"expected PlaceAck, got {type(ack).__name__}")
        if not ack.ok:
            raise WorkerError(f"worker at {self.address} refused placement: {ack.error}")
        self._placed = True
        self._n_slots = ack.n_slots
        self.k_max = ack.k_max
        self.max_len = ack.max_len
        self.greedy = ack.greedy
        self.paged_attention = ack.paged_attention

    @property
    def fingerprint(self) -> tuple:
        return (self.k_max, self.max_len, self.greedy, self.paged_attention)

    # -- shadowed introspection (no round trips) -----------------------------

    @property
    def streams(self) -> Dict[int, DeviceStream]:
        return self._streams

    @property
    def n_free(self) -> int:
        return self._n_slots - len(self._streams)

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    @property
    def steps(self):
        """Compiled executables cannot cross processes; never shareable."""
        return None

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._pending

    def next_event_hint(self, now: float) -> Optional[float]:
        return self._hint

    # -- driver surface (proxied) --------------------------------------------

    def admit(self, device_id: int, prompt, now: float = 0.0) -> Optional[DeviceStream]:
        reply = self.channel.request(
            codec.AdmitRequest(device_id, np.asarray(prompt, np.int32), now)
        )
        if not reply.ok:
            return None
        stream = DeviceStream(
            device_id=device_id,
            slot=reply.slot,
            prev_token=int(reply.prev_token),
            admitted_at=now,
        )
        self._streams[device_id] = stream
        return stream

    def submit(self, device_id: int, draft_tokens, now: float, draft_q=None) -> None:
        toks = np.asarray(draft_tokens, np.int32).reshape(-1)
        self.channel.request(
            codec.SubmitRequest(
                device_id, toks, now,
                draft_q=None if draft_q is None else np.asarray(draft_q, np.float32),
                qmode="none" if draft_q is None else "f32",
            )
        )
        self._pending[device_id] = int(toks.shape[0])

    def step(self, now: float) -> Optional[List[Verdict]]:
        if not self._pending:
            return None  # nothing queued on this worker: skip the round trip
        reply = self.channel.request(codec.StepRequest(now))
        self._queue_depth = reply.queue_depth
        self._hint = reply.hint
        verdicts: List[Verdict] = []
        for rec in reply.verdicts:
            stream = self._streams.get(rec.device_id)
            drafted = self._pending.pop(rec.device_id, 0)
            if stream is not None:
                stream.committed.extend(int(t) for t in rec.tokens)
                stream.prev_token = int(rec.next_prev)
                stream.rounds += 1
                stream.drafted += drafted
                stream.accepted += int(rec.n_accepted)
            verdicts.append(
                Verdict(
                    device_id=rec.device_id,
                    n_accepted=int(rec.n_accepted),
                    tokens=np.asarray(rec.tokens, np.int32),
                    next_prev=int(rec.next_prev),
                    accept_rate=float(rec.accept_rate),
                    queue_depth=int(rec.queue_depth),
                    queue_s=float(rec.queue_s),
                    verify_s=float(rec.verify_s),
                )
            )
        return verdicts or None

    def retire(self, device_id: int) -> DeviceStream:
        reply = self.channel.request(codec.RetireRequest(device_id))
        self._pending.pop(device_id, None)
        self._streams.pop(device_id, None)
        from repro.transport.worker import state_to_stream

        return state_to_stream(reply.stream)

    def cancel_request(self, device_id: int) -> bool:
        reply = self.channel.request(codec.CancelRequest(device_id))
        if reply.ok:
            self._pending.pop(device_id, None)
        return reply.ok

    def force_extend(self, device_id: int, tokens) -> int:
        reply = self.channel.request(
            codec.ForceExtendRequest(device_id, np.asarray(tokens, np.int32))
        )
        stream = self._streams.get(device_id)
        if stream is not None:
            stream.committed.extend(int(t) for t in np.asarray(tokens).reshape(-1))
            stream.prev_token = int(reply.next_prev)
        return int(reply.next_prev)

    # -- migration (streams cross the wire bit-exactly) ----------------------

    def export_stream(self, device_id: int):
        reply = self.channel.request(codec.ExportStream(device_id))
        self._pending.pop(device_id, None)
        self._streams.pop(device_id, None)
        from repro.transport.worker import state_to_stream

        return state_to_stream(reply.stream), dict(reply.stream.row)

    def import_stream(self, stream: DeviceStream, row_cache) -> DeviceStream:
        from repro.transport.worker import stream_to_state

        reply = self.channel.request(
            codec.ImportStream(stream_to_state(stream, row_cache))
        )
        stream.slot = reply.slot
        self._streams[stream.device_id] = stream
        return stream

    # -- stats / warmup / lifecycle ------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        req = codec.StatsRequest(
            now=0.0 if now is None else float(now), has_now=now is not None
        )
        try:
            reply = self.channel.request(req)
        except ReplicaGone:
            # side-effect-free: one reconnect-and-retry before giving up
            self.channel.reconnect()
            reply = self.channel.request(req)
        if reply.telemetry_json:
            self.last_telemetry = json.loads(reply.telemetry_json)
        return EngineStats(**json.loads(reply.stats_json))

    def warmup(self, buckets=None) -> Dict[int, float]:
        reply = self.channel.request(codec.WarmupRequest(), timeout=WARMUP_TIMEOUT)
        return {int(k): v for k, v in json.loads(reply.compile_json).items()}

    def drain(self) -> None:
        """Best-effort: ask the worker to exit; reap a spawned process."""
        try:
            if self.channel.connected or not self.dead:
                self.channel.request(codec.Drain(), timeout=10.0)
        except (ReplicaGone, WorkerError):
            pass
        self.close()
        if self.proc is not None:
            try:
                self.proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                self.proc.terminate()
                try:
                    self.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    self.proc.kill()
            self.proc = None

    def close(self) -> None:
        self.channel.close()
