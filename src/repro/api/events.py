"""Typed streaming events and unified result records for the session API.

A :class:`~repro.api.system.Session`'s ``generate()`` yields, per round:
``TokenEvent`` for each token committed to the stream (in order, capped at
the session's budget), then one ``RoundEvent`` summarizing the round, and
finally one ``DoneEvent``.  The same records come out of every backend —
reference, engine, cluster, transport — so a consumer written against the
event stream is backend-agnostic.

``SessionResult`` / ``ServeResult`` are the uniform end-of-run records; both
expose ``to_json()`` (as do :class:`~repro.core.engine.EngineStats` and
:class:`~repro.transport.client.ClientStats`), which is the ONE dict shape
the benchmarks emit as BENCH artifacts — no more ad-hoc dict building per
driver.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

from repro.core.engine import EngineStats
from repro.transport.client import ClientStats


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One token committed to a stream (``index`` = position in the stream)."""

    device_id: int
    token: int
    index: int


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One resolved drafting round (verification verdict or §III-A fallback)."""

    device_id: int
    round: int  # 0-based round index within the session
    n_drafted: int
    n_accepted: int  # verified acceptances only — 0 on fallback rounds
    tokens: Tuple[int, ...]  # committed this round: accepted drafts + bonus/
    # correction, or the locally-released (unverified) run on a fallback round
    fallback: bool = False


@dataclasses.dataclass(frozen=True)
class DoneEvent:
    """The session reached its token budget (or the stream closed)."""

    device_id: int
    n_tokens: int


Event = Union[TokenEvent, RoundEvent, DoneEvent]


@dataclasses.dataclass
class SessionResult:
    """One stream's unified outcome, identical in shape across backends."""

    device_id: int
    tokens: List[int]
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    fallback_rounds: int = 0
    fallback_tokens: int = 0
    wall_seconds: float = 0.0
    # the stream went down with an evicted replica and could not be
    # re-placed (capacity/deadline): ``tokens`` holds what was committed
    # before the loss, and the session ended with an explicit rejection
    # verdict instead of a hang
    shed: bool = False
    client: Optional[ClientStats] = None  # transport backend only
    # per-round TraceEvents (repro.telemetry), populated when the spec was
    # built with telemetry=True; empty otherwise
    trace: List = dataclasses.field(default_factory=list)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    def to_json(self) -> dict:
        d = {
            "device_id": self.device_id,
            "n_tokens": len(self.tokens),
            "tokens": [int(t) for t in self.tokens],
            "rounds": self.rounds,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "acceptance_rate": self.acceptance_rate,
            "fallback_rounds": self.fallback_rounds,
            "fallback_tokens": self.fallback_tokens,
            "wall_seconds": self.wall_seconds,
        }
        if self.shed:
            d["shed"] = True
        if self.client is not None:
            d["client"] = self.client.to_json()
        if self.trace:
            d["trace"] = [ev.to_json() for ev in self.trace]
        return d


@dataclasses.dataclass
class ServeResult:
    """A full fleet run: per-session results + merged server/client stats."""

    backend: str
    sessions: List[SessionResult]
    engine: EngineStats
    clients: Optional[ClientStats] = None  # ClientStats.merge over the fleet
    wall_seconds: float = 0.0
    # devices whose streams were shed with an evicted replica (Router
    # supervision); empty on fault-free runs and when recovery re-placed
    # every stream
    lost_devices: List[int] = dataclasses.field(default_factory=list)
    # metrics snapshot + flight-recorder rows (engine.telemetry_payload());
    # None unless telemetry was enabled for the run
    telemetry: Optional[dict] = None

    @property
    def outputs(self) -> Dict[int, List[int]]:
        """device_id -> committed tokens (the equivalence-check surface)."""
        return {s.device_id: s.tokens for s in self.sessions}

    @property
    def total_tokens(self) -> int:
        return sum(len(s.tokens) for s in self.sessions)

    @property
    def trace(self) -> List:
        """Fleet-wide per-round trace: every session's TraceEvents, in
        session order (sort by ``.t`` for a global timeline)."""
        return [ev for s in self.sessions for ev in s.trace]

    def to_json(self) -> dict:
        d = {
            "backend": self.backend,
            "wall_seconds": self.wall_seconds,
            "total_tokens": self.total_tokens,
            "engine": self.engine.to_json(),
            "sessions": [s.to_json() for s in self.sessions],
        }
        if self.lost_devices:
            d["lost_devices"] = [int(x) for x in self.lost_devices]
        if self.clients is not None:
            d["clients"] = self.clients.to_json()
        if self.telemetry is not None:
            d["telemetry"] = self.telemetry
        return d
