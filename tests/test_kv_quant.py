"""int8 KV cache (beyond-paper): fidelity + end-to-end serve-path checks."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model_zoo import build_model

V = 128


def _model():
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    m = build_model(cfg)
    return m, m.init_params(jax.random.key(0))


def test_int8_kv_close_to_bf16():
    m, p = _model()
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, V)
    c16 = m.make_cache(2, 64, attn_chunk=16)
    c8 = m.make_cache(2, 64, attn_chunk=16, kv_dtype=jnp.int8)
    lg16, c16 = m.prefill(p, toks, c16, attn_chunk=16)
    lg8, c8 = m.prefill(p, toks, c8, attn_chunk=16)
    assert c8["k"].dtype == jnp.int8
    rel = float(jnp.abs(lg16 - lg8).max() / (jnp.abs(lg16).max() + 1e-9))
    assert rel < 0.1, rel
    # verify path still works and commits
    h8, ck, _ = m.decode_forward(p, c8, toks[:, :3], attn_chunk=16)
    assert bool(jnp.isfinite(h8).all())
    committed = m.commit(ck, jnp.array([2, 3], jnp.int32))
    assert committed["k"].dtype == jnp.int8
    assert committed["length"].tolist() == [18, 19]


def test_int8_kv_footprint_halves():
    m, _ = _model()
    c16 = m.make_cache(2, 64, attn_chunk=16, spec_only=True)
    c8 = m.make_cache(2, 64, attn_chunk=16, spec_only=True, kv_dtype=jnp.int8)
    b16 = c16["k"].size * c16["k"].dtype.itemsize
    b8 = c8["k"].size * c8["k"].dtype.itemsize
    assert b8 * 2 == b16
