"""Core pure-JAX layers: norms, RoPE, flash-style attention, MLP, MoE.

Everything is a pure function over parameter pytrees (no flax).  Attention is
implemented as an online-softmax scan over KV chunks ("xla_flash") so that
32k-524k contexts never materialise (S_q, S_kv) score tensors; the Pallas
kernel in repro.kernels.verify_attn is the TPU-target version of the same
computation and is validated against the same oracle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

Params = Dict[str, Any]

NEG_INF = -1e30  # large-negative instead of -inf: keeps fully-masked rows NaN-free

# Attention lowering mode for the dry-run perf methodology (§Perf):
#   "xla"  — the portable chunked online-softmax scan (scores round-trip HBM
#            between the two GEMMs: what XLA does without a fused kernel)
#   "stub" — kernel-traffic stand-in: reads K and V exactly once and writes
#            the O-shaped output, nothing else.  This measures the step's
#            NON-attention traffic + the Pallas kernel's intrinsic traffic
#            (kernels/verify_attn.py streams KV once with scores resident in
#            VMEM), so `dryrun --attn-impl stub` models the fused-kernel
#            deployment.  GEMM FLOPs of the kernel are added analytically in
#            EXPERIMENTS.md §Perf (the stub does no score math).
ATTN_IMPL = "xla"

# int8 KV cache (beyond-paper: halves the cache stream and fits the two
# cells whose bf16 caches exceed v5e HBM — qwen1.5-32b decode_32k and the
# paper's llama-70b target).  Symmetric scale per (row, kv-head), FIXED at
# prompt prefill: the first append into an empty row (cache_len == 0)
# computes scale = max(|K|)/127 over the prompt and stores it in the
# cache's ``k_scale``/``v_scale`` leaves; every later append reuses the
# stored value.  Fixing the scale at prefill is what keeps quantization
# deterministic under replay — device-replay recovery re-prefills the same
# prompt (same scale) and then force-extends, so a recovered stream's int8
# rows are bit-identical to its fault-free twin's no matter how the appends
# were grouped.  ``KV_SCALE`` remains the static fallback for callers that
# pass no scale.  Opt-in: make_cache(kv_dtype=jnp.int8), dryrun --kv-bits 8.
KV_SCALE = 0.05
KV_SCALE_EPS = 1e-6  # floor for amax/127 so all-zero rows stay invertible


def _bc(scale: jax.Array) -> jax.Array:
    """(B, Hkv) scale broadcast against a (B, S, Hkv, D) K/V tile."""
    return scale[:, None, :, None]


def kv_quant(x: jax.Array, dtype, scale: Optional[jax.Array] = None) -> jax.Array:
    if dtype != jnp.int8:
        return x.astype(dtype)
    s = KV_SCALE if scale is None else _bc(scale)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)


def kv_dequant(x: jax.Array, scale: Optional[jax.Array] = None) -> jax.Array:
    if x.dtype != jnp.int8:
        return x
    s = KV_SCALE if scale is None else _bc(scale)
    return (x.astype(jnp.float32) * s).astype(jnp.bfloat16)


def kv_fresh_scale(x: jax.Array) -> jax.Array:
    """Per-(row, kv-head) symmetric scale for a fresh (B, S, Hkv, D) tile."""
    amax = jnp.abs(x.astype(jnp.float32)).max(axis=(1, 3))
    return jnp.maximum(amax / 127.0, KV_SCALE_EPS)


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """How a layer should shard itself when running under a mesh.

    ``mesh=None`` means single-device math (smoke tests, examples).
    ``batch_axes`` are the mesh axes carrying the batch dimension
    (('pod','data') multi-pod, ('data',) single pod), ``model_axis`` carries
    tensor/expert parallelism.  ``seq_shard_kv`` switches attention caches
    to sequence sharding over the model axis (flash-decoding combine; see
    distributed/collectives.py) — the fit strategy for small-kv GQA archs.
    """

    mesh: Any = None
    batch_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    fsdp: bool = False
    seq_shard_kv: bool = False

    @property
    def tp(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_batch_shards(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a] if self.mesh is not None else 1
        return n

    def bspec(self, batch_size: int):
        """Batch-dim axes, or None when the batch can't shard evenly."""
        if self.batch_axes and batch_size % self.n_batch_shards == 0:
            return self.batch_axes
        return None


NO_MESH = MeshContext()


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x: jax.Array, p: Params, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def init_norm(d: int, kind: str) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# Embedding lookup (vocab-TP aware)
# ---------------------------------------------------------------------------


def embed_lookup(embed: jax.Array, tokens: jax.Array, ctx: "MeshContext") -> jax.Array:
    """Token embedding gather that works WITH a vocab-sharded table.

    A plain ``embed[tokens]`` on a model-axis-sharded table makes GSPMD
    replicate the whole table per step ("involuntary full rematerialization");
    instead each model shard gathers its own vocab range and a psum combines
    — the standard TP embedding trick, here via shard_map.
    """
    V, d = embed.shape
    tp = ctx.tp
    if ctx.mesh is None or tp == 1 or V % tp != 0:
        return embed[tokens]
    ax = ctx.model_axis
    bspec = ctx.bspec(tokens.shape[0])
    v_loc = V // tp

    def f(emb, toks):
        r = jax.lax.axis_index(ax)
        rel = toks - r * v_loc
        ok = (rel >= 0) & (rel < v_loc)
        rows = emb[jnp.clip(rel, 0, v_loc - 1)]
        rows = jnp.where(ok[..., None], rows, jnp.zeros((), rows.dtype))
        return jax.lax.psum(rows, ax)

    return shard_map(
        f,
        mesh=ctx.mesh,
        in_specs=(P(ax, None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(embed, tokens)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated at ``positions`` (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style attention (online softmax over KV chunks, pure XLA)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D) — or (L, B, Skv, Hkv, D) with ``layer``
    v: jax.Array,
    *,
    q_pos: Optional[jax.Array] = None,  # (B, Sq) absolute positions; None -> arange
    kv_valid: Optional[jax.Array] = None,  # (B,) number of valid kv entries; None -> Skv
    causal: bool = True,
    chunk: int = 1024,
    scale: Optional[float] = None,
    layer: Optional[jax.Array] = None,  # stream chunks straight from a
    # stacked (L, B, S, H, D) cache buffer — avoids materialising a per-layer
    # slice copy of the cache inside the layer loop (§Perf memory fix)
    slots: Optional[jax.Array] = None,  # (B,) pool-row indices: k/v are a
    # PagedKVCache pool ((n_pool, S, H, D), or (L, n_pool, S, H, D) with
    # ``layer``) and batch entry b attends against pool row slots[b].  Each
    # kv chunk is sliced from the pool FIRST and row-indexed second, so only
    # chunk-sized slot-indexed tiles ever materialise — the XLA mirror of
    # kernels/verify_attn.verify_attention_paged's scalar-prefetch indexing
    # (no step-level gather of the multi-GB cache).
    pos_offset: Optional[jax.Array] = None,  # global position of k[:, 0]
    # (sequence-parallel shards pass their shard offset)
    return_stats: bool = False,  # return (acc, m, l) un-normalised for
    # cross-shard softmax combination (flash-decoding style)
    remat: bool = False,  # checkpoint the chunk body (training: do not save
    # per-chunk score tensors for backward)
    k_scale: Optional[jax.Array] = None,  # (B, Hkv) per-row dequant scales
    v_scale: Optional[jax.Array] = None,  # for int8 k/v (already row-selected
    # by the caller: constant over the sequence, so every chunk shares them)
):
    """Chunked online-softmax attention.

    KV entry ``j`` is visible to query at absolute position ``p`` iff
    ``j < kv_valid`` and (not causal or ``j <= p``).  Cache semantics: buffer
    index == absolute position, so speculative rollback is just a smaller
    ``kv_valid`` next round.
    """
    B, Sq, Hq, D = q.shape
    stacked = layer is not None
    Skv, Hkv = (k.shape[2], k.shape[3]) if stacked else (k.shape[1], k.shape[2])
    Bk = k.shape[1] if stacked else k.shape[0]  # pool rows when slots given
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    if ATTN_IMPL == "stub":  # fused-kernel traffic model (see module note)
        if stacked:
            k = jax.lax.dynamic_index_in_dim(k, layer, 0, keepdims=False)
            v = jax.lax.dynamic_index_in_dim(v, layer, 0, keepdims=False)
        if slots is not None:
            k = jnp.take(k, slots, axis=0)
            v = jnp.take(v, slots, axis=0)
        seq_ax = 1
        kv = (k.astype(jnp.float32).mean(axis=seq_ax)
              + v.astype(jnp.float32).mean(axis=seq_ax))  # one pass over K+V
        kv = jnp.repeat(kv, G, axis=1)  # (B, Hq, D)
        out = (q.astype(jnp.float32) * kv[:, None] * scale).astype(q.dtype)
        if return_stats:
            m = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
            l = jnp.ones((B, Sq, Hkv, G), jnp.float32)
            return out.reshape(B, Sq, Hkv, G, D).astype(jnp.float32), m, l
        return out
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    if kv_valid is None:
        kv_valid = jnp.full((B,), Skv, jnp.int32)

    chunk = min(chunk, Skv)
    n_chunks = math.ceil(Skv / chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        # rare path: callers size KV buffers to a chunk multiple (make_cache
        # rounds up), so only short fresh K/V (e.g. whisper's 1500-frame
        # encoder) ever pays this copy.
        padw = ((0, 0),) * (k.ndim - 3) + ((0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)

    if stacked:
        def chunk_at(a, idx):
            sl = jax.lax.dynamic_slice(
                a, (layer, 0, idx * chunk, 0, 0), (1, Bk, chunk, Hkv, D)
            )[0]
            # pool layout: slice the chunk first, row-index second — only a
            # (B, chunk, H, D) slot-indexed tile materialises, never a dense
            # gathered copy of the cache rows
            return jnp.take(sl, slots, axis=0) if slots is not None else sl
    else:
        def chunk_at(a, idx):
            sl = jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, axis=1)
            return jnp.take(sl, slots, axis=0) if slots is not None else sl

    qg = q.reshape(B, Sq, Hkv, G, D)

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    def body(carry, idx):
        # stream chunks with dynamic_slice (no transposed copy of the cache:
        # a reshape+moveaxis here doubles the HBM traffic — §Perf iter 0)
        m, l, acc = carry
        kb = kv_dequant(chunk_at(k, idx), k_scale)
        vb = kv_dequant(chunk_at(v, idx), v_scale)
        # scores: (B, Sq, Hkv, G, chunk)
        s = jnp.einsum(
            "bshgd,bchd->bshgc", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        j = idx * chunk + jnp.arange(chunk, dtype=jnp.int32)  # (chunk,)
        if pos_offset is not None:
            j = j + pos_offset
        mask = j[None, None, :] < kv_valid[:, None, None]  # (B, 1, chunk)
        if causal:
            mask = mask & (j[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshgc,bchd->bshgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), jnp.arange(n_chunks, dtype=jnp.int32)
    )
    if return_stats:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    out_std = std / math.sqrt(2 * max(cfg.num_layers, 1))
    p = {
        "wq": (jax.random.normal(k1, (d, hq * hd)) * std).astype(jnp.bfloat16),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * std).astype(jnp.bfloat16),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * std).astype(jnp.bfloat16),
        "wo": (jax.random.normal(k4, (hq * hd, d)) * out_std).astype(jnp.bfloat16),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.bfloat16)
    return p


def attention_block(
    x: jax.Array,  # (B, S, d)
    p: Params,
    cfg,
    *,
    positions: jax.Array,  # (B, S)
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B, Smax, Hkv, D) x2
    cache_len: Optional[jax.Array] = None,  # (B,)
    cache_layer: Optional[jax.Array] = None,  # kv_cache is the full (L, ...) stack
    uniform_start: Optional[jax.Array] = None,  # scalar: all rows share the
    # same insert position (static padded batches, the paper's planner) ->
    # the cache append is ONE dynamic_update_slice instead of a scatter,
    # which XLA updates in place (scatter is charged/copied full-buffer)
    causal: bool = True,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    cross_len: Optional[jax.Array] = None,
    cross_layer: Optional[jax.Array] = None,
    chunk: int = 1024,
    ctx: "MeshContext" = NO_MESH,
    flash_remat: bool = False,
    slots: Optional[jax.Array] = None,  # kv_cache is a slot pool; batch row
    # b owns pool row slots[b] (PagedKVCache continuous batching)
    kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # int8 cache
    # dequant scales, same addressing as the cache: (B, Hkv) plain,
    # (L, B, Hkv) with ``cache_layer``, (L, n_pool, Hkv) with ``slots`` too
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, ...]]]:
    """QKV -> (optional cache append) -> flash attention -> output proj.

    With a kv_cache, new K/V rows are scattered into the buffer at
    ``cache_len + arange(S)`` per row, and attention runs over the whole
    buffer with ``kv_valid = cache_len + S``; returns the updated cache.
    With ``cache_layer``, the cache is the stacked (L, B, S, H, D) buffer:
    only the S new rows are written (tiny scatter) and attention streams
    chunks directly from the stack — the layer loop never copies the cache.
    With ``slots``, the cache batch axis is a PagedKVCache row pool: the
    fresh K/V rows are scattered straight into pool rows ``slots`` and
    attention streams slot-indexed chunks from the pool (flash_attention
    ``slots=``) — the pool is only ever touched at O(B*S) fresh rows.
    Cross-attention ignores caches for K/V and uses ``cross_kv``.
    """
    B, S, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, hq, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = flash_attention(
            q, k, v, q_pos=positions, kv_valid=cross_len, causal=False,
            chunk=chunk, layer=cross_layer, slots=slots,
        )
        return (out.reshape(B, S, hq * hd) @ p["wo"], None)

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)

    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if slots is not None and ctx.seq_shard_kv:
        raise NotImplementedError("slot-pool caches are not sequence-sharded")
    if slots is not None and uniform_start is not None:
        raise ValueError("slot-pool rows have per-row lengths; uniform_start does not apply")
    if kv_cache is not None and ctx.seq_shard_kv:
        # sequence-parallel cache: append + flash-decoding combine in one
        # shard_map (distributed/collectives.py)
        from repro.distributed.collectives import sp_append_attend

        start = uniform_start if uniform_start is not None else cache_len[0]
        out, ck, cv = sp_append_attend(
            q, kv_cache[0], kv_cache[1], k, v, cache_len, start, ctx,
            causal=causal, chunk=chunk,
        )
        return out.reshape(B, S, hq * hd) @ p["wo"], (ck, cv)
    if kv_cache is not None:
        ck, cv = kv_cache
        ksc = vsc = row_ks = row_vs = None
        if kv_scales is not None and ck.dtype == jnp.int8:
            ksc, vsc = kv_scales

            def _rows(sc):
                # select this layer's / these slots' (B, Hkv) scale rows,
                # mirroring the cache addressing
                if cache_layer is not None:
                    sc = jax.lax.dynamic_index_in_dim(sc, cache_layer, 0, keepdims=False)
                if slots is not None:
                    sc = jnp.take(sc, slots, axis=0)
                return sc

            # determinism contract: scale is FIXED at prefill.  The first
            # append into an empty row (cache_len == 0) derives it from the
            # fresh K/V amax; every later append reuses the stored value, so
            # replayed appends quantize bit-identically however they are
            # grouped (device-replay recovery re-prefills the same prompt).
            row_ks = jnp.where(cache_len[:, None] == 0, kv_fresh_scale(k), _rows(ksc))
            row_vs = jnp.where(cache_len[:, None] == 0, kv_fresh_scale(v), _rows(vsc))
        kq, vq = kv_quant(k, ck.dtype, row_ks), kv_quant(v, cv.dtype, row_vs)
        if uniform_start is not None and cache_layer is not None:
            start = (cache_layer, jnp.int32(0), uniform_start.astype(jnp.int32),
                     jnp.int32(0), jnp.int32(0))
            ck = jax.lax.dynamic_update_slice(ck, kq[None], start)
            cv = jax.lax.dynamic_update_slice(cv, vq[None], start)
        elif uniform_start is not None:
            start = (jnp.int32(0), uniform_start.astype(jnp.int32), jnp.int32(0),
                     jnp.int32(0))
            ck = jax.lax.dynamic_update_slice(ck, kq, start)
            cv = jax.lax.dynamic_update_slice(cv, vq, start)
        elif slots is not None:
            # slot pool: batch row b appends its S fresh rows into pool row
            # slots[b].  A static unroll of per-row dynamic_update_slice
            # (one contiguous (1, S, H, D) window each) is the ONLY pool
            # write of the step — a scatter here would be rewritten by XLA's
            # scatter expander into a B*S-trip select loop over the whole
            # pool buffer.  Duplicate scratch-slot rows overwrite in order
            # (deterministic last-writer; scratch is never read as
            # committed).  NB dynamic_update_slice clamps, so callers size
            # max_len >= committed + S (same contract the engine already
            # keeps for the dense path's drop-mode scatter).
            for b in range(B):
                row = slots[b].astype(jnp.int32)
                pos = cache_len[b].astype(jnp.int32)
                if cache_layer is not None:
                    start = (cache_layer, row, pos, jnp.int32(0), jnp.int32(0))
                    ck = jax.lax.dynamic_update_slice(ck, kq[b][None, None], start)
                    cv = jax.lax.dynamic_update_slice(cv, vq[b][None, None], start)
                else:
                    start = (row, pos, jnp.int32(0), jnp.int32(0))
                    ck = jax.lax.dynamic_update_slice(ck, kq[b][None], start)
                    cv = jax.lax.dynamic_update_slice(cv, vq[b][None], start)
        else:
            b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]  # (B,1)
            s_idx = cache_len[:, None] + jnp.arange(S, dtype=jnp.int32)[None]  # (B,S)
            if cache_layer is not None:
                ck = ck.at[cache_layer, b_idx, s_idx].set(kq, mode="drop")
                cv = cv.at[cache_layer, b_idx, s_idx].set(vq, mode="drop")
            else:
                ck = ck.at[b_idx, s_idx].set(kq, mode="drop")
                cv = cv.at[b_idx, s_idx].set(vq, mode="drop")
        if row_ks is not None:
            # persist the selected scales along the same addressing as the
            # K/V append (a no-op rewrite for rows whose scale was already
            # fixed: row_ks == the stored value there)
            if slots is not None:
                for b in range(B):
                    row = slots[b].astype(jnp.int32)
                    if cache_layer is not None:
                        st = (cache_layer, row, jnp.int32(0))
                        ksc = jax.lax.dynamic_update_slice(ksc, row_ks[b][None, None], st)
                        vsc = jax.lax.dynamic_update_slice(vsc, row_vs[b][None, None], st)
                    else:
                        st = (row, jnp.int32(0))
                        ksc = jax.lax.dynamic_update_slice(ksc, row_ks[b][None], st)
                        vsc = jax.lax.dynamic_update_slice(vsc, row_vs[b][None], st)
            elif cache_layer is not None:
                st = (cache_layer, jnp.int32(0), jnp.int32(0))
                ksc = jax.lax.dynamic_update_slice(ksc, row_ks[None], st)
                vsc = jax.lax.dynamic_update_slice(vsc, row_vs[None], st)
            else:
                ksc, vsc = row_ks, row_vs
        new_cache = (ck, cv) if ksc is None else (ck, cv, ksc, vsc)
        kv_valid = cache_len + S
        out = flash_attention(
            q, ck, cv, q_pos=positions, kv_valid=kv_valid, causal=causal,
            chunk=chunk, layer=cache_layer, slots=slots,
            k_scale=row_ks, v_scale=row_vs,
        )
    else:
        out = flash_attention(q, k, v, q_pos=positions, causal=causal, chunk=chunk,
                              remat=flash_remat)

    return out.reshape(B, S, hq * hd) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    out_std = std / math.sqrt(2 * max(cfg.num_layers, 1))
    if cfg.act == "swiglu":
        return {
            "wg": (jax.random.normal(k1, (d, f)) * std).astype(jnp.bfloat16),
            "wu": (jax.random.normal(k2, (d, f)) * std).astype(jnp.bfloat16),
            "wd": (jax.random.normal(k3, (f, d)) * out_std).astype(jnp.bfloat16),
        }
    return {
        "wi": (jax.random.normal(k1, (d, f)) * std).astype(jnp.bfloat16),
        "wd": (jax.random.normal(k3, (f, d)) * out_std).astype(jnp.bfloat16),
    }


def mlp_block(x: jax.Array, p: Params, cfg) -> jax.Array:
    if "wg" in p:
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["wi"], approximate=True) @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-based dispatch; EP via shard_map when a mesh is given)
# ---------------------------------------------------------------------------


def init_moe(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    out_std = std / math.sqrt(2 * max(cfg.num_layers, 1))
    return {
        "router": (jax.random.normal(k1, (d, e)) * std).astype(jnp.float32),
        "wg": (jax.random.normal(k2, (e, d, f)) * std).astype(jnp.bfloat16),
        "wu": (jax.random.normal(k3, (e, d, f)) * std).astype(jnp.bfloat16),
        "wd": (jax.random.normal(k4, (e, f, d)) * out_std).astype(jnp.bfloat16),
    }


def _moe_local(x_flat: jax.Array, p: Params, cfg, e_start: int, e_local: int,
               capacity: int) -> Tuple[jax.Array, jax.Array]:
    """MoE math over a contiguous slice of experts [e_start, e_start+e_local).

    x_flat: (T, d) local tokens. Router runs over ALL experts (replicated,
    cheap); only assignments landing in the local expert slice are dispatched.
    Returns (out (T, d) partial sum over local experts, aux loss scalar).
    """
    T, d = x_flat.shape
    E, K = cfg.num_experts, cfg.experts_per_token

    logits = (x_flat.astype(jnp.float32) @ p["router"])  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, K)  # (T, K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), computed over all experts
    me = jnp.mean(gates, axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # local assignment mask + position within each local expert
    local = (top_idx >= e_start) & (top_idx < e_start + e_local)  # (T, K)
    e_rel = jnp.where(local, top_idx - e_start, 0)  # (T, K)
    flat_onehot = (
        jax.nn.one_hot(e_rel, e_local, dtype=jnp.int32)
        * local[..., None].astype(jnp.int32)
    ).reshape(T * K, e_local)
    pos = (jnp.cumsum(flat_onehot, axis=0) - flat_onehot)  # position per assignment
    pos = (pos * flat_onehot).sum(-1).reshape(T, K)
    keep = local & (pos < capacity)

    # scatter tokens into (e_local, capacity, d)
    disp = jnp.zeros((e_local, capacity, d), x_flat.dtype)
    t_rep = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
    e_flat = jnp.where(keep, e_rel, e_local)  # drop -> OOB row
    disp = disp.at[e_flat.reshape(-1), pos.reshape(-1)].add(
        jnp.where(keep.reshape(-1, 1), x_flat[t_rep.reshape(-1)], 0),
        mode="drop",
    )

    h = jnp.einsum("ecd,edf->ecf", disp, p["wg"], preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", disp, p["wu"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * u).astype(x_flat.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"], preferred_element_type=jnp.float32)

    # gather back: out[t] += gate * y[e, pos]
    vals = y[e_flat.reshape(-1), pos.reshape(-1)]  # (T*K, d)
    vals = vals * (top_vals.reshape(-1, 1) * keep.reshape(-1, 1))
    out = jnp.zeros((T, d), jnp.float32).at[t_rep.reshape(-1)].add(vals)
    return out.astype(x_flat.dtype), aux


def moe_block(x: jax.Array, p: Params, cfg, ctx: MeshContext) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (B, S, d), aux-loss.

    Under a mesh: tokens stay sharded over the batch axes; experts are
    sharded over the model axis when E % tp == 0 (EP), otherwise expert-
    internal d_ff is sharded (f-TP).  Either way the partial outputs are
    psum'd over the model axis — same collective volume as a dense TP MLP.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token

    def cap(tokens: int) -> int:
        c = int(math.ceil(tokens * K / E * cfg.capacity_factor))
        return max(8, -(-c // 8) * 8)  # round up to 8

    if ctx.mesh is None:
        out, aux = _moe_local(x.reshape(B * S, d), p, cfg, 0, E, cap(B * S))
        return out.reshape(B, S, d), aux

    tp = ctx.tp
    ep = E % tp == 0
    ax = ctx.model_axis
    batch_spec = P(ctx.batch_axes) if ctx.batch_axes else P()
    n_batch_shards = 1
    for a in ctx.batch_axes:
        n_batch_shards *= ctx.mesh.shape[a]
    t_local = (B // n_batch_shards) * S
    capacity = cap(t_local)

    if ep:
        w_specs = {
            "router": P(None, None),
            "wg": P(ax, None, None),
            "wu": P(ax, None, None),
            "wd": P(ax, None, None),
        }
    else:
        w_specs = {
            "router": P(None, None),
            "wg": P(None, None, ax),
            "wu": P(None, None, ax),
            "wd": P(None, ax, None),
        }

    def shard_fn(xb, pw):
        tb, _, _ = xb.shape
        xf = xb.reshape(tb * S, d)
        if ep:
            idx = jax.lax.axis_index(ax)
            e_local = E // tp
            out, aux = _moe_local(xf, pw, cfg, idx * e_local, e_local, capacity)
        else:
            # f-TP: all experts, partial d_ff -> swiglu is elementwise in f,
            # wd contracts the local f slice; psum completes both f and E sums.
            out, aux = _moe_local(xf, pw, cfg, 0, E, capacity)
        # aux is computed from the full router on every model rank; de-dup.
        aux = aux / tp
        out = jax.lax.psum(out, ax)
        aux = jax.lax.psum(aux, ax)
        return out.reshape(tb, S, d), aux

    out, aux = shard_map(
        shard_fn,
        mesh=ctx.mesh,
        in_specs=(P(ctx.batch_axes if ctx.batch_axes else None, None, None), w_specs),
        out_specs=(P(ctx.batch_axes if ctx.batch_axes else None, None, None), P()),
        check_vma=False,
    )(x, {k: p[k] for k in ("router", "wg", "wu", "wd")})
    return out, aux / n_batch_shards
