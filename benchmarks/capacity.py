"""Paper Table I: system capacity, SLED vs centralized, per device type.

Capacity = number of edge devices the system supports at the same response
rate.  The paper reports x2.60 (RPi 4B), x2.86 (RPi 5), x2.77 (Jetson) —
our validation target is ratios in that x2-3 band.

``--cluster`` switches to the REAL replica-sharded serving stack: the sweep
is a list of :class:`~repro.api.ServeSpec` variants (replicas x kctl) built
through the unified ``repro.api`` front door — one base spec, each sweep
point a ``dataclasses.replace`` of it, every stack constructed by
``System.build`` with shared models/steps so the sweep measures capacity,
not compiles.  Capacity = peak concurrently-admitted streams under a
deadline-gated admission loop oversubscribing one replica's pool (should
scale ~linearly in replicas at matched deadline-miss rate); the kctl half
races adaptive vs fixed spec length over loopback transport.  ``--processes``
adds a CROSS-PROCESS sweep: 1 vs 2 spawned ``repro worker`` replicas behind
the Router's codec v3 control plane, same gate and deadline, where admitted
streams should again scale ~linearly — now across OS processes.  ``--json
PATH`` records the rows — stats via the uniform ``EngineStats.to_json`` /
``ServeResult.to_json`` records — as a BENCH artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import emit
from repro.serving.devices import A100_X4, DEVICES
from repro.serving.simulator import SimConfig, capacity


def run(quick: bool = False) -> list:
    rows = []
    sim_time = 20.0 if quick else 45.0
    for dev_name in ("rpi4b", "rpi5", "jetson-orin-nano"):
        dev = DEVICES[dev_name]
        base = SimConfig(
            mode="sled", spec_len=4, acceptance=0.90,
            device_rate=dev.rate("llama-1b-draft", 4),
            target_params=11e9, server_batch=16, batch_policy="deadline",
            sim_time=sim_time,
        )
        cap_sled = capacity(base, A100_X4, n_max=2048)
        cap_cent = capacity(dataclasses.replace(base, mode="centralized"),
                            A100_X4, n_max=2048)
        rows.append({
            "device": dev_name,
            "cap_sled": cap_sled,
            "cap_centralized": cap_cent,
            "improvement": round(cap_sled / max(cap_cent, 1), 2),
            "paper_claim": {"rpi4b": 2.60, "rpi5": 2.86, "jetson-orin-nano": 2.77}[dev_name],
        })
    emit(rows, "table1_capacity")
    return rows


# ---------------------------------------------------------------------------
# real cluster: replica capacity scaling + adaptive spec length
# (spec sweeps through the repro.api front door)
# ---------------------------------------------------------------------------


def _base_spec(quick: bool):
    from repro.api import ModelSpec, ServeSpec

    # random-init pairs agree greedily (trivial 1.0 acceptance); perturb the
    # draft so rejections are real and the adaptive controller has a signal
    return ServeSpec(
        backend="cluster",
        model=ModelSpec(
            vocab_size=128,
            target_layers=2 if quick else 3,
            draft_layers=None,  # full reduced draft
            draft_noise=0.05,
            seed=0,
        ),
        prompt_len=10,
        k_max=4,
        session_seed_base=0,
    )


def _drive_deadline_gated(system, spec, *, n_offer, max_new, deadline_s, miss_cap, window):
    """Run the deadline-gated admission loop against one built System.

    A new stream is admitted only while the trailing ``window`` of verdict
    latencies meets the per-round deadline, so peak admitted streams is a
    measured serving capacity — pool-bound when the replicas keep up
    (``gated_by: pool``), compute-bound when they don't (``gated_by:
    deadline``).  Shared by the in-process replica sweep and the
    cross-process worker sweep (same loop, same gate — only the System's
    replica flavor differs)."""
    router, kit = system.engine, system.kit
    prompts = system.prompts()
    devices, outputs, waiting = {}, {}, list(range(n_offer))
    submit_at, latencies = {}, []
    peak_admitted = 0
    deadline_gated = False
    t0 = time.time()
    while len(outputs) < n_offer:
        now = time.time() - t0
        recent = latencies[-window:]
        meeting_deadline = (
            sum(1 for lat in recent if lat > deadline_s)
            <= miss_cap * len(recent)
        )
        deadline_gated |= not meeting_deadline
        while waiting and router.n_free > 0 and meeting_deadline:
            i = waiting.pop(0)
            stream = router.admit(i, prompts[i], now)
            assert stream is not None, "router reported a free slot"
            devices[i] = kit.spawn(i, prompts[i], max_len=spec.max_len, seed=i)
        peak_admitted = max(peak_admitted, len(router.streams))
        for i, dev in devices.items():
            if not dev.awaiting:
                now = time.time() - t0
                router.submit(i, dev.draft(), now)
                submit_at[i] = now
        verdicts = router.step(time.time() - t0)
        now = time.time() - t0
        for v in verdicts or []:
            latencies.append(now - submit_at[v.device_id])
            dev = devices[v.device_id]
            dev.on_verdict(v)
            if len(dev.committed) >= max_new:
                outputs[v.device_id] = dev.committed[:max_new]
                router.retire(v.device_id)
                del devices[v.device_id]
    wall = time.time() - t0
    st = router.stats(wall)
    misses = sum(1 for lat in latencies if lat > deadline_s)
    return {
        "capacity_streams": peak_admitted,
        "gated_by": "deadline" if deadline_gated else "pool",
        "deadline_s": deadline_s,
        "deadline_miss_rate": round(misses / max(len(latencies), 1), 4),
        "wstgr": round(n_offer * max_new / wall, 2),
        "migrations": router.migrations,
        "wall_s": round(wall, 2),
        "engine": st.to_json(),
    }


def _capacity_rows(base, *, quick: bool) -> list:
    """Replica sweep under oversubscribed offered load, in-process driver.

    The sweep is a list of ServeSpecs (one per replica count) built on
    shared models and one shared VerifySteps bundle, so every replica count
    runs the same compiled executables (the sweep measures capacity, not
    compiles).
    """
    from repro.api import ClusterSpec, SchedulerSpec, System, build_models

    slots, max_new = (2, 5) if quick else (3, 10)
    replica_counts = (1, 2) if quick else (1, 2, 4)
    n_offer = 2 * max(replica_counts) * slots  # oversubscribe every config

    base = dataclasses.replace(
        base,
        devices=n_offer,
        max_new=max_new,
        c_th=0.3,
        scheduler=SchedulerSpec(slots=slots),
    )
    sweep = [
        dataclasses.replace(base, cluster=ClusterSpec(replicas=n)) for n in replica_counts
    ]
    models = build_models(base.model)

    # warm every jitted path — verify buckets, prefill, draft — up front on a
    # throwaway single-replica system sharing the sweep's step bundle + kit
    warm = System.build(
        dataclasses.replace(sweep[0], cluster=ClusterSpec(replicas=1), devices=1),
        models=models,
    )
    warm.warmup()
    warm.serve(prompts=warm.prompts()[:1])
    steps, kit = warm.steps, warm.kit

    rows = []
    base_capacity = None
    for spec in sweep:
        system = System.build(spec, models=models, steps=steps, kit=kit)
        row = _drive_deadline_gated(
            system, spec, n_offer=n_offer, max_new=max_new,
            deadline_s=2.0, miss_cap=0.1, window=16,
        )
        if base_capacity is None:
            base_capacity = row["capacity_streams"]
        row = {
            "section": "capacity",
            "spec": spec.to_json(),
            "capacity_ratio": round(row["capacity_streams"] / max(base_capacity, 1), 2),
            **row,
        }
        rows.append(row)
        print(
            f"[capacity] {spec.cluster.n_replicas} replica(s): peak "
            f"{row['capacity_streams']} admitted ({row['capacity_ratio']}x), "
            f"miss rate {row['deadline_miss_rate']:.1%}, {row['wstgr']} tok/s"
        )
    return rows


def _processes_rows(base, *, quick: bool) -> list:
    """Cross-PROCESS capacity: 1 vs 2 spawned ``repro worker`` replicas.

    Same deadline-gated loop and matched deadline as the in-process sweep,
    but each replica is a worker OS process behind the codec v3 control
    plane — 2 single-engine workers should admit ~2x the streams of 1 at
    matched miss rate (the ISSUE's >=1.8x near-linear floor), because each
    worker verifies in its own process and the Router fans step RPCs out
    concurrently.  Every worker rebuilds params from the spec seed, so the
    sweep's outputs stay token-identical to the in-process cluster."""
    from repro.api import ClusterSpec, SchedulerSpec, System

    slots, max_new = (2, 5) if quick else (3, 10)
    worker_counts = (1, 2)
    n_offer = 2 * max(worker_counts) * slots

    base = dataclasses.replace(
        base,
        devices=n_offer,
        max_new=max_new,
        c_th=0.3,
        scheduler=SchedulerSpec(slots=slots),
    )
    sweep = [
        dataclasses.replace(
            base,
            # telemetry rides along: each worker ships its registry snapshot +
            # flight-recorder rows back inside ReplicaStats, so the artifact
            # carries the per-worker span breakdown (observation-only)
            telemetry=True,
            cluster=ClusterSpec(replicas=[{"flavor": "remote"}] * n),
        )
        for n in worker_counts
    ]

    rows = []
    base_capacity = None
    for spec in sweep:
        system = System.build(spec)
        try:
            system.warmup()  # per-worker RPC: each process compiles its own
            row = _drive_deadline_gated(
                system, spec, n_offer=n_offer, max_new=max_new,
                deadline_s=2.0, miss_cap=0.1, window=16,
            )
            # per-worker stats must be captured BEFORE close() reaps the
            # worker processes; stats() also pulls each worker's telemetry
            # payload over the control plane
            per_worker = [st.to_json() for st in system.engine.replica_stats()]
            tele = system.engine.telemetry_payload()
        finally:
            system.close()  # drain + reap the spawned workers
        if base_capacity is None:
            base_capacity = row["capacity_streams"]
        row = {
            "section": "capacity-processes",
            "spec": spec.to_json(),
            "workers": spec.cluster.n_replicas,
            "workers_stats": per_worker,
            "telemetry": tele,
            "capacity_ratio": round(row["capacity_streams"] / max(base_capacity, 1), 2),
            **row,
        }
        rows.append(row)
        print(
            f"[capacity-processes] {row['workers']} worker(s): peak "
            f"{row['capacity_streams']} admitted ({row['capacity_ratio']}x), "
            f"miss rate {row['deadline_miss_rate']:.1%}, {row['wstgr']} tok/s"
        )
    from repro import telemetry

    telemetry.enable(False)  # don't bleed collection into later timed sweeps
    return rows


def _fill_warmup_buckets(payload, engine_buckets) -> list:
    """Derive a ``warmup(buckets=...)`` subset from the telemetry fill
    histogram: each non-empty ``engine_verify_fill`` bucket maps to the
    engine bucket that fill pads into, so a follow-up deployment warms only
    the batch shapes the workload actually dispatched instead of the full
    power-of-two ladder."""
    h = ((payload or {}).get("snapshot") or {}).get("histograms", {}).get(
        "engine_verify_fill"
    )
    if not h or not engine_buckets:
        return list(engine_buckets)
    prev, hit = 0, set()
    for ub, cum in h.get("buckets", []):
        count = int(cum) - prev
        prev = int(cum)
        if count <= 0:
            continue
        fill = engine_buckets[-1] if ub == "+Inf" else float(ub)
        hit.add(next((b for b in engine_buckets if b >= fill), engine_buckets[-1]))
    return sorted(hit) or list(engine_buckets)


def _kv_dtype_rows(base, *, quick: bool) -> list:
    """KV-pool dtype sweep at a FIXED pool byte budget (the ISSUE's memory
    ceiling): bytes-per-slot comes from each dtype's spec-only cache, the
    byte budget buys ``budget // bytes_per_slot`` pool rows, and the same
    deadline-gated admission loop measures peak concurrently-admitted
    streams.  int8 rows cost ~half the bytes, so the same budget admits
    ~2x the streams at matched deadline-miss rate (>=1.8x floor).

    The second (int8) run also exercises the telemetry-derived warmup
    subset: the bf16 run's ``engine_verify_fill`` histogram names the
    buckets the workload actually dispatched, and the int8 system warms
    only those."""
    import jax
    import jax.numpy as jnp

    from repro.api import ClusterSpec, SchedulerSpec, System, build_models

    slots_bf16, max_new = (2, 5) if quick else (3, 10)
    base = dataclasses.replace(base, max_new=max_new, c_th=0.3, telemetry=True)
    models = build_models(base.model)

    def bytes_per_slot(kv_dtype: str) -> int:
        kw = {"kv_dtype": jnp.int8} if kv_dtype == "int8" else {}
        cache = models.target.make_cache(
            1, base.max_len, attn_chunk=base.attn_chunk, spec_only=True, **kw
        )
        return sum(
            int(a.size) * jnp.dtype(a.dtype).itemsize for a in jax.tree.leaves(cache)
        )

    # the fixed HBM stand-in: the byte budget must cover the pool's physical
    # rows — n_slots serveable + 1 scratch (PagedKVCache) — so a dtype's
    # serveable slot count is budget // bytes_per_slot - 1
    budget = (slots_bf16 + 1) * bytes_per_slot("bf16")
    n_offer = 2 * (budget // bytes_per_slot("int8") - 1)  # oversubscribe both

    rows = []
    warm_buckets = None  # first run warms everything; second warms the subset
    base_row = None
    for kv_dtype in ("bf16", "int8"):
        bps = bytes_per_slot(kv_dtype)
        n_slots = budget // bps - 1
        spec = dataclasses.replace(
            base,
            kv_dtype=kv_dtype,
            devices=n_offer,
            cluster=ClusterSpec(replicas=1),
            scheduler=SchedulerSpec(slots=n_slots),
        )
        system = System.build(spec, models=models)
        compile_s = system.warmup(warm_buckets)
        row = _drive_deadline_gated(
            system, spec, n_offer=n_offer, max_new=max_new,
            deadline_s=2.0, miss_cap=0.1, window=16,
        )
        payload = system.engine.telemetry_payload()
        engine_buckets = sorted(compile_s) if warm_buckets is None else warm_buckets
        derived = _fill_warmup_buckets(payload, sorted(set(engine_buckets)))
        row = {
            "section": "kv-dtype",
            "kv_dtype": kv_dtype,
            "pool_byte_budget": budget,
            "bytes_per_slot": bps,
            "n_slots": n_slots,
            "warmup_buckets": sorted(compile_s),
            "warmup_seconds": round(sum(compile_s.values()), 2),
            "fill_derived_buckets": derived,
            "pools": payload.get("pools", {}),
            "spec": spec.to_json(),
            **row,
        }
        if base_row is None:
            base_row = row
        row["capacity_ratio"] = round(
            row["capacity_streams"] / max(base_row["capacity_streams"], 1), 2
        )
        rows.append(row)
        warm_buckets = derived  # the int8 run warms only the observed fills
        print(
            f"[kv-dtype {kv_dtype}] {bps} B/slot -> {n_slots} slots in the "
            f"{budget} B budget; peak {row['capacity_streams']} admitted "
            f"({row['capacity_ratio']}x), miss rate "
            f"{row['deadline_miss_rate']:.1%}, warmed {row['warmup_buckets']}"
        )
    ratio = rows[-1]["capacity_streams"] / max(rows[0]["capacity_streams"], 1)
    rows.append({
        "section": "kv-dtype-summary",
        "admitted_ratio_int8_vs_bf16": round(ratio, 2),
        "meets_1_8x_floor": bool(ratio >= 1.8),
        "miss_rate_bf16": rows[0]["deadline_miss_rate"],
        "miss_rate_int8": rows[1]["deadline_miss_rate"],
    })
    from repro import telemetry

    telemetry.enable(False)
    return rows


def _kctl_rows(base, *, quick: bool) -> list:
    """Adaptive vs fixed spec length over loopback transport (real feedback
    loop: Verdict accept_rate/queue_depth -> AIMD controller -> draft k) —
    two ServeSpecs differing only in ``kctl``, served through the API."""
    from repro.api import ClusterSpec, SchedulerSpec, System, TransportSpec, build_models

    n_dev, max_new = (3, 8) if quick else (4, 16)
    base = dataclasses.replace(
        base,
        backend="transport",
        cluster=ClusterSpec(replicas=1),
        transport=TransportSpec(link="loopback", verify_timeout=30.0, stagger_s=0.0),
        scheduler=SchedulerSpec(slots=n_dev, stagger_ticks=0),
        devices=n_dev,
        prompt_seed=5,
        max_new=max_new,
        c_th=0.0,
    )
    sweep = [dataclasses.replace(base, kctl=k) for k in ("fixed", "adaptive")]
    models = build_models(base.model)

    # warm fleet evens out first-use compiles (verify buckets, prefill,
    # draft, peek) before either configuration is timed; both measured
    # systems share its step bundle and device kit
    warm = System.build(sweep[0], models=models)
    warm.warmup()
    warm.serve()
    steps, kit = warm.steps, warm.kit

    rows = []
    for spec in sweep:
        system = System.build(spec, models=models, steps=steps, kit=kit)
        result = system.serve()
        st, fleet = result.engine, result.clients
        rows.append({
            "section": "kctl",
            "kctl": spec.kctl,
            "spec": spec.to_json(),
            "wstgr": round(result.total_tokens / result.wall_seconds, 2),
            "acceptance": round(st.acceptance_rate, 3),
            "rounds": st.rounds,
            "k_mean": round(fleet.k_mean, 2),
            "k_final": fleet.k_final,
            # device-side draft() work per committed token (ClientStats.drafted
            # — the legacy EdgeDevice.drafted quantity adaptive-k reduces)
            "drafted_per_token": round(
                sum(s.client.drafted for s in result.sessions)
                / max(result.total_tokens, 1), 2,
            ),
            "bytes_up": st.bytes_rx,
            "wall_s": round(result.wall_seconds, 2),
            "engine": st.to_json(),
            "clients": fleet.to_json(),
        })
        print(
            f"[kctl {spec.kctl}] {rows[-1]['wstgr']} tok/s, acceptance "
            f"{rows[-1]['acceptance']}, mean k {rows[-1]['k_mean']}, "
            f"{rows[-1]['drafted_per_token']} drafted/token"
        )
    return rows


def run_cluster(quick: bool = False, json_path: str = "", processes: bool = False,
                kv_dtype: bool = False) -> list:
    base = _base_spec(quick)
    rows = _capacity_rows(base, quick=quick)
    if processes:
        rows += _processes_rows(base, quick=quick)
    if kv_dtype:
        rows += _kv_dtype_rows(base, quick=quick)
    rows += _kctl_rows(base, quick=quick)
    emit(rows, "cluster_capacity")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "cluster_capacity", "quick": quick, "rows": rows}, f,
                      indent=2)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", action="store_true",
                    help="real replica-sharded capacity sweep + adaptive-k fleet")
    ap.add_argument("--processes", action="store_true",
                    help="with --cluster: add a cross-process sweep over "
                         "spawned repro-worker replicas (1 vs 2 OS processes)")
    ap.add_argument("--kv-dtype", action="store_true",
                    help="with --cluster: add the bf16-vs-int8 KV pool sweep "
                         "at a fixed pool byte budget (slots-per-HBM-byte)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", type=str, default="",
                    help="write the rows as a BENCH JSON artifact")
    a = ap.parse_args()
    if a.cluster:
        run_cluster(quick=a.quick, json_path=a.json, processes=a.processes,
                    kv_dtype=a.kv_dtype)
    else:
        rows = run(quick=a.quick)
        if a.json:
            with open(a.json, "w") as f:
                json.dump({"benchmark": "table1_capacity", "quick": a.quick,
                           "rows": rows}, f, indent=2)
            print(f"wrote {a.json}")
