"""repro.tuning — profiling-driven auto-configuration of heterogeneous
fleets (``repro tune``).

    profile.py   measure: a short telemetry-on serve calibrates per-class
                 acceptance/draft-length priors and the server latency
                 scale; tiny reference probes price candidate draft configs
    search.py    decide: coordinate-descent sweep of per-class (k, c_th,
                 draft model, bits) candidates through the calibrated
                 simulator + Eq. 2 cost model, validated on the real engine

See ROADMAP.md "Heterogeneous fleets" and ISSUE 10 for the design.
"""

from repro.tuning.profile import (
    ClassCalibration,
    FleetCalibration,
    class_commit_rate,
    class_draft_rate,
    make_prober,
    probe_draft_config,
    profile_fleet,
)
from repro.tuning.search import (
    TuneConfig,
    TuneResult,
    at_multiplier,
    measured_run,
    scaled_fleet,
    score_candidate,
    sim_config_for,
    sim_fleet_capacity,
    tune,
    with_class,
)

__all__ = [
    "ClassCalibration",
    "FleetCalibration",
    "TuneConfig",
    "TuneResult",
    "at_multiplier",
    "class_commit_rate",
    "class_draft_rate",
    "make_prober",
    "measured_run",
    "probe_draft_config",
    "profile_fleet",
    "scaled_fleet",
    "score_candidate",
    "sim_config_for",
    "sim_fleet_capacity",
    "tune",
    "with_class",
]
