"""SLED server launcher on the continuous-batching engine (single-host demo
of the deployment path; the production mesh path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --devices 6

Real models end-to-end: edge devices (batch-1 draft loops) join at staggered
times, draft at heterogeneous lengths, and stream verification requests into
a ServerEngine whose BatchPlanner policy (default ``continuous``) dispatches
whatever subset is queued — so batches are PARTIAL by construction, slots
free as devices finish, and waiting devices are admitted mid-stream.  With
``--check`` (default) the committed greedy tokens are verified token-for-
token against the lock-step reference loop (engine_loop.sled_generate):
continuous batching must not change outputs, only scheduling.
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.engine_loop import sled_generate
from repro.core.server_engine import EdgeDeviceKit, ServerEngine
from repro.models.model_zoo import build_model
from repro.quant.quantize import dequantize_pytree, quantize_pytree


def serve(args) -> dict:
    vocab = 256
    tcfg = dataclasses.replace(get_config(args.arch).reduced(), vocab_size=vocab)
    dcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="edge-draft", vocab_size=vocab, num_layers=1
    )
    target = build_model(tcfg)
    draft = build_model(dcfg)
    kw = {"max_pos": 256} if not tcfg.use_rope else {}
    tp = target.init_params(jax.random.key(0), **kw)
    if args.bits < 16:
        tp = dequantize_pytree(quantize_pytree(tp, args.bits))
        print(f"serving int{args.bits} weight-only quantized target")
    dp = draft.init_params(jax.random.key(1))

    N, max_len = args.devices, 128
    prompts = jax.random.randint(jax.random.key(2), (N, 12), 0, vocab)
    engine = ServerEngine(
        target,
        tp,
        n_slots=args.slots or N,
        max_len=max_len,
        k_max=args.k_max,
        policy=args.policy,
        max_wait=args.max_wait,
        attn_chunk=32,
    )
    kit = EdgeDeviceKit(draft, dp, k_max=args.k_max, c_th=args.c_th, greedy=True, attn_chunk=32)

    # staggered joins: device i shows up i * stagger rounds into the run, so
    # early rounds verify a strict subset and late rounds drain the tail
    join_at = {i: i * args.stagger for i in range(N)}
    devices, outputs, waiting = {}, {}, set(range(N))
    t0 = time.time()
    tick, rounds = 0, 0
    min_fill, max_fill = N, 0
    while len(outputs) < N:
        tick += 1
        now = time.time() - t0
        for i in sorted(waiting):
            if join_at[i] > tick:
                continue
            if engine.admit(i, prompts[i], now) is None:
                break  # pool full: stays waiting, admitted when a slot frees
            devices[i] = kit.spawn(i, prompts[i], max_len=max_len, seed=1000 + i)
            waiting.discard(i)
        for i, dev in devices.items():
            if not dev.awaiting:
                engine.submit(i, dev.draft(), time.time() - t0)
        verdicts = engine.step(time.time() - t0)
        if verdicts is None:
            continue
        rounds += 1
        min_fill = min(min_fill, len(verdicts))
        max_fill = max(max_fill, len(verdicts))
        for v in verdicts:
            dev = devices[v.device_id]
            dev.on_verdict(v)
            if len(dev.committed) >= args.max_new:
                outputs[v.device_id] = dev.committed[: args.max_new]
                engine.retire(v.device_id)
                del devices[v.device_id]
        if rounds % 5 == 0 or len(verdicts) < N:
            print(
                f"round {rounds:3d}: batch {len(verdicts)}/{N} "
                f"queue {engine.queue_depth} active {len(devices)} "
                f"done {len(outputs)}"
            )

    now = time.time() - t0
    stats = engine.stats(now)
    print(
        f"served {stats.streams_served} streams, "
        f"{sum(len(o) for o in outputs.values())} tokens in {stats.rounds} rounds "
        f"({stats.wstgr:.1f} tok/s on CPU) — mean batch fill "
        f"{stats.mean_batch_fill:.2f}/{N}, {stats.partial_rounds} partial rounds, "
        f"fill range [{min_fill}, {max_fill}]"
    )
    if args.policy == "continuous" and N > 1:
        # deadline/static deliberately wait for fill; only the continuous
        # policy must dispatch whatever subset is queued
        assert min_fill < N, "staggered arrivals should produce a partial batch"

    if args.check:
        ref, _, _ = sled_generate(
            draft, dp, target, tp, prompts,
            max_new=args.max_new, k_max=args.k_max, c_th=args.c_th, greedy=True,
        )
        eng = np.array([outputs[i] for i in range(N)])
        match = np.array_equal(eng, np.asarray(ref))
        print(f"greedy lock-step reference match: {'OK' if match else 'MISMATCH'}")
        assert match, "continuous-batching engine must be output-identical to sled_generate"
    return stats.as_dict()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen2-1.5b")
    ap.add_argument("--devices", type=int, default=6)
    ap.add_argument("--slots", type=int, default=0, help="cache pool rows (0: = devices)")
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--c-th", type=float, default=0.3)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--policy", choices=("continuous", "deadline", "static"),
                    default="continuous")
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--stagger", type=int, default=3,
                    help="device i joins i*stagger scheduler ticks into the run")
    ap.add_argument("--bits", type=int, default=16, choices=(4, 8, 16))
    ap.add_argument("--check", action=argparse.BooleanOptionalAction, default=True,
                    help="verify engine output equals the lock-step reference")
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
