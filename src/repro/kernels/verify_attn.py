"""Pallas TPU kernel: SLED batched-verification attention.

The server's hot loop attends Sq = K+1 fresh tokens per request against a
long KV cache.  TPU adaptation (vs the CUDA "append attention" kernels GPU
serving engines use — DESIGN.md §3):

  * the MXU wants >= 8 x 128 tiles, but Sq is tiny (5).  We PACK the GQA
    group dimension into the query rows: rows = Sq * G (granite MQA: 5 x 48
    = 240 rows — full MXU occupancy from what would be a 5-row matmul);
  * the KV cache streams HBM->VMEM once in ``block_k`` chunks along the
    sequence — verification at small K is HBM-bound, so one pass over the
    cache IS the roofline;
  * online-softmax state (m, l, acc) lives in fp32 VMEM scratch across the
    kv-chunk grid axis (TPU grids iterate the last axis sequentially);
  * the causal offset mask (query i sits at absolute position
    kv_valid - Sq + i) is computed from iota over packed rows — no mask
    tensor is ever materialised.

Layouts: q is pre-packed to (B, Hkv, Sq*G, D) by ops.py (tiny transpose);
k/v stay (B, Skv, Hkv, D) — BlockSpec index maps stride the head dim, so
the multi-GB cache is never transposed.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(kv_valid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_k: int, sq: int, scale: float):
    j_blk = pl.program_id(2)
    n_blk = pl.num_programs(2)

    @pl.when(j_blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (rows, D) rows = Sq*G
    k = k_ref[0, :, 0, :]  # (block_k, D)
    v = v_ref[0, :, 0, :]
    rows = q.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (rows, block_k)

    kv_valid = kv_valid_ref[0]
    # packed row r -> query index i = r // G; abs position = kv_valid - Sq + i
    g = rows // sq
    i_vec = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
    j_vec = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1) + j_blk * block_k
    mask = j_vec <= (kv_valid - sq + i_vec)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j_blk == n_blk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def verify_attention_packed(
    q: jax.Array,        # (B, Hkv, rows=Sq*G, D)
    k: jax.Array,        # (B, Skv, Hkv, D)
    v: jax.Array,
    kv_valid: jax.Array,  # (B,) int32
    *,
    sq: int,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,  # CPU container: interpret; flip off on TPU
) -> jax.Array:
    B, Hkv, rows, D = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0, "cache buffers are sized to block multiples"
    n_blk = Skv // block_k

    kernel = functools.partial(_kernel, block_k=block_k, sq=sq, scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_blk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),                 # kv_valid
            pl.BlockSpec((1, 1, rows, D), lambda b, h, j: (b, h, 0, 0)),   # q
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),  # k
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # m
            pltpu.VMEM((rows, 1), jnp.float32),   # l
            pltpu.VMEM((rows, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(kv_valid, q, k, v)
