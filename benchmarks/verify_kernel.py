"""SLED verification-attention kernel: modeled HBM traffic vs the XLA path.

No TPU in this container, so the comparison is structural: we lower the
pure-XLA flash verification attention, walk its HLO with the trip-aware
cost model, and compare bytes moved against the Pallas kernel's analytic
minimum (stream KV exactly once + write O(Sq) output).  Correctness of the
kernel itself is covered by tests/test_kernels.py (interpret-mode sweeps).

``--engine`` compares the paged (slot-gather/scatter) verify step against
the dense lock-step verify step the same way: both are lowered for matched
shapes and their HLO byte totals quantify what continuous batching pays for
arbitrary row-subset dispatch (the gather/scatter tax a paged attention
kernel would eliminate — see ROADMAP).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.models.layers import flash_attention
from repro.roofline.hlo_cost import HloCostModel


def run(quick: bool = False) -> list:
    rows = []
    shapes = [
        (8, 5, 48, 1, 4096, 128),   # granite-34b-like MQA verify
        (8, 5, 32, 4, 4096, 128),   # qwen3-moe-like GQA verify
    ] if not quick else [(4, 5, 8, 1, 1024, 64)]
    for (B, Sq, Hq, Hkv, Skv, D) in shapes:
        q = jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.bfloat16)
        k = jax.ShapeDtypeStruct((B, Skv, Hkv, D), jnp.bfloat16)
        v = jax.ShapeDtypeStruct((B, Skv, Hkv, D), jnp.bfloat16)
        kv_valid = jax.ShapeDtypeStruct((B,), jnp.int32)

        def xla_path(q, k, v, kv_valid):
            q_pos = kv_valid[:, None] - Sq + jnp.arange(Sq)[None]
            return flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                   chunk=min(1024, Skv))

        lowered = jax.jit(xla_path).lower(q, k, v, kv_valid)
        costs = HloCostModel(lowered.compile().as_text()).totals()
        kv_bytes = 2 * B * Skv * Hkv * D * 2  # stream K and V exactly once
        out_bytes = 2 * B * Sq * Hq * D * 2
        kernel_min = kv_bytes + out_bytes
        rows.append({
            "shape": f"B{B}xSq{Sq}xHq{Hq}/{Hkv}xS{Skv}xD{D}",
            "xla_bytes_mb": round(costs["bytes"] / 1e6, 1),
            "kernel_min_mb": round(kernel_min / 1e6, 1),
            "traffic_ratio": round(costs["bytes"] / kernel_min, 2),
            "mxu_rows_packed": Sq * (Hq // Hkv),
        })
    emit(rows, "verify_kernel")
    return rows


def run_engine(quick: bool = False) -> list:
    """Lower dense vs paged verify steps for matched bucket shapes and
    compare trip-aware HLO bytes: the paged step's extra traffic is the
    row gather/scatter that buys arbitrary-subset continuous batching."""
    from repro.configs.base import get_config
    from repro.core import verification
    from repro.models.model_zoo import build_model

    vocab = 128
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_slots, k_max, max_len = (4, 4, 64) if quick else (8, 4, 128)

    rows = []
    for bucket in ((2,) if quick else (2, 4, 8)):
        pool = model.make_cache(n_slots + 1, max_len, attn_chunk=32)
        dense_cache = model.make_cache(bucket, max_len, attn_chunk=32)
        batch = verification.verify_batch_spec(bucket, k_max)
        batch = {k: jnp.zeros(v.shape, v.dtype) for k, v in batch.items()}
        slots = jnp.arange(bucket, dtype=jnp.int32)

        dense = verification.make_verify_step(model, greedy=True, attn_chunk=32)
        paged = verification.make_paged_verify_step(
            model, scratch_slot=n_slots, greedy=True, attn_chunk=32
        )
        dense_hlo = jax.jit(dense).lower(params, dense_cache, batch).compile().as_text()
        paged_hlo = (
            jax.jit(paged).lower(params, pool, slots, batch).compile().as_text()
        )
        d_bytes = HloCostModel(dense_hlo).totals()["bytes"]
        p_bytes = HloCostModel(paged_hlo).totals()["bytes"]
        rows.append({
            "bucket": bucket,
            "pool_slots": n_slots,
            "dense_bytes_mb": round(d_bytes / 1e6, 2),
            "paged_bytes_mb": round(p_bytes / 1e6, 2),
            "paging_tax": round(p_bytes / max(d_bytes, 1), 2),
        })
    emit(rows, "engine_verify_step")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="compare paged vs dense verify-step HLO traffic")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    (run_engine if a.engine else run)(quick=a.quick)
