"""Zamba2-style hybrid: Mamba2 backbone + one shared (weight-tied) attention block.

The shared block is applied after every ``cfg.attn_every`` SSM layers.  Its
weights are tied across applications (the zamba2 trick that keeps the
parameter count low), but each application has its own KV cache slice.

Speculative rollback: SSM layers checkpoint per-position states (mamba2.py),
the shared-attention caches roll back via ``length`` like any KV cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.layers import MeshContext, NO_MESH
from repro.models.transformer import _block

Params = Dict[str, Any]


def n_apps(cfg) -> int:
    return cfg.num_layers // cfg.attn_every


def _split_groups(cfg, stacked):
    """(L, ...) stacked ssm params -> ((n_apps, attn_every, ...), (tail, ...))."""
    na, ae = n_apps(cfg), cfg.attn_every
    full = na * ae

    def grp(a):
        return a[:full].reshape(na, ae, *a.shape[1:])

    def tail(a):
        return a[full:]

    return jax.tree.map(grp, stacked), jax.tree.map(tail, stacked)


def init_params(cfg, key, **_) -> Params:
    k_emb, k_ssm, k_attn, k_head = jax.random.split(key, 4)
    keys = jax.random.split(k_ssm, cfg.num_layers)
    ka1, ka2 = jax.random.split(k_attn)
    p: Params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(jnp.bfloat16),
        "ssm_layers": jax.vmap(lambda k: M2.init_ssd_layer(cfg, k))(keys),
        "shared_attn": {
            "ln1": L.init_norm(cfg.d_model, cfg.norm),
            "attn": L.init_attention(ka1, cfg),
            "ln2": L.init_norm(cfg.d_model, cfg.norm),
            "mlp": L.init_mlp(ka2, cfg),
        },
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * 0.02).astype(jnp.bfloat16)
    return p


lm_head = M2.lm_head


def make_cache(cfg, batch: int, max_len: int, *, spec_only: bool = False,
               attn_chunk: int = 1024, **_):
    max_len = -(-max_len // attn_chunk) * attn_chunk
    ssm = M2.make_cache(cfg, batch, spec_only=spec_only)
    na = n_apps(cfg)
    kv_shape = (na, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if spec_only:
        kv = {
            "k": jax.ShapeDtypeStruct(kv_shape, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(kv_shape, jnp.bfloat16),
        }
    else:
        kv = {"k": jnp.zeros(kv_shape, jnp.bfloat16), "v": jnp.zeros(kv_shape, jnp.bfloat16)}
    return {**ssm, **kv}


def forward(cfg, params, tokens, ctx: MeshContext = NO_MESH, *, remat=False,
            attn_chunk: int = 1024, **_):
    x = L.embed_lookup(params["embed"], tokens, ctx)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    groups, tail = _split_groups(cfg, params["ssm_layers"])

    def ssm_body(h, lp):
        return M2.ssd_layer_forward(cfg, lp, h, remat_inner=remat, ctx=ctx), None

    if remat:
        ssm_body = jax.checkpoint(ssm_body, prevent_cse=False)

    def group_body(h, grp_params):
        h, _ = jax.lax.scan(ssm_body, h, grp_params)
        h, _, _ = _block(h, params["shared_attn"], cfg, ctx, positions=positions,
                         attn_chunk=attn_chunk, flash_remat=remat)
        return h, None

    x, _ = jax.lax.scan(group_body, x, groups)
    if cfg.num_layers % cfg.attn_every:
        x, _ = jax.lax.scan(ssm_body, x, tail)
    return L.apply_norm(x, params["final_norm"], cfg.norm), jnp.zeros((), jnp.float32)


def _run_cached(cfg, params, cache, tokens, ctx, attn_chunk, decode: bool):
    """Shared prefill/decode machinery. decode=True emits SSM checkpoints."""
    x = L.embed_lookup(params["embed"], tokens, ctx)
    B, S = tokens.shape
    cache_len = cache["length"]
    positions = cache_len[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    groups, tail = _split_groups(cfg, params["ssm_layers"])
    g_ssm, t_ssm = _split_groups(cfg, cache["ssm"])
    g_conv, t_conv = _split_groups(cfg, cache["conv"])

    def ssm_step(h, xs):
        lp, h0, c0 = xs
        if decode:
            out, h_ck, c_ck = M2.ssd_layer_decode(cfg, lp, h, h0, c0)
            return out, (h_ck, c_ck)
        out, (hf, cf) = M2.ssd_layer_forward(cfg, lp, h, h0=h0, conv0=c0,
                                             return_state=True, ctx=ctx)
        return out, (hf, cf.astype(jnp.bfloat16))

    def group_body(h, xs):
        grp, h0s, c0s, kl, vl = xs
        h, states = jax.lax.scan(ssm_step, h, (grp, h0s, c0s))
        h, new_kv, _ = _block(h, params["shared_attn"], cfg, ctx, positions=positions,
                              kv=(kl, vl), cache_len=cache_len, attn_chunk=attn_chunk)
        return h, (states, new_kv)

    x, (g_states, new_kv) = jax.lax.scan(
        group_body, x, (groups, g_ssm, g_conv, cache["k"], cache["v"])
    )
    if cfg.num_layers % cfg.attn_every:
        x, t_states = jax.lax.scan(ssm_step, x, (tail, t_ssm, t_conv))
    else:
        t_states = jax.tree.map(lambda a: a[0][:0], g_states)  # empty (0, B, ...)

    def merge(g, t):  # (na, ae, B, ...) + (tail, B, ...) -> (L, B, ...)
        return jnp.concatenate([g.reshape(-1, *g.shape[2:]), t], axis=0)

    ssm_s, conv_s = jax.tree.map(merge, g_states[0], t_states[0]), jax.tree.map(
        merge, g_states[1], t_states[1]
    )
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    return x, ssm_s, conv_s, new_kv


def prefill(cfg, params, tokens, cache, ctx: MeshContext = NO_MESH, *,
            attn_chunk: int = 1024, **_):
    x, ssm_s, conv_s, new_kv = _run_cached(cfg, params, cache, tokens, ctx, attn_chunk, False)
    new_cache = {
        "ssm": ssm_s, "conv": conv_s, "k": new_kv[0], "v": new_kv[1],
        "length": cache["length"] + tokens.shape[1],
    }
    return lm_head(cfg, params, x[:, -1:, :])[:, 0], new_cache


def decode_forward(cfg, params, cache, tokens, ctx: MeshContext = NO_MESH, *,
                   attn_chunk: int = 1024, slots=None, **_):
    if slots is not None:
        raise NotImplementedError(
            "slot-indexed paged attention is not supported for the 'hybrid' "
            "family: its recurrent state leaves (ssm, conv) are not position-"
            "indexed K/V, so pool rows cannot be addressed in place.  Route "
            "this model through the gather/scatter fallback instead "
            "(paged_attention=False, or gate on "
            "models.kvcache.supports_paged_attention(cfg))."
        )
    x, ssm_ck, conv_ck, new_kv = _run_cached(cfg, params, cache, tokens, ctx, attn_chunk, True)
    ckpt_cache = {**cache, "k": new_kv[0], "v": new_kv[1],
                  "ssm_ckpt": ssm_ck, "conv_ckpt": conv_ck}
    return x, ckpt_cache, jnp.zeros((), jnp.float32)


def select_checkpoint(cache: Dict[str, jax.Array], n_commit: jax.Array) -> Dict[str, jax.Array]:
    i = (n_commit - 1).astype(jnp.int32)
    b = jnp.arange(cache["ssm_ckpt"].shape[1])

    def take(a):
        return a[:, b, i]

    return {
        "ssm": take(cache["ssm_ckpt"]).astype(jnp.float32),
        "conv": take(cache["conv_ckpt"]),
        "k": cache["k"], "v": cache["v"],
        "length": cache["length"] + n_commit.astype(jnp.int32),
    }
