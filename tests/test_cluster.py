"""Cluster router: placement, migration, replica equivalence, adaptive k.

The load-bearing tests extend the PR-1/PR-2 equivalence ladder one more
level: a replica-sharded Router — including one that migrates a live stream
between replicas mid-run — must commit exactly the tokens the lock-step
reference loop commits.  Placement and migration may change which replica's
batches a stream rides in, never what it generates.
"""

import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster import Router, make_placement
from repro.configs.base import get_config
from repro.core.engine import EngineStats
from repro.core.engine_loop import sled_generate
from repro.core.server_engine import EdgeDeviceKit, ServerEngine
from repro.models.model_zoo import build_model, perturb_params
from repro.serving.speclen import SpecLenController, make_controller
from repro.transport import codec
from repro.transport.links import LoopbackLink, tcp_connect, tcp_listen

V = 128


def _models():
    tcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="tgt", vocab_size=V, num_layers=3
    )
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    dm, tm = build_model(dcfg), build_model(tcfg)
    dp = perturb_params(dm.init_params(jax.random.key(1)), 0.03)
    return dm, dp, tm, tm.init_params(jax.random.key(2))


def _drive(router, kit, prompts, *, max_new, seed_base=100):
    """In-process fleet loop over a router (mirrors launch/serve.py inproc);
    ``max_new`` may be per-device (list) to force staggered retirement."""
    n = prompts.shape[0]
    budgets = max_new if isinstance(max_new, (list, tuple)) else [max_new] * n
    devices, outputs = {}, {}
    now = 0.0
    while len(outputs) < n:
        now += 1.0
        for i in range(n):
            if i not in devices and i not in outputs:
                if router.admit(i, prompts[i], now) is not None:
                    devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=seed_base + i)
        for i, dev in devices.items():
            if not dev.awaiting:
                router.submit(i, dev.draft(), now)
        for v in router.step(now) or []:
            dev = devices[v.device_id]
            dev.on_verdict(v)
            if len(dev.committed) >= budgets[v.device_id]:
                outputs[v.device_id] = dev.committed[: budgets[v.device_id]]
                router.retire(v.device_id)
                del devices[v.device_id]
        assert now < 500, "fleet failed to drain"
    return outputs


# ---------------------------------------------------------------------------
# EngineStats.merge
# ---------------------------------------------------------------------------


def test_engine_stats_merge():
    a = EngineStats(
        wstgr=10.0, per_device_rate=5.0, server_busy_frac=0.4, rounds=8,
        timeouts=1, fallback_tokens=3, mean_batch_fill=2.0,
        mean_round_latency=0.1, server_rounds_per_s=4.0, partial_rounds=2,
        streams_served=2, acceptance_rate=0.8, mean_queue_depth=1.0,
        bytes_tx=100, frames_tx=10,
    )
    b = EngineStats(
        wstgr=30.0, per_device_rate=5.0, server_busy_frac=0.2, rounds=24,
        timeouts=0, fallback_tokens=1, mean_batch_fill=4.0,
        mean_round_latency=0.3, server_rounds_per_s=12.0, partial_rounds=1,
        streams_served=6, acceptance_rate=0.4, mean_queue_depth=3.0,
        bytes_tx=300, frames_tx=30,
    )
    m = EngineStats.merge([a, b])
    assert m.replicas == 2
    assert m.wstgr == 40.0 and m.server_rounds_per_s == 16.0
    assert m.rounds == 32 and m.timeouts == 1 and m.fallback_tokens == 4
    assert m.streams_served == 8 and m.partial_rounds == 3
    assert m.bytes_tx == 400 and m.frames_tx == 40
    # round-weighted means: (2*8 + 4*24) / 32 = 3.5
    assert m.mean_batch_fill == pytest.approx(3.5)
    assert m.mean_round_latency == pytest.approx(0.25)
    assert m.acceptance_rate == pytest.approx((0.8 * 8 + 0.4 * 24) / 32)
    # n_streams reconstructed as wstgr/per_device_rate: 2 + 6 devices
    assert m.per_device_rate == pytest.approx(40.0 / 8)
    # merge of one is a copy, not an alias
    one = EngineStats.merge([a])
    assert one == a and one is not a
    with pytest.raises(ValueError):
        EngineStats.merge([])


# ---------------------------------------------------------------------------
# adaptive spec-length controller
# ---------------------------------------------------------------------------


def test_speclen_aimd_increase_and_decrease():
    c = SpecLenController(k_max=8, k_min=1, k_init=4, ewma=1.0)
    # high acceptance, idle queue: additive increase up to the bound
    assert c.update(1.0, 0) == 5
    assert c.update(1.0, 0) == 6
    for _ in range(8):
        c.update(1.0, 0)
    assert c.k == 8  # bounded above
    # low acceptance: multiplicative back-off
    assert c.update(0.1, 0) == 4
    assert c.update(0.1, 0) == 2
    assert c.update(0.1, 0) == 1
    assert c.update(0.1, 0) == 1  # bounded below
    assert c.decreases >= 3 and c.increases >= 2


def test_speclen_congestion_backs_off_despite_acceptance():
    c = SpecLenController(k_max=8, k_init=8, queue_hi=2, ewma=1.0)
    # perfect acceptance but a deep replica queue still reads as congestion
    assert c.update(1.0, 10) == 4
    assert c.update(1.0, 10) == 2
    # queue drains -> probe back up
    assert c.update(1.0, 0) == 3


def test_speclen_middle_band_holds_k():
    c = SpecLenController(k_max=8, k_init=4, accept_lo=0.3, accept_hi=0.8, ewma=1.0)
    assert c.update(0.5, 0) == 4  # between thresholds: hold


def test_make_controller():
    assert make_controller("fixed", k_max=4) is None
    c = make_controller("adaptive", k_max=4)
    assert isinstance(c, SpecLenController) and c.k == 4
    with pytest.raises(ValueError):
        make_controller("warp", k_max=4)
    with pytest.raises(ValueError):
        SpecLenController(k_max=2, k_min=3)


# ---------------------------------------------------------------------------
# codec feedback fields
# ---------------------------------------------------------------------------


def test_codec_verdict_feedback_roundtrip():
    v = codec.Verdict(
        device_id=3, seq=9, n_accepted=2,
        tokens=np.asarray([1, 2, 3], np.int32), next_prev=7,
        accept_rate=0.625, queue_depth=5,
    )
    out, used = codec.decode_frame(codec.encode_frame(v))
    assert used == len(codec.encode_frame(v))
    assert out.accept_rate == pytest.approx(0.625)
    assert out.queue_depth == 5
    np.testing.assert_array_equal(out.tokens, v.tokens)
    # defaults stay wire-compatible within v2
    out2, _ = codec.decode_frame(
        codec.encode_frame(codec.Verdict(1, 2, 1, np.asarray([4], np.int32), 4))
    )
    assert out2.accept_rate == 0.0 and out2.queue_depth == 0


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------


def test_idle_replica_does_not_skew_merged_means():
    busy = EngineStats(
        wstgr=10.0, per_device_rate=5.0, server_busy_frac=0.5, rounds=100,
        timeouts=0, fallback_tokens=0, mean_batch_fill=4.0,
        mean_round_latency=0.2, server_rounds_per_s=2.0, streams_served=2,
        acceptance_rate=0.9,
    )
    idle = EngineStats(
        wstgr=0.0, per_device_rate=0.0, server_busy_frac=0.0, rounds=0,
        timeouts=0, fallback_tokens=0, mean_batch_fill=0.0,
        mean_round_latency=0.0, server_rounds_per_s=0.0,
    )
    m = EngineStats.merge([busy, idle])
    assert m.mean_batch_fill == pytest.approx(4.0)  # idle carries no weight
    assert m.acceptance_rate == pytest.approx(0.9)
    assert m.per_device_rate == pytest.approx(5.0)  # no phantom stream


def test_shared_steps_bundle_mismatch_raises():
    _, _, tm, tp = _models()
    a = ServerEngine(tm, tp, n_slots=2, max_len=64, k_max=4, attn_chunk=32)
    with pytest.raises(ValueError, match="greedy"):
        ServerEngine(tm, tp, n_slots=2, max_len=64, k_max=4, attn_chunk=32,
                     greedy=False, steps=a.steps)
    with pytest.raises(ValueError, match="scratch_slot"):
        ServerEngine(tm, tp, n_slots=3, max_len=64, k_max=4, attn_chunk=32,
                     steps=a.steps)


def test_router_requires_replicas_and_homogeneity():
    with pytest.raises(ValueError):
        Router([])
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("warp")
    _, _, tm, tp = _models()
    a = ServerEngine(tm, tp, n_slots=1, max_len=64, k_max=4, attn_chunk=32)
    b = ServerEngine(tm, tp, n_slots=1, max_len=64, k_max=2, attn_chunk=32)
    with pytest.raises(ValueError, match="homogeneous"):
        Router([a, b])


def test_least_loaded_placement_invariant():
    """Under staggered arrivals with no retirements, least-loaded keeps the
    per-replica load spread within 1 stream after every admission."""
    _, _, tm, tp = _models()
    router = Router.build(tm, tp, replicas=3, n_slots=2, max_len=64, k_max=4,
                          attn_chunk=32)
    prompts = jax.random.randint(jax.random.key(0), (6, 8), 0, V)
    for i in range(6):
        assert router.admit(i, prompts[i], float(i)) is not None
        loads = router.loads()
        assert max(loads) - min(loads) <= 1, f"unbalanced after admit {i}: {loads}"
    assert router.loads() == [2, 2, 2]
    # full cluster refuses further admissions (caller queues + retries)
    assert router.admit(99, prompts[0], 9.0) is None


def test_affinity_and_round_robin_placement():
    _, _, tm, tp = _models()
    prompts = jax.random.randint(jax.random.key(0), (5, 8), 0, V)

    router = Router.build(tm, tp, replicas=2, n_slots=2, max_len=64, k_max=4,
                          attn_chunk=32, placement="affinity",
                          migrate_on_retire=False)
    for i in (0, 2, 1):  # home replica = device_id % 2
        router.admit(i, prompts[i], 0.0)
    assert router.replica_of(0) == 0 and router.replica_of(2) == 0
    assert router.replica_of(1) == 1
    router.admit(4, prompts[4], 1.0)  # home r0 is full -> least-loaded spill
    assert router.replica_of(4) == 1

    rr = Router.build(tm, tp, replicas=2, n_slots=2, max_len=64, k_max=4,
                      attn_chunk=32, placement="round-robin")
    for i in range(4):
        rr.admit(i, prompts[i], 0.0)
    assert [rr.replica_of(i) for i in range(4)] == [0, 1, 0, 1]


# ---------------------------------------------------------------------------
# equivalence: replicas, migration
# ---------------------------------------------------------------------------


def test_router_single_replica_matches_lockstep_reference():
    """replicas=1 is the old single-engine serving loop: token-identical to
    sled_generate under the continuous policy with staggered arrivals."""
    dm, dp, tm, tp = _models()
    B, max_new = 3, 10
    prompts = jax.random.randint(jax.random.key(3), (B, 12), 0, V)
    router = Router.build(tm, tp, replicas=1, n_slots=B, max_len=128, k_max=4,
                          policy="continuous", attn_chunk=32)
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)
    outputs = _drive(router, kit, prompts, max_new=max_new)
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(
        np.array([outputs[i] for i in range(B)]), np.asarray(ref)
    )
    st = router.stats(50.0)
    assert st.streams_served == B and st.replicas == 1


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["deadline", "static"])
def test_router_single_replica_all_policies(policy):
    dm, dp, tm, tp = _models()
    B, max_new = 2, 8
    prompts = jax.random.randint(jax.random.key(4), (B, 12), 0, V)
    router = Router.build(tm, tp, replicas=1, n_slots=B, max_len=128, k_max=4,
                          policy=policy, max_wait=0.0, attn_chunk=32)
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)
    outputs = _drive(router, kit, prompts, max_new=max_new)
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(
        np.array([outputs[i] for i in range(B)]), np.asarray(ref)
    )


def test_migration_on_retire_is_bit_identical():
    """Pile streams onto replica 0 via affinity, retire replica 1's only
    stream early: the router migrates a live stream over (its KV row copied
    bit-exactly), and every stream's output still equals the reference."""
    dm, dp, tm, tp = _models()
    prompts = jax.random.randint(jax.random.key(5), (5, 12), 0, V)
    router = Router.build(tm, tp, replicas=2, n_slots=3, max_len=128, k_max=4,
                          policy="continuous", attn_chunk=32,
                          placement="affinity", migrate_on_retire=True)
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)
    # ids 0/2/4 home onto replica 0 (full), id 1 onto replica 1; stream 1's
    # small budget retires it early -> imbalance [3, 0] -> migration fires
    ids = [0, 2, 4, 1]
    budgets = [12, 4, 12, 12, 12]  # indexed by device id: stream 1 quits early
    n = prompts.shape[0]  # only ids in `ids` are driven
    devices, outputs = {}, {}
    now = 0.0
    for i in ids:
        assert router.admit(i, prompts[i], now) is not None
        devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=100 + i)
    assert router.loads() == [3, 1]
    assert all(router.replica_of(i) == 0 for i in (0, 2, 4))
    migrated_live = set()
    while len(outputs) < len(ids):
        now += 1.0
        for i, dev in devices.items():
            if not dev.awaiting:
                router.submit(i, dev.draft(), now)
        for v in router.step(now) or []:
            dev = devices[v.device_id]
            dev.on_verdict(v)
            if len(dev.committed) >= budgets[v.device_id]:
                outputs[v.device_id] = dev.committed[: budgets[v.device_id]]
                router.retire(v.device_id)
                del devices[v.device_id]
        # catch a stream that now lives on replica 1 while still generating
        migrated_live |= {i for i in (0, 2, 4)
                          if i in devices and router.replica_of(i) == 1}
        assert now < 500, "fleet failed to drain"
    assert router.migrations >= 1, "retirement imbalance must trigger migration"
    assert migrated_live, "a replica-0 stream should keep generating on replica 1"
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=12, k_max=4, c_th=0.3, greedy=True
    )
    for i in ids:
        np.testing.assert_array_equal(
            np.asarray(outputs[i]), np.asarray(ref)[i, : budgets[i]],
            err_msg=f"stream {i} diverged (n={n})",
        )


def test_export_import_stream_moves_row_bit_exactly():
    _, _, tm, tp = _models()
    a = ServerEngine(tm, tp, n_slots=2, max_len=64, k_max=4, attn_chunk=32)
    b = ServerEngine(tm, tp, n_slots=2, max_len=64, k_max=4, attn_chunk=32,
                     steps=a.steps)
    prompt = jax.random.randint(jax.random.key(6), (9,), 0, V)
    a.admit(7, prompt, 0.0)
    stream, row = a.export_stream(7)
    assert 7 not in a.streams and a.pool.n_free == 2
    b.import_stream(stream, row)
    assert b.streams[7].prev_token == stream.prev_token
    got = b.core.export_row(b.streams[7].slot)
    for leaf_name in row:
        np.testing.assert_array_equal(np.asarray(row[leaf_name]),
                                      np.asarray(got[leaf_name]))
    # in-flight requests block migration (the row would change under copy)
    b.submit(7, np.asarray([1, 2], np.int32), 1.0)
    with pytest.raises(ValueError, match="in flight"):
        b.export_stream(7)


# ---------------------------------------------------------------------------
# adaptive k end-to-end (loopback transport, real feedback)
# ---------------------------------------------------------------------------


def test_adaptive_k_fleet_converges_down_on_rejections():
    """With a noisy draft model the AIMD controller must actually move k:
    verdict feedback drives it below k_max, and the proposal lengths on the
    wire respect the adapted cap."""
    from repro.transport.client import EdgeClient
    from repro.transport.server import TransportServer

    dm, dp0, tm, tp = _models()
    dp = perturb_params(dp0, 0.15)  # heavy noise: low acceptance
    k_max, max_new = 4, 12
    prompts = jax.random.randint(jax.random.key(7), (2, 12), 0, V)
    engine = ServerEngine(tm, tp, n_slots=2, max_len=128, k_max=k_max,
                          attn_chunk=32)
    kit = EdgeDeviceKit(dm, dp, k_max=k_max, c_th=0.0, greedy=True, attn_chunk=32)

    async def inner():
        server = TransportServer(engine)
        clients = []
        for i in range(2):
            link = LoopbackLink()
            server.attach(link.server)
            clients.append(
                EdgeClient(kit, i, np.asarray(prompts[i]), link.device,
                           max_new=max_new, max_len=128, pipeline=False,
                           verify_timeout=30.0, kctl="adaptive", seed=i)
            )
        outs = await asyncio.gather(*(c.run() for c in clients))
        await server.stop()
        return outs, clients

    outs, clients = asyncio.run(inner())
    assert all(len(o) == max_new for o in outs)
    assert all(c.kctl is not None and c.kctl.updates > 0 for c in clients)
    assert any(c.stats.k_final < k_max for c in clients), (
        f"low acceptance must shrink k: finals "
        f"{[c.stats.k_final for c in clients]}"
    )
    assert all(1 <= c.stats.k_final <= k_max for c in clients)


def test_edge_device_draft_k_clamp_is_prefix():
    """draft(k=) must return exactly the first k tokens of the unclamped
    greedy round (deterministic prefix property the truncation relies on)."""
    dm, dp, _, _ = _models()
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.0, greedy=True, attn_chunk=32)
    prompt = jax.random.randint(jax.random.key(8), (10,), 0, V)
    full = kit.spawn(0, prompt, max_len=64, seed=1).draft()
    clamped = kit.spawn(0, prompt, max_len=64, seed=1).draft(k=2)
    assert clamped.shape[0] == 2
    np.testing.assert_array_equal(clamped, full[:2])


# ---------------------------------------------------------------------------
# TCP endpoint (real sockets, same codec)
# ---------------------------------------------------------------------------


def test_tcp_endpoint_codec_roundtrip_matches_loopback():
    """Frames over a real TCP socket decode identically to loopback — the
    FrameDecoder reassembles whatever segmentation the kernel produces."""
    msgs = [
        codec.Hello(device_id=1, prompt=np.asarray([5, 6, 7], np.int32)),
        codec.DraftPacket(device_id=1, seq=0, tokens=np.asarray([9, 8], np.int32)),
        codec.Verdict(device_id=1, seq=0, n_accepted=1,
                      tokens=np.asarray([9, 3], np.int32), next_prev=3,
                      accept_rate=0.5, queue_depth=2),
        codec.Fallback(device_id=1, seq=1, tokens=np.asarray([2], np.int32)),
        codec.FallbackAck(device_id=1, seq=1, next_prev=2),
        codec.Close(device_id=1),
    ]

    async def over_tcp():
        accepted = asyncio.Queue()
        server, port = await tcp_listen(lambda ep: accepted.put_nowait(ep))
        client = await tcp_connect("127.0.0.1", port)
        server_ep = await accepted.get()
        got = []
        # client -> server, one frame per send (kernel may merge them)
        for m in msgs:
            await client.send(codec.encode_frame(m))
        for _ in msgs:
            frame = await asyncio.wait_for(server_ep.recv(), 5.0)
            got.append(codec.decode_frame(frame)[0])
        # server -> client in one write burst (split across reads)
        for m in msgs:
            await server_ep.send(codec.encode_frame(m))
        back = []
        for _ in msgs:
            frame = await asyncio.wait_for(client.recv(), 5.0)
            back.append(codec.decode_frame(frame)[0])
        client.close()
        server.close()
        await server.wait_closed()
        assert client.stats.frames_tx == len(msgs)
        assert server_ep.stats.frames_rx == len(msgs)
        return got, back

    async def over_loopback():
        link = LoopbackLink()
        got = []
        for m in msgs:
            await link.device.send(codec.encode_frame(m))
            got.append(codec.decode_frame(await link.server.recv())[0])
        return got

    tcp_got, tcp_back = asyncio.run(over_tcp())
    loop_got = asyncio.run(over_loopback())
    for a, b in zip(tcp_got, loop_got):
        assert type(a) is type(b)
        assert codec.encode_frame(a) == codec.encode_frame(b)
    for a, m in zip(tcp_back, msgs):
        assert codec.encode_frame(a) == codec.encode_frame(m)


def test_tcp_endpoint_recv_none_on_close():
    async def inner():
        accepted = asyncio.Queue()
        server, port = await tcp_listen(accepted.put_nowait)
        client = await tcp_connect("127.0.0.1", port)
        server_ep = await accepted.get()
        await client.send(codec.encode_frame(codec.Close(device_id=4)))
        frame = await asyncio.wait_for(server_ep.recv(), 5.0)
        assert isinstance(codec.decode_frame(frame)[0], codec.Close)
        client.close()
        assert await asyncio.wait_for(server_ep.recv(), 5.0) is None
        server.close()
        await server.wait_closed()

    asyncio.run(inner())


# ---------------------------------------------------------------------------
# SSM/hybrid paged routing fails clean
# ---------------------------------------------------------------------------


def test_ssm_decode_forward_with_slots_raises_cleanly():
    """Routing an SSM model down the slot-indexed path must fail with a
    clear NotImplementedError at the API boundary, not a shape error deep
    in the step (the gather fallback is the supported route)."""
    mcfg = dataclasses.replace(
        get_config("mamba2-370m").reduced(), vocab_size=V, num_layers=2
    )
    mm = build_model(mcfg)
    mp = mm.init_params(jax.random.key(0))
    cache = mm.make_cache(2, 32)
    toks = jax.numpy.zeros((2, 3), jax.numpy.int32)
    with pytest.raises(NotImplementedError, match="gather/scatter fallback"):
        mm.decode_forward(mp, cache, toks, slots=jax.numpy.asarray([0, 1]))
