"""SLED verification-attention kernel: modeled HBM traffic vs the XLA path.

No TPU in this container, so the comparison is structural: we lower the
pure-XLA flash verification attention, walk its HLO with the trip-aware
cost model, and compare bytes moved against the Pallas kernel's analytic
minimum (stream KV exactly once + write O(Sq) output).  Correctness of the
kernel itself is covered by tests/test_kernels.py (interpret-mode sweeps).

``--engine`` lowers THREE verify-step variants for matched bucket shapes
and compares trip-aware HLO bytes:

  dense        lock-step verify over a dense (bucket,)-batched cache — the
               floor continuous batching is measured against
  gather-paged the PR-1 fallback: gather the scheduled pool rows into a
               dense sub-cache, verify, scatter everything back (the
               "paging tax" — still what SSM/hybrid caches pay)
  slot-paged   the slot-indexed fast path: the forward runs directly
               against the pool, attention streams slot-indexed chunks and
               only the K+1 fresh rows are written back
               (verification.make_paged_verify_step(paged_attention=True),
               mirrored on TPU by kernels/verify_attn.verify_attention_paged)

``--json PATH`` records the rows as a BENCH JSON artifact so CI can track
the paging-tax trajectory across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.models.layers import flash_attention
from repro.roofline.hlo_cost import HloCostModel

KV_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "int8": jnp.int8}


def run(quick: bool = False, kv_dtype: str = "bf16") -> list:
    rows = []
    kdt = KV_DTYPES[kv_dtype]
    shapes = [
        (8, 5, 48, 1, 4096, 128),   # granite-34b-like MQA verify
        (8, 5, 32, 4, 4096, 128),   # qwen3-moe-like GQA verify
    ] if not quick else [(4, 5, 8, 1, 1024, 64)]
    for (B, Sq, Hq, Hkv, Skv, D) in shapes:
        q = jax.ShapeDtypeStruct((B, Sq, Hq, D), jnp.bfloat16)
        k = jax.ShapeDtypeStruct((B, Skv, Hkv, D), kdt)
        v = jax.ShapeDtypeStruct((B, Skv, Hkv, D), kdt)
        kv_valid = jax.ShapeDtypeStruct((B,), jnp.int32)

        def xla_path(q, k, v, kv_valid):
            q_pos = kv_valid[:, None] - Sq + jnp.arange(Sq)[None]
            return flash_attention(q, k, v, q_pos=q_pos, kv_valid=kv_valid,
                                   chunk=min(1024, Skv))

        lowered = jax.jit(xla_path).lower(q, k, v, kv_valid)
        costs = HloCostModel(lowered.compile().as_text()).totals()
        # kernel floor: stream K and V exactly once at the CACHE dtype
        # (int8-quantized caches stream half the bf16 bytes), read q + write
        # o once at the activation dtype
        kv_bytes = 2 * B * Skv * Hkv * D * jnp.dtype(kdt).itemsize
        out_bytes = 2 * B * Sq * Hq * D * jnp.dtype(jnp.bfloat16).itemsize
        kernel_min = kv_bytes + out_bytes
        rows.append({
            "shape": f"B{B}xSq{Sq}xHq{Hq}/{Hkv}xS{Skv}xD{D}",
            "kv_dtype": kv_dtype,
            "xla_bytes_mb": round(costs["bytes"] / 1e6, 1),
            "kernel_min_mb": round(kernel_min / 1e6, 1),
            "traffic_ratio": round(costs["bytes"] / kernel_min, 2),
            "mxu_rows_packed": Sq * (Hq // Hkv),
        })
    emit(rows, "verify_kernel")
    return rows


def run_engine(quick: bool = False) -> list:
    """Lower dense vs gather-paged vs slot-indexed-paged verify steps for
    matched bucket shapes and compare trip-aware HLO bytes.  The gather
    variant's surplus is the row gather/scatter paging tax; the slot-indexed
    variant must collapse to ~the dense step's traffic (acceptance: within
    ~1.1x at every bucket size)."""
    from repro.configs.base import get_config
    from repro.core import verification
    from repro.models.model_zoo import build_model

    vocab = 128
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_slots, k_max, max_len = (4, 4, 64) if quick else (8, 4, 128)

    rows = []
    for bucket in ((2,) if quick else (2, 4, 8)):
        pool = model.make_cache(n_slots + 1, max_len, attn_chunk=32)
        dense_cache = model.make_cache(bucket, max_len, attn_chunk=32)
        batch = verification.verify_batch_spec(bucket, k_max)
        batch = {k: jnp.zeros(v.shape, v.dtype) for k, v in batch.items()}
        slots = jnp.arange(bucket, dtype=jnp.int32)

        dense = verification.make_verify_step(model, greedy=True, attn_chunk=32)
        gather = verification.make_paged_verify_step(
            model, scratch_slot=n_slots, greedy=True, attn_chunk=32,
            paged_attention=False,
        )
        paged = verification.make_paged_verify_step(
            model, scratch_slot=n_slots, greedy=True, attn_chunk=32,
            paged_attention=True,
        )
        assert paged.paged_attention and not gather.paged_attention

        def lowered_bytes(fn, *args):
            hlo = jax.jit(fn).lower(*args).compile().as_text()
            return HloCostModel(hlo).totals()["bytes"]

        d_bytes = lowered_bytes(dense, params, dense_cache, batch)
        g_bytes = lowered_bytes(gather, params, pool, slots, batch)
        p_bytes = lowered_bytes(paged, params, pool, slots, batch)
        rows.append({
            "bucket": bucket,
            "pool_slots": n_slots,
            "dense_bytes_mb": round(d_bytes / 1e6, 2),
            "gather_bytes_mb": round(g_bytes / 1e6, 2),
            "slot_bytes_mb": round(p_bytes / 1e6, 2),
            "gather_tax": round(g_bytes / max(d_bytes, 1), 2),
            "slot_tax": round(p_bytes / max(d_bytes, 1), 2),
        })
    emit(rows, "engine_verify_step")
    return rows


def run_bandwidth(quick: bool = False) -> list:
    """Roofline-predicted vs MEASURED verify bandwidth, bf16 vs int8 pools.

    For each pool dtype the slot-indexed paged verify step is lowered (the
    trip-aware HLO byte count is the roofline traffic prediction) and then
    actually run under ``timed`` — the achieved GB/s is predicted bytes over
    measured wall time.  On a bandwidth-bound verify the int8 pool's HLO
    bytes drop to ~the storage ratio while the achieved bandwidth stays in
    the same regime, which is exactly the capacity-per-HBM-byte claim; both
    columns land side by side in the BENCH artifact so CI tracks them.
    """
    from repro.configs.base import get_config
    from repro.core import verification
    from repro.models.kvcache import PagedKVCache
    from repro.models.model_zoo import build_model

    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=128)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    n_slots, k_max, max_len = (4, 4, 64) if quick else (8, 4, 256)
    bucket = 2 if quick else 4

    rows = []
    for kv_dtype in ("bf16", "int8"):
        cache_kw = {"attn_chunk": 32}
        if kv_dtype == "int8":
            cache_kw["kv_dtype"] = jnp.int8
        pool = PagedKVCache(model, n_slots, max_len, **cache_kw)
        step = jax.jit(verification.make_paged_verify_step(
            model, scratch_slot=pool.scratch_slot, greedy=True, attn_chunk=32,
        ))
        batch = verification.verify_batch_spec(bucket, k_max)
        batch = {k: jnp.zeros(v.shape, v.dtype) for k, v in batch.items()}
        slots = jnp.arange(bucket, dtype=jnp.int32)
        hlo = jax.jit(step).lower(params, pool.cache, slots, batch).compile().as_text()
        pred_bytes = HloCostModel(hlo).totals()["bytes"]

        def run_step(c):
            res, c2 = step(params, pool.cache, slots, batch)
            jax.block_until_ready(c2["length"])
            return c2

        _, dt = timed(run_step, pool.cache, warmup=2, iters=5)
        rows.append({
            "kv_dtype": kv_dtype,
            "bucket": bucket,
            "pool_bytes": pool.pool_bytes(),
            "bytes_per_slot": pool.bytes_per_slot(),
            "roofline_bytes_mb": round(pred_bytes / 1e6, 2),
            "us_per_call": round(dt * 1e6, 1),
            "achieved_gbs": round(pred_bytes / max(dt, 1e-9) / 1e9, 3),
        })
    bf16, int8 = rows
    rows.append({
        "kv_dtype": "int8/bf16",
        "bytes_per_slot_ratio": round(bf16["bytes_per_slot"] / int8["bytes_per_slot"], 2),
        "roofline_bytes_ratio": round(
            int8["roofline_bytes_mb"] / max(bf16["roofline_bytes_mb"], 1e-9), 2
        ),
        "time_ratio": round(int8["us_per_call"] / max(bf16["us_per_call"], 1e-9), 2),
    })
    emit([dict(r) for r in rows], "verify_bandwidth")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="compare dense/gather-paged/slot-paged verify-step HLO traffic")
    ap.add_argument("--bandwidth", action="store_true",
                    help="roofline-predicted vs measured verify bandwidth, bf16 vs int8 pools")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--kv-dtype", choices=sorted(KV_DTYPES), default="bf16",
                    help="cache dtype for the kernel-vs-XLA comparison "
                         "(kernel_min derives from it — int8 halves the floor)")
    ap.add_argument("--json", type=str, default="",
                    help="also write the rows as a BENCH JSON artifact")
    a = ap.parse_args()
    if a.engine:
        rows = run_engine(quick=a.quick)
        name = "engine_verify_step"
    elif a.bandwidth:
        rows = run_bandwidth(quick=a.quick)
        name = "verify_bandwidth"
    else:
        rows = run(quick=a.quick, kv_dtype=a.kv_dtype)
        name = "verify_kernel"
    if a.json:
        with open(a.json, "w") as f:
            json.dump({"benchmark": name, "quick": a.quick, "rows": rows}, f, indent=2)
        print(f"wrote {a.json}")


if __name__ == "__main__":
    main()
