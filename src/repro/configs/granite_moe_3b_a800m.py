"""granite-moe-3b-a800m [moe]: 40 experts, top-8.

32L d_model=1536 24H (kv=8) d_ff=512/expert vocab=49155  [hf:ibm-granite]
"""
from repro.configs.base import ModelConfig, register

GRANITE_MOE = register(
    ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        num_experts=40,
        experts_per_token=8,
        moe_d_ff=512,
        act="swiglu",
        tie_embeddings=True,
        notes="40 experts not divisible by 16: GSPMD pads expert axis shards",
    )
)
