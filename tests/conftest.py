"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
only launch/dryrun.py (and tests that spawn their own debug mesh via
xla_force_host_platform_device_count in a subprocess) use more."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
