"""qwen2-1.5b [dense]: GQA kv=2, QKV bias.

28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936  [arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig, register

QWEN2_1_5B = register(
    ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        act="swiglu",
        tie_embeddings=True,
    )
)
