"""Admission control + stream lifecycle for one verification replica.

The middle layer of the serving stack: :class:`AdmissionControl` owns the
per-stream server state (DeviceStream registry), the request queue discipline
(one in-flight round per device, duplicate/cancel arbitration), and the
:class:`~repro.core.scheduler.BatchPlanner` that decides *when* queued
requests dispatch.  It never touches model state — the engine core
(core/engine.py) owns the pool and the compute; core/server_engine.py
composes the two into the single-replica ``ServerEngine``, and
cluster/router.py places streams across many of them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro import telemetry
from repro.core.scheduler import BatchPlanner, PlannedBatch, VerifyRequest


@dataclasses.dataclass
class DeviceStream:
    """Server-side state of one admitted device stream."""

    device_id: int
    slot: int
    prev_token: int
    committed: List[int] = dataclasses.field(default_factory=list)
    admitted_at: float = 0.0
    rounds: int = 0
    drafted: int = 0  # lifetime draft tokens verified for this stream
    accepted: int = 0  # lifetime accepted draft tokens

    @property
    def accept_rate(self) -> float:
        """Lifetime acceptance ratio (stats/diagnostics; verdict feedback
        carries the per-round rate so the control loop stays responsive)."""
        return self.accepted / max(self.drafted, 1)


class AdmissionControl:
    """Stream registry + request queue for one replica.

    Invariants enforced here (they used to live inline in ServerEngine):

      * a device has at most ONE queued (unverdicted) request — a second
        would put the same cache row twice in one verify batch;
      * retiring or cancelling a device purges its queued request;
      * straggler-evicted requests from still-active streams are requeued
        with a fresh arrival (in-process drivers never abandon a round —
        transport clients instead cancel + force-extend on timeout).
    """

    def __init__(
        self,
        *,
        batch_size: int,
        k_max: int,
        policy: str = "continuous",
        max_wait: float = 0.050,
        straggler_timeout: float = 1.0,
        greedy: bool = True,
    ):
        self.planner = BatchPlanner(
            batch_size=batch_size,
            k_max=k_max,
            policy=policy,
            max_wait=max_wait,
            straggler_timeout=straggler_timeout,
        )
        self.batch_cap = batch_size
        self.greedy = greedy
        self.streams: Dict[int, DeviceStream] = {}
        self.timeouts = 0
        self.streams_served = 0
        self._inflight: set = set()
        self._req_id = 0

    # -- stream lifecycle ----------------------------------------------------

    def register(self, device_id: int, slot: int, prev_token: int, now: float) -> DeviceStream:
        if device_id in self.streams:
            raise ValueError(f"device {device_id} already admitted")
        stream = DeviceStream(device_id, slot, prev_token, admitted_at=now)
        self.streams[device_id] = stream
        return stream

    def adopt(self, stream: DeviceStream) -> None:
        """Take over a stream migrated from another replica (slot already
        rewritten by the caller); its history rides along untouched."""
        if stream.device_id in self.streams:
            raise ValueError(f"device {stream.device_id} already admitted")
        self.streams[stream.device_id] = stream

    def release(self, device_id: int, *, served: bool = True) -> DeviceStream:
        """Drop the stream (retire or migrate away); purges any queued
        request.  ``served=False`` (migration) skips the served counter."""
        stream = self.streams.pop(device_id)
        if device_id in self._inflight:
            self.planner.queue = type(self.planner.queue)(
                r for r in self.planner.queue if r.device_id != device_id
            )
            self._inflight.discard(device_id)
        if served:
            self.streams_served += 1
        return stream

    # -- request queue -------------------------------------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        stream = self.streams[device_id]
        if device_id in self._inflight:
            # a second in-flight request would put the same cache row twice
            # in one scatter (undefined winner) — the device must wait for
            # its verdict (EdgeDevice.awaiting mirrors this server-side)
            raise ValueError(f"device {device_id} already has a request in flight")
        if not self.greedy and draft_q is None:
            raise ValueError("sampling mode needs per-request draft_q")
        if self.greedy:
            # greedy verification ignores q — and feeding it anyway would
            # change the jitted verify batch's pytree structure and recompile
            # every bucket behind warmup()'s back
            draft_q = None
        self.planner.add(
            VerifyRequest(
                device_id=device_id,
                arrival=now,
                prev_token=stream.prev_token,
                draft_tokens=np.asarray(draft_tokens),
                draft_q=draft_q,
                request_id=self._req_id,
            )
        )
        self._inflight.add(device_id)
        self._req_id += 1

    def cancel(self, device_id: int) -> bool:
        """Withdraw the device's queued request (transport fallback protocol).
        Returns False when nothing is queued — the round already verified and
        the verdict is authoritative."""
        if device_id not in self._inflight:
            return False
        self.planner.queue = type(self.planner.queue)(
            r for r in self.planner.queue if r.device_id != device_id
        )
        self._inflight.discard(device_id)
        return True

    def resolve(self, device_id: int) -> None:
        """The device's request left the queue inside a dispatched batch."""
        self._inflight.discard(device_id)

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._inflight

    @property
    def queue_depth(self) -> int:
        return len(self.planner.queue)

    # -- dispatch ------------------------------------------------------------

    def next_batch(self, now: float) -> Optional[PlannedBatch]:
        """Ask the planner for a batch, capped at the active stream count.

        The closed-loop cap mirrors the simulator's eff_batch: never wait
        for more requests than there are active streams, otherwise the
        static policy deadlocks as soon as the first stream retires.
        Straggler-evicted requests from live streams are requeued.
        """
        self.planner.batch_size = max(1, min(self.batch_cap, len(self.streams) or 1))
        batch = self.planner.next_batch(now, server_idle=True)
        if batch is not None and telemetry.enabled():
            for req in batch.requests:
                telemetry.observe("admission_queue_wait_seconds", now - req.arrival)
            telemetry.registry().gauge("admission_queue_depth").set(self.queue_depth)
        if self.planner.dropped:
            for req in self.planner.dropped:
                if req.device_id in self.streams:
                    self.timeouts += 1
                    req.arrival = now
                    self.planner.add(req)
                else:
                    self._inflight.discard(req.device_id)
            self.planner.dropped = []
        return batch

    def next_event_hint(self, now: float) -> Optional[float]:
        return self.planner.next_event_hint(now)
