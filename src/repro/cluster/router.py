"""Cluster router: replica-sharded verification behind one serving surface.

SLED's capacity story (paper Table I) is one shared target model serving many
heterogeneous drafters; at production scale that target tier is N engine
replicas behind a placement layer, not one engine object.  The
:class:`Router` owns N replicas and turns admission into a placement
decision:

  * **placement** — a pluggable :class:`PlacementPolicy` (BatchPlanner-style
    registry: ``least-loaded`` / ``affinity`` / ``round-robin``) picks the
    replica for each new stream among live replicas with a free pool slot;
  * **migration** — when a stream retires and frees a slot, the router may
    migrate an active stream over from the most-loaded replica
    (``migrate_on_retire``).  A migrated KV row is copied bit-exactly
    (``export_stream``/``import_stream``), so migration never changes a
    stream's tokens — only which replica's batches it rides in;
  * **aggregation** — cluster stats are ``EngineStats.merge`` over live
    replicas, and verdicts carry replica-local queue-depth feedback.

Replicas come in two flavors behind one driver surface:

  :class:`LocalReplica`   — wraps an in-process
      :class:`~repro.core.server_engine.ServerEngine`; fleets share one
      jitted VerifySteps bundle, so N replicas cost one XLA compilation.
  RemoteReplica (cluster/remote.py) — proxies the same surface to a
      ``repro worker`` process over codec v3 control frames; the Router
      steps its remotes CONCURRENTLY on a thread pool (each worker verifies
      in its own process, so cluster throughput scales with processes), and
      a transport failure mid-RPC evicts the replica (``_evict``) rather
      than stalling the fleet.

Migration is flavor-guarded: local<->local moves copy the row in memory;
remote<->remote moves ride ExportStream/ImportStream frames (both workers
rebuilt params from the same spec seed, so the row stays bit-valid); a
MIXED local<->remote move raises :class:`MigrationError`, because the two
sides' parameters have different provenance (in-process object vs
spec-seed rebuild) and bit-identity across the move cannot be verified.

The router mirrors the full ServerEngine driver surface (admit / submit /
step / retire / cancel_request / force_extend / stats / warmup), so the
transport server and the in-process serving loops drive a replica fleet by
holding a Router where they held an engine.

**Supervision** is governed by a :class:`~repro.api.spec.FaultPolicy`
(default: today's evict-only behavior).  With ``respawn`` on, an evicted
replica is revived in place — respawn the worker (or redial a dial-only
address), re-place its spec, re-warmup — under a capped, seeded-jitter
:class:`~repro.cluster.faults.Backoff` and a ``max_respawns`` budget; dead
replicas are also redialed periodically from the step loop, and all-dead
becomes retry-until-``all_dead_deadline_s`` instead of instantly fatal.
With ``recover_streams`` on, the streams that went down with a replica are
re-admitted to a surviving (or freshly revived) replica by DEVICE REPLAY:
the router shadows each stream's prompt, committed tokens, and last
unanswered submit, so recovery is admit + chunked ``force_extend`` of the
committed history (runs of <= k_max+1) + re-submit — greedy continuation
stays token-identical to the fault-free run.  Only streams that exceed the
surviving capacity are shed into ``lost_devices``.  A ``heartbeat_interval_s``
 > 0 starts a background Ping monitor that marks silent peers ``suspect``
within seconds instead of waiting out the 120 s control-RPC timeout.
"""

from __future__ import annotations

import logging
import threading
import time
from collections.abc import Mapping
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from repro import telemetry
from repro.cluster.faults import Backoff
from repro.core.admission import DeviceStream
from repro.core.engine import EngineStats, Verdict
from repro.core.server_engine import ServerEngine

log = logging.getLogger(__name__)


class MigrationError(RuntimeError):
    """A stream move that cannot preserve bit-identity was requested."""


class LocalReplica:
    """In-process replica: a ServerEngine behind the replica driver surface.

    Everything not listed here (admit/submit/step/...) delegates straight to
    the engine; the explicit members are the bits the Router needs uniform
    across flavors (liveness, capacity, fingerprint, lifecycle).
    """

    flavor = "local"

    def __init__(self, engine: ServerEngine):
        self.engine = engine
        self.dead = False
        self.suspect = False
        self._killed = False  # chaos: delegated calls fail like a dead worker

    @property
    def n_free(self) -> int:
        return self.engine.pool.n_free

    @property
    def max_len(self) -> int:
        return self.engine.pool.max_len

    @property
    def fingerprint(self) -> tuple:
        e = self.engine
        # kv_dtype is part of the identity: an int8 row scattered into a
        # bf16 pool (or vice versa) would silently cast and corrupt the cache
        return (e.k_max, e.pool.max_len, e.greedy, e.paged_attention, e.kv_dtype)

    def chaos_kill(self) -> None:
        """Fault injection: every delegated call now raises ConnectionError,
        which is exactly what a crashed worker looks like to the Router —
        the in-process path exercises the same evict/recover machinery."""
        self._killed = True

    def can_revive(self) -> bool:
        return self._killed  # only a chaos-killed local can come back

    def revive(self) -> None:
        """Undo a chaos kill: the engine object was never actually broken,
        so revival is clearing the flag and retiring the dead incarnation's
        streams (a real respawn starts with an empty pool too)."""
        self._killed = False
        for dev in list(self.engine.streams):
            try:
                self.engine.cancel_request(dev)
            except Exception:
                pass
            try:
                self.engine.retire(dev)
            except Exception:
                pass
        self.dead = False
        self.suspect = False

    def drain(self) -> None:  # lifecycle parity with RemoteReplica
        pass

    def close(self) -> None:
        pass

    def __getattr__(self, name: str):
        if self.__dict__.get("_killed"):
            raise ConnectionError(f"local replica is chaos-killed ({name!r})")
        return getattr(self.engine, name)


class PlacementPolicy:
    """Chooses the replica for a new stream; None when every pool is full."""

    name = "base"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        raise NotImplementedError

    @staticmethod
    def _open(router: "Router") -> List[int]:
        return [
            i for i, r in enumerate(router.replicas) if not r.dead and r.n_free > 0
        ]


class LeastLoadedPlacement(PlacementPolicy):
    """Fewest active streams wins (ties break toward the lowest replica id):
    keeps per-replica batch fill even under staggered arrivals."""

    name = "least-loaded"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        open_ = self._open(router)
        if not open_:
            return None
        return min(open_, key=lambda i: (len(router.replicas[i].streams), i))


class AffinityPlacement(PlacementPolicy):
    """Deterministic device->replica hash (session/cache affinity); falls
    over to least-loaded when the home replica is full or gone."""

    name = "affinity"

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        home = device_id % len(router.replicas)
        r = router.replicas[home]
        if not r.dead and r.n_free > 0:
            return home
        return LeastLoadedPlacement().choose(router, device_id)


class RoundRobinPlacement(PlacementPolicy):
    """Cycle through replicas, skipping full pools and dead replicas."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        n = len(router.replicas)
        for off in range(n):
            i = (self._next + off) % n
            r = router.replicas[i]
            if not r.dead and r.n_free > 0:
                self._next = i + 1
                return i
        return None


class ClassAffinityPlacement(PlacementPolicy):
    """Home a heterogeneous fleet's device CLASSES on replicas (class i ->
    replica i % n): same-class streams share a verify batch, so each
    replica's rounds keep one (k, c_th, draft-model) shape instead of
    interleaving a Jetson's 4-token rounds with an RPi's singletons.  Spills
    to least-loaded when the home replica is full or dead.

    ``class_of`` maps device_id -> class index; System supplies it from the
    fleet spec.  Without a map (bare Router construction) it degrades to
    per-device affinity.
    """

    name = "class-affinity"

    def __init__(self, class_of: Optional[Callable[[int], int]] = None) -> None:
        self.class_of = class_of

    def choose(self, router: "Router", device_id: int) -> Optional[int]:
        cls = self.class_of(device_id) if self.class_of is not None else device_id
        home = cls % len(router.replicas)
        r = router.replicas[home]
        if not r.dead and r.n_free > 0:
            return home
        return LeastLoadedPlacement().choose(router, device_id)


PLACEMENT_POLICIES = {
    p.name: p for p in (
        LeastLoadedPlacement, AffinityPlacement, RoundRobinPlacement,
        ClassAffinityPlacement,
    )
}


def make_placement(policy: str) -> PlacementPolicy:
    if policy not in PLACEMENT_POLICIES:
        raise ValueError(
            f"unknown placement policy {policy!r} (one of {sorted(PLACEMENT_POLICIES)})"
        )
    return PLACEMENT_POLICIES[policy]()


class _StreamView(Mapping):
    """Read-only dict-like view over every replica's streams.

    Membership and lookup go through the router's placement map (O(1) per
    frame in the transport hot path) instead of merging N dicts per access.
    """

    def __init__(self, router: "Router"):
        self._router = router

    def __contains__(self, device_id) -> bool:
        return device_id in self._router._where

    def __getitem__(self, device_id) -> DeviceStream:
        return self._router._replica(device_id).streams[device_id]

    def __iter__(self) -> Iterator[int]:
        return iter(self._router._where)

    def __len__(self) -> int:
        return len(self._router._where)


class Router:
    """N replicas (local and/or remote) + placement: the cluster object."""

    def __init__(
        self,
        replicas: Sequence[Any],
        *,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
        faults: Optional[Any] = None,
    ):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        wrapped = [
            LocalReplica(r) if isinstance(r, ServerEngine) else r for r in replicas
        ]
        k_maxes = {r.k_max for r in wrapped}
        max_lens = {r.max_len for r in wrapped}
        if len(k_maxes) > 1 or len(max_lens) > 1:
            raise ValueError(
                f"replicas must be homogeneous for migration: k_max {k_maxes}, "
                f"max_len {max_lens}"
            )
        if faults is None:
            from repro.api.spec import FaultPolicy  # lazy: api sits above cluster

            faults = FaultPolicy()
        self.replicas: List[Any] = wrapped
        self.placement = (
            placement if isinstance(placement, PlacementPolicy) else make_placement(placement)
        )
        self.migrate_on_retire = migrate_on_retire
        self.faults = faults
        self.chaos: Optional[Any] = None  # ChaosInjector, attached by System/tests
        self.migrations = 0
        self.evictions = 0
        self.respawns = 0
        self.recovered_streams = 0
        self.shed_streams = 0
        self.steps_taken = 0  # cluster step counter (chaos schedule clock)
        self.lost_devices: List[int] = []  # streams shed with evicted replicas
        self._where: Dict[int, int] = {}  # device_id -> replica index
        self._pool: Optional[ThreadPoolExecutor] = None  # remote step fan-out
        # router-side shadow flight recorders, one ring per replica: fed from
        # the verdicts the router itself merges, so a post-mortem survives a
        # worker process that died without answering another RPC
        self.flight: Dict[int, telemetry.FlightRecorder] = {
            i: telemetry.FlightRecorder() for i in range(len(wrapped))
        }
        self.flight_dumps: Dict[int, List[dict]] = {}  # idx -> dump at eviction
        self._round_seq: Dict[int, int] = {}  # device_id -> round seq
        self._last_k: Dict[int, int] = {}  # device_id -> last submitted len
        # device-replay shadows: everything needed to rebuild a stream on
        # another replica after its worker dies (prompt + committed history +
        # the round that was in flight, if any)
        self._prompts: Dict[int, np.ndarray] = {}
        self._admit_now: Dict[int, float] = {}
        self._committed: Dict[int, List[int]] = {}
        self._last_submit: Dict[int, Tuple] = {}  # dev -> (tokens, now, draft_q)
        # respawn bookkeeping
        self._backoff: Dict[int, Backoff] = {}
        self._respawn_count: Dict[int, int] = {}
        self._redial_at: Dict[int, float] = {}
        self._hb: Optional[_HeartbeatMonitor] = None

    @classmethod
    def build(
        cls,
        model: Any,
        params: Any,
        *,
        replicas: int,
        n_slots: int,
        placement: str | PlacementPolicy = "least-loaded",
        migrate_on_retire: bool = True,
        faults: Optional[Any] = None,
        **engine_kw,
    ) -> "Router":
        """N homogeneous in-process replicas (``n_slots`` rows each) sharing
        one jitted VerifySteps bundle — the fleet compiles once.  Pass
        ``steps=`` to share an ALREADY-compiled bundle from another
        homogeneous fleet (spec sweeps build every replica count on the same
        executables).  Remote fleets are assembled by repro.api's
        System.build instead (spawn/dial + PlaceReplica, then ``Router``)."""
        if replicas < 1:
            raise ValueError(f"need at least 1 replica, got {replicas}")
        steps = engine_kw.pop("steps", None)
        first = ServerEngine(model, params, n_slots=n_slots, steps=steps, **engine_kw)
        rest = [
            ServerEngine(model, params, n_slots=n_slots, steps=first.steps, **engine_kw)
            for _ in range(replicas - 1)
        ]
        return cls(
            [first, *rest],
            placement=placement,
            migrate_on_retire=migrate_on_retire,
            faults=faults,
        )

    # -- introspection -------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def alive(self) -> List[Any]:
        return [r for r in self.replicas if not r.dead]

    @property
    def k_max(self) -> int:
        return self.replicas[0].k_max

    @property
    def paged_attention(self) -> bool:
        return self.replicas[0].paged_attention

    @property
    def streams(self) -> Mapping:
        """Lazy device->stream mapping across replicas (read-only): O(1)
        membership/lookup via the placement map, no per-access dict merge."""
        return _StreamView(self)

    @property
    def queue_depth(self) -> int:
        return sum(r.queue_depth for r in self.alive)

    @property
    def n_free(self) -> int:
        return sum(r.n_free for r in self.alive)

    def replica_of(self, device_id: int) -> int:
        return self._where[device_id]

    def loads(self) -> List[int]:
        """Active stream count per replica (placement test surface)."""
        out = []
        for r in self.replicas:
            try:
                out.append(len(r.streams))
            except ConnectionError:  # chaos-killed local: unreachable engine
                out.append(0)
        return out

    def _replica(self, device_id: int):
        return self.replicas[self._where[device_id]]

    # -- supervision ---------------------------------------------------------

    def _evict(self, idx: int) -> None:
        """A replica's worker is unreachable: mark it dead, harvest the
        streams that went down with it, and keep serving on the survivors.
        Under the default FaultPolicy that is the whole story (a one-shot
        RPC retry happens below this layer, guarded by the worker's v4
        replay cache); with ``respawn``/``recover_streams`` on, the replica
        is revived in place and its streams are re-placed by device replay —
        only what exceeds the surviving capacity is shed."""
        replica = self.replicas[idx]
        if replica.dead:
            return
        replica.dead = True
        lost = [d for d, i in self._where.items() if i == idx]
        for d in lost:
            del self._where[d]
        self.evictions += 1
        # the worker may be gone without a goodbye: dump the router-side
        # shadow ring so the loss report carries the replica's last N rounds
        dump = self.flight[idx].dump()
        self.flight_dumps[idx] = dump
        log.warning(
            "evicting replica %d (%s): streams down %s; flight recorder "
            "holds %d round(s)",
            idx, getattr(replica, "flavor", "local"), lost, len(dump),
        )
        for row in dump[-8:]:
            log.warning("  flight[replica %d]: %s", idx, row)
        telemetry.count("router_evictions_total")
        replica.close()
        if self.faults.respawn or self.faults.recover_streams:
            recovered = self._recover(idx, lost)
            lost = [d for d in lost if d not in recovered]
        for d in lost:
            self._shed(d)
        if not self.alive:
            raise RuntimeError(
                f"all {len(self.replicas)} replicas evicted; cluster has no capacity"
            )

    def _shed(self, dev: int) -> None:
        """Give up on one stream: record the loss and drop its shadows."""
        self.lost_devices.append(dev)
        self.shed_streams += 1
        for shadow in (
            self._prompts, self._admit_now, self._committed,
            self._last_submit, self._round_seq, self._last_k,
        ):
            shadow.pop(dev, None)
        telemetry.count("router_shed_streams_total")

    # -- recovery: respawn + device replay ------------------------------------

    def _recover(self, idx: int, lost: List[int]) -> Set[int]:
        """Post-eviction recovery: revive the dead replica (policy
        permitting), then re-place each lost stream by device replay.
        Returns the devices that made it back."""
        p = self.faults
        if p.respawn:
            self._try_revive(idx)
        if not self.alive:
            if p.respawn:
                self._revive_until_deadline()  # raises when the fleet is gone
            else:
                return set()
        if not p.recover_streams or not lost:
            return set()
        recovered: Set[int] = set()
        with telemetry.span("router_recovery_seconds"):
            for dev in lost:
                if self._readmit(dev):
                    recovered.add(dev)
                    self.recovered_streams += 1
                    telemetry.count("router_recovered_streams_total")
                else:
                    log.warning("device %d could not be re-placed; shedding", dev)
        log.info(
            "recovered %d/%d stream(s) after evicting replica %d",
            len(recovered), len(lost), idx,
        )
        return recovered

    def _readmit(self, dev: int) -> bool:
        """Re-place one orphaned stream by DEVICE REPLAY: admit the original
        prompt, force_extend the committed history in runs of <= k_max+1
        (the engine's fallback-run ceiling), then re-submit the round that
        was in flight.  The rebuilt engine state matches the fault-free
        stream exactly, so greedy continuation is token-identical."""
        prompt = self._prompts.get(dev)
        if prompt is None:
            return False
        committed = list(self._committed.get(dev, ()))
        stream = self.admit(dev, prompt, self._admit_now.get(dev, 0.0))
        if stream is None:
            return False  # every surviving pool is full: shed
        run = self.k_max + 1
        try:
            idx = self._where[dev]
            for i in range(0, len(committed), run):
                chunk = np.asarray(committed[i : i + run], np.int32)
                with self._guard(idx):
                    self.replicas[idx].force_extend(dev, chunk)
            pending = self._last_submit.get(dev)
            if pending is not None:
                tokens, t_sub, draft_q = pending
                with self._guard(self._where[dev]):
                    self.replicas[self._where[dev]].submit(
                        dev, tokens, t_sub, draft_q=draft_q
                    )
        except ConnectionError:
            # the target died mid-replay; ITS eviction recursed into
            # recovery, so the stream is either fully re-placed or lost
            return dev in self._where
        return True

    def _try_revive(self, idx: int, *, wait: bool = True) -> bool:
        """One supervised revive attempt: seeded-jitter backoff (skipped on
        the periodic-redial path, which is paced by ``redial_interval_s``),
        a ``max_respawns`` budget, and the replica's own revive() doing the
        respawn-or-redial + re-place + re-warmup."""
        replica = self.replicas[idx]
        if not replica.dead:
            return True
        if not getattr(replica, "can_revive", lambda: False)():
            return False
        p = self.faults
        n = self._respawn_count.get(idx, 0)
        if n >= p.max_respawns:
            return False
        bo = self._backoff.get(idx)
        if bo is None:
            bo = self._backoff[idx] = Backoff(
                p.backoff_base_s, p.backoff_max_s, p.backoff_jitter, seed=idx
            )
        if wait:
            time.sleep(bo.attempt())
        self._respawn_count[idx] = n + 1
        try:
            with telemetry.span("router_respawn_seconds"):
                replica.revive()
        except Exception as e:
            log.warning(
                "revive of replica %d failed (attempt %d/%d): %s",
                idx, n + 1, p.max_respawns, e,
            )
            return False
        bo.reset()
        self.respawns += 1
        telemetry.count("router_respawns_total")
        log.info("replica %d revived (respawn %d/%d)", idx, n + 1, p.max_respawns)
        return True

    def _revive_until_deadline(self) -> None:
        """Every replica is dead but respawn is on: keep trying to bring one
        back until ``all_dead_deadline_s`` runs out, then raise."""
        p = self.faults
        deadline = time.monotonic() + p.all_dead_deadline_s
        while time.monotonic() < deadline:
            eligible = [
                i
                for i, r in enumerate(self.replicas)
                if r.dead
                and getattr(r, "can_revive", lambda: False)()
                and self._respawn_count.get(i, 0) < p.max_respawns
            ]
            if not eligible:
                break
            for i in eligible:
                if self._try_revive(i):
                    return
        raise RuntimeError(
            f"all {len(self.replicas)} replicas evicted and none revived within "
            f"{p.all_dead_deadline_s:.1f}s; cluster has no capacity"
        )

    def _maybe_redial(self) -> None:
        """Step-loop supervision tick: periodically retry dead replicas that
        can come back (dial-only peers whose partition may have healed,
        spawned workers under their respawn budget)."""
        if not self.faults.respawn:
            return
        t = time.monotonic()
        for i, r in enumerate(self.replicas):
            if not r.dead:
                continue
            if not getattr(r, "can_revive", lambda: False)():
                continue
            if self._respawn_count.get(i, 0) >= self.faults.max_respawns:
                continue
            if t < self._redial_at.get(i, 0.0):
                continue
            self._redial_at[i] = t + self.faults.redial_interval_s
            self._try_revive(i, wait=False)

    def _check_suspects(self) -> None:
        """Evict replicas the heartbeat monitor marked suspect (they stopped
        answering Pings); eviction runs the normal recovery path."""
        for i, r in enumerate(self.replicas):
            if not r.dead and getattr(r, "suspect", False):
                log.warning("replica %d failed heartbeat; evicting", i)
                self._evict(i)

    def _guard(self, idx: int):
        """Context for one replica RPC: ReplicaGone -> evict, re-raised so
        the caller can decide whether the operation is retryable."""
        return _EvictOnGone(self, idx)

    # -- admission as placement ----------------------------------------------

    def admit(self, device_id: int, prompt: jax.Array, now: float = 0.0) -> Optional[DeviceStream]:
        """Place the stream on a replica chosen by the policy; None when
        every live replica's pool is full (caller queues and retries on
        retire).  Admission IS retried after an eviction — the worker dying
        before acking means the stream was never placed anywhere."""
        if device_id in self._where:
            raise ValueError(f"device {device_id} already admitted")
        while True:
            idx = self.placement.choose(self, device_id)
            if idx is None:
                return None
            try:
                with telemetry.span("router_place_seconds"):
                    stream = self.replicas[idx].admit(device_id, prompt, now)
            except ConnectionError:
                self._evict(idx)
                continue  # re-place on the survivors
            if stream is None:  # policy raced a concurrent admit; treat as full
                return None
            self._where[device_id] = idx
            self._prompts[device_id] = np.asarray(prompt, np.int32).reshape(-1)
            self._admit_now[device_id] = now
            self._committed.setdefault(device_id, [])
            log.info(
                "placed device %d on replica %d (%s, %d free slot(s) left)",
                device_id, idx, self.replicas[idx].flavor, self.replicas[idx].n_free,
            )
            return stream

    def retire(self, device_id: int) -> DeviceStream:
        idx = self._where.pop(device_id)
        for shadow in (
            self._round_seq, self._last_k, self._prompts,
            self._admit_now, self._committed, self._last_submit,
        ):
            shadow.pop(device_id, None)
        with self._guard(idx):
            stream = self.replicas[idx].retire(device_id)
        if self.migrate_on_retire:
            self._rebalance_into(idx)
        return stream

    def migrate(self, device_id: int, dst: int) -> None:
        """Move a quiescent stream to replica ``dst`` bit-identically: the
        KV row is copied exactly between same-flavor replicas with matching
        fingerprints, so the stream's future tokens are unchanged — only its
        batch-mates are.  Local->local moves share params by object; a
        remote->remote move is valid because both workers rebuilt params
        from the same spec seed.  Mixed flavors raise MigrationError."""
        src = self._where[device_id]
        if src == dst:
            return
        src_r, dst_r = self.replicas[src], self.replicas[dst]
        if dst_r.dead:
            raise MigrationError(f"replica {dst} was evicted; cannot migrate into it")
        if src_r.flavor != dst_r.flavor:
            raise MigrationError(
                f"cannot migrate device {device_id} from {src_r.flavor} replica "
                f"{src} to {dst_r.flavor} replica {dst}: parameters on the two "
                f"sides have different provenance (in-process object vs worker "
                f"spec-seed rebuild), so bit-identity across the move cannot be "
                f"guaranteed"
            )
        if src_r.fingerprint != dst_r.fingerprint:
            raise MigrationError(
                f"replica fingerprints differ ({src_r.fingerprint} vs "
                f"{dst_r.fingerprint}); migration would change the stream's tokens"
            )
        with telemetry.span("router_migrate_seconds"):
            with self._guard(src):
                stream, row = src_r.export_stream(device_id)
            try:
                with self._guard(dst):
                    dst_r.import_stream(stream, row)
            except ConnectionError:
                # dst died mid-import: put the stream back where it came from
                src_r.import_stream(stream, row)
                self._where[device_id] = src
                raise
            except Exception:
                # roll back: the stream must never be lost mid-migration
                src_r.import_stream(stream, row)
                raise
        self._where[device_id] = dst
        self.migrations += 1
        telemetry.count("router_migrations_total")
        log.info("migrated device %d: replica %d -> %d", device_id, src, dst)

    def _rebalance_into(self, dst: int) -> None:
        """After a retirement freed a slot on ``dst``: pull one quiescent
        SAME-FLAVOR stream over from the most-loaded replica when the
        imbalance is ≥2 (moving one stream then strictly improves balance)."""
        dst_r = self.replicas[dst]
        if dst_r.dead or dst_r.n_free == 0:
            return
        loads = self.loads()
        candidates = [
            i
            for i, r in enumerate(self.replicas)
            if i != dst and not r.dead and r.flavor == dst_r.flavor
        ]
        if not candidates:
            return
        src = max(candidates, key=lambda i: (loads[i], -i))
        if loads[src] - loads[dst] < 2:
            return
        replica = self.replicas[src]
        movable = [d for d in replica.streams if not replica.has_inflight(d)]
        if not movable:
            return
        self.migrate(movable[0], dst)

    # -- request path (delegated via placement map) --------------------------

    def submit(
        self,
        device_id: int,
        draft_tokens: np.ndarray,
        now: float,
        draft_q: Optional[np.ndarray] = None,
    ) -> None:
        tokens = np.asarray(draft_tokens)
        self._last_k[device_id] = int(tokens.shape[0])
        self._last_submit[device_id] = (tokens, now, draft_q)
        idx = self._where[device_id]
        try:
            self.replicas[idx].submit(device_id, tokens, now, draft_q=draft_q)
        except ConnectionError:
            self._evict(idx)
            if device_id not in self._where:
                raise  # the stream was shed with the replica
            # recovery re-placed the stream AND re-submitted this round (it
            # was already in _last_submit), so the caller's submit succeeded

    def cancel_request(self, device_id: int) -> bool:
        idx = self._where[device_id]
        try:
            ok = self.replicas[idx].cancel_request(device_id)
        except ConnectionError:
            self._evict(idx)
            if device_id not in self._where:
                raise
            # recovered elsewhere (pending round re-submitted); re-cancel it
            with self._guard(self._where[device_id]):
                ok = self.replicas[self._where[device_id]].cancel_request(device_id)
        if ok:
            self._last_submit.pop(device_id, None)
        return ok

    def force_extend(self, device_id: int, tokens: np.ndarray) -> int:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        idx = self._where[device_id]
        try:
            prev = self.replicas[idx].force_extend(device_id, toks)
        except ConnectionError:
            self._evict(idx)
            if device_id not in self._where:
                raise
            # recovered (committed shadow did NOT include these tokens, so
            # the replay stopped short of them); apply them on the new home
            with self._guard(self._where[device_id]):
                prev = self.replicas[self._where[device_id]].force_extend(
                    device_id, toks
                )
        self._committed.setdefault(device_id, []).extend(int(t) for t in toks)
        return prev

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._where and self._replica(device_id).has_inflight(device_id)

    def next_event_hint(self, now: float) -> Optional[float]:
        hints = [h for r in self.alive if (h := r.next_event_hint(now)) is not None]
        return min(hints) if hints else None

    # -- the serving hot loop ------------------------------------------------

    def step(self, now: float) -> Optional[List[Verdict]]:
        """Step every replica whose policy fires; one merged verdict list.

        Local replicas step back to back in this process (they contend for
        the same accelerator anyway); REMOTE replicas are stepped
        concurrently on a thread pool — each RPC blocks only on its worker's
        verification, so N workers verify in parallel and admitted-stream
        capacity scales with processes.  Verdicts merge in replica order
        regardless of completion order, and each verdict's queue-depth
        feedback stays replica-local — that is the congestion signal for the
        streams riding that replica.  A worker that fails mid-step is
        evicted and the surviving replicas' verdicts are still returned.

        This is also the supervision tick: the chaos schedule fires against
        the step counter, suspect (heartbeat-silent) replicas are evicted,
        and dead replicas get their periodic redial attempt.
        """
        self.steps_taken += 1
        if self.chaos is not None:
            self.chaos.on_step(self.steps_taken)
        if self._hb is None and self.faults.heartbeat_interval_s > 0:
            self._hb = _HeartbeatMonitor(self, self.faults)
            self._hb.start()
        self._check_suspects()
        self._maybe_redial()
        remote_idx = [
            i
            for i, r in enumerate(self.replicas)
            if not r.dead and r.flavor == "remote"
        ]
        futures = {}
        with telemetry.span("router_step_seconds"):
            if len(remote_idx) > 1:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=len(self.replicas), thread_name_prefix="router-step"
                    )
                futures = {
                    i: self._pool.submit(self.replicas[i].step, now) for i in remote_idx
                }
            results: Dict[int, Optional[List[Verdict]]] = {}
            failed: List[int] = []
            for i, replica in enumerate(self.replicas):
                if replica.dead or i in futures:
                    continue
                try:
                    results[i] = replica.step(now)
                except ConnectionError:
                    failed.append(i)
            for i, fut in futures.items():
                try:
                    results[i] = fut.result()
                except ConnectionError:
                    failed.append(i)
            # evictions run AFTER every step future resolved: recovery may
            # re-admit streams onto surviving replicas, and their control
            # channels must be idle first (they are not thread-safe)
            for i in failed:
                self._evict(i)
        verdicts: List[Verdict] = []
        for i in sorted(results):
            out = results[i]
            if not out:
                continue
            ring = self.flight[i]
            for v in out:
                # shadow ring: recorded unconditionally (a deque append per
                # verdict) so eviction post-mortems exist even when metrics
                # collection is off
                seq = self._round_seq.get(v.device_id, 0)
                self._round_seq[v.device_id] = seq + 1
                ring.record(
                    telemetry.TraceEvent(
                        device_id=v.device_id,
                        round=seq,
                        t=now,
                        k=self._last_k.get(v.device_id, 0),
                        n_accepted=v.n_accepted,
                        n_commit=len(v.tokens),
                        queue_s=v.queue_s,
                        verify_s=v.verify_s,
                        replica=i,
                    )
                )
                # device-replay shadow: the delivered verdict's tokens are
                # committed history now, and its round is no longer in flight
                if len(v.tokens):
                    self._committed.setdefault(v.device_id, []).extend(
                        int(t) for t in v.tokens
                    )
                self._last_submit.pop(v.device_id, None)
            verdicts.extend(out)
        return verdicts or None

    def warmup(self, buckets=None) -> Dict[int, float]:
        """Warm one local replica (an in-process fleet shares a single
        VerifySteps bundle, so its executables are hot for every sibling)
        plus EVERY remote replica — each worker process has its own compile
        cache, and an un-warmed worker would pay XLA compilation inside its
        first timed step."""
        out: Dict[int, float] = {}
        warmed_local = False
        for r in self.alive:
            if r.flavor == "local":
                if warmed_local:
                    continue
                warmed_local = True
            secs = r.warmup(buckets)
            for k, v in secs.items():
                out[k] = max(out.get(k, 0.0), v)
        return out

    def drain(self) -> None:
        """Ask every remote worker to exit (reaping spawned processes);
        local replicas are no-ops.  Idempotent."""
        if self._hb is not None:
            self._hb.stop()
            self._hb = None
        for r in self.replicas:
            if not r.dead:
                r.drain()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    # -- stats ---------------------------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        return EngineStats.merge(self.replica_stats(now))

    def replica_stats(self, now: Optional[float] = None) -> List[EngineStats]:
        out = []
        for i, r in enumerate(self.replicas):
            if r.dead:
                continue
            try:
                out.append(r.stats(now))
            except ConnectionError:
                self._evict(i)
        return out

    def telemetry_payload(self) -> dict:
        """Cluster-level telemetry record, same keys as the single-engine
        ``ServerEngine.telemetry_payload``: this process's metrics snapshot
        plus the shadow flight rings (flattened, each event tagged with its
        replica), with per-remote worker payloads and eviction dumps
        attached when present."""
        if not telemetry.enabled():
            return {}
        flight = [ev.to_json() for ring in self.flight.values() for ev in ring.events()]
        flight.sort(key=lambda e: e["t"])
        out = {"snapshot": telemetry.registry().snapshot(), "flight": flight}
        # per-replica pool capacity: local replicas read their pool directly;
        # remote workers ship engine_kv_pool_bytes / engine_bytes_per_slot
        # gauges inside their own telemetry snapshot (ReplicaStats payload)
        pools = {}
        for i, r in enumerate(self.replicas):
            if r.dead:
                continue
            eng = getattr(r, "engine", None)
            if eng is not None:
                pools[str(i)] = {
                    "kv_dtype": eng.kv_dtype,
                    "kv_pool_bytes": eng.pool.pool_bytes(),
                    "bytes_per_slot": eng.pool.bytes_per_slot(),
                }
            else:
                snap = (getattr(r, "last_telemetry", None) or {}).get("snapshot") or {}
                g = snap.get("gauges", {})
                if "engine_kv_pool_bytes" in g:
                    spec = getattr(r, "spec", None)
                    pools[str(i)] = {
                        "kv_dtype": getattr(spec, "kv_dtype", "bf16"),
                        "kv_pool_bytes": int(g["engine_kv_pool_bytes"]),
                        "bytes_per_slot": int(g.get("engine_bytes_per_slot", 0)),
                    }
        if pools:
            out["pools"] = pools
        workers = {}
        for i, r in enumerate(self.replicas):
            try:
                payload = getattr(r, "last_telemetry", None)
            except ConnectionError:  # chaos-killed local: nothing to report
                payload = None
            if payload:
                workers[str(i)] = payload
        if workers:
            out["workers"] = workers
        if self.flight_dumps:
            out["evicted"] = {str(i): d for i, d in self.flight_dumps.items()}
        if self.evictions or self.respawns or self.shed_streams:
            out["supervision"] = {
                "evictions": self.evictions,
                "respawns": self.respawns,
                "recovered_streams": self.recovered_streams,
                "shed_streams": self.shed_streams,
                "lost_devices": list(self.lost_devices),
            }
        return out


class _HeartbeatMonitor(threading.Thread):
    """Background Ping loop over every remote replica's dedicated heartbeat
    channel: ``heartbeat_misses`` consecutive unanswered Pings mark the
    replica ``suspect``, and the Router evicts suspects at the top of its
    next step — a partitioned or SIGSTOPped worker is detected in seconds
    instead of waiting out the 120 s control-RPC timeout.  Replicas without
    a ``ping`` method (locals) are skipped."""

    def __init__(self, router: Router, policy: Any):
        super().__init__(daemon=True, name="router-heartbeat")
        self.router = router
        self.policy = policy
        self.misses: Dict[int, int] = {}
        self._stopped = threading.Event()

    def stop(self) -> None:
        self._stopped.set()

    def run(self) -> None:
        while not self._stopped.wait(self.policy.heartbeat_interval_s):
            self.sweep()

    def sweep(self) -> None:
        """One pass over the fleet (separated from run() for tests)."""
        for i, r in enumerate(self.router.replicas):
            ping = getattr(r, "ping", None)
            if r.dead or getattr(r, "suspect", False) or ping is None:
                continue
            try:
                ok = ping(timeout=self.policy.heartbeat_timeout_s)
            except Exception:
                ok = False
            if ok:
                self.misses[i] = 0
                continue
            self.misses[i] = self.misses.get(i, 0) + 1
            telemetry.count("router_heartbeat_misses_total")
            if self.misses[i] >= self.policy.heartbeat_misses:
                log.warning(
                    "replica %d missed %d consecutive heartbeat(s); marking suspect",
                    i, self.misses[i],
                )
                r.suspect = True
                self.misses[i] = 0


class _EvictOnGone:
    """``with router._guard(idx):`` — evict replica ``idx`` if the body dies
    with a transport failure (ReplicaGone is a ConnectionError), then
    re-raise so the caller sees the loss."""

    def __init__(self, router: Router, idx: int):
        self.router = router
        self.idx = idx

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and issubclass(exc_type, ConnectionError):
            self.router._evict(self.idx)
        return False
