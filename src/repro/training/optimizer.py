"""AdamW in pure JAX with fp32 master weights, grad clipping, LR schedules.

Optimizer state layout (per parameter leaf):
  master: fp32 copy of the weights (params themselves stay bf16 — ZeRO-style
          mixed precision: 2 bytes live weights + 12 bytes sharded opt state)
  m, v:   fp32 Adam moments

Under the production mesh the whole opt state is sharded like an FSDP
optimizer: sharding/policy.py assigns it the same PartitionSpec as the
parameter plus sharding over the data axis where the parameter is large.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    grads: Any, state: AdamWState, cfg: AdamWConfig
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """Returns (new bf16-cast params, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mst, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if mst.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * mst
        mst = mst - lr * delta
        return mst, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mst = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new = [upd(g, mst, m, v) for g, mst, m, v in zip(flat_g, flat_mst, flat_m, flat_v)]
    master = jax.tree.unflatten(treedef, [n[0] for n in new])
    m = jax.tree.unflatten(treedef, [n[1] for n in new])
    v = jax.tree.unflatten(treedef, [n[2] for n in new])

    # live params: cast masters back to the parameter dtype (bf16 weights,
    # fp32 norms keep their original dtype via the old params' dtype map)
    new_state = AdamWState(step=step, master=master, m=m, v=v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return master, new_state, metrics


def cast_like(params_template: Any, master: Any) -> Any:
    return jax.tree.map(lambda t, m: m.astype(t.dtype), params_template, master)


# ---------------------------------------------------------------------------
# Gradient compression (beyond-paper: cheap cross-pod/DCN all-reduce)
# ---------------------------------------------------------------------------


def compress_grads_int8(grads: Any, error_fb: Optional[Any]) -> Tuple[Any, Any]:
    """Int8 stochastic-free quantization with error feedback.

    Returns (dequantized grads to feed the optimizer, new error buffers).
    On hardware the int8 payload is what crosses the DCN pod axis; here we
    model it numerically (quantize -> dequantize) so convergence effects are
    real while staying pure-JAX.
    """
    if error_fb is None:
        error_fb = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = qi.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    pairs = jax.tree.map(q, grads, error_fb)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
