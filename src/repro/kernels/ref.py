"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def verify_attention_ref(
    q: jax.Array,        # (B, Sq, Hq, D) — the K+1 verify tokens' queries
    k: jax.Array,        # (B, Skv, Hkv, D) cache (buffer idx == position)
    v: jax.Array,        # (B, Skv, Hkv, D)
    kv_valid: jax.Array,  # (B,) valid entries incl. the Sq new rows
    scale: Optional[float] = None,
) -> jax.Array:
    """Causal-offset attention: query i sits at position kv_valid - Sq + i."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bihgd,bjhd->bhgij", qg, k.astype(jnp.float32)) * scale
    j = jnp.arange(Skv)
    q_pos = kv_valid[:, None] - Sq + jnp.arange(Sq)[None]  # (B, Sq)
    mask = j[None, None, :] <= q_pos[:, :, None]  # (B, Sq, Skv)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgij,bjhd->bihgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def verify_attention_paged_ref(
    q: jax.Array,         # (B, Sq, Hq, D)
    k_pool: jax.Array,    # (n_slots+1, Skv, Hkv, D) cache-row pool
    v_pool: jax.Array,
    slots: jax.Array,     # (B,) int32 pool row per batch entry
    kv_valid: jax.Array,  # (B,)
    scale: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,  # (n_slots+1, Hkv) f32 dequant
    v_scale: Optional[jax.Array] = None,  # scales for an int8 pool
) -> jax.Array:
    """Pool-indexed oracle: materialise the gather, then dense attention.

    The Pallas paged kernel must match this bit-for-tolerance — the gather
    here is the very traffic the kernel's scalar-prefetched index maps
    eliminate, but as an oracle it is the cleanest statement of semantics.
    For an int8 pool the oracle does exactly what the kernel refuses to do:
    materialise the dequantized bf16 gather (layers.kv_dequant arithmetic,
    int8 -> f32 * scale -> bf16), then run dense attention over it.
    """
    k = jnp.take(k_pool, slots, axis=0)
    v = jnp.take(v_pool, slots, axis=0)
    if k.dtype == jnp.int8:
        if k_scale is None or v_scale is None:
            raise ValueError("int8 pool oracle requires k_scale/v_scale")
        ks = jnp.take(k_scale, slots, axis=0)[:, None, :, None]  # (B,1,Hkv,1)
        vs = jnp.take(v_scale, slots, axis=0)[:, None, :, None]
        k = (k.astype(jnp.float32) * ks).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * vs).astype(jnp.bfloat16)
    return verify_attention_ref(q, k, v, kv_valid, scale=scale)


def ssd_scan_ref(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) fp32, post-softplus
    A: jax.Array,    # (H,) fp32, negative
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence — the slow exact oracle."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        xt = x[:, t].astype(jnp.float32)
        dtt = dt[:, t]
        Bt = Bm[:, t].astype(jnp.float32)
        Ct = Cm[:, t].astype(jnp.float32)
        decay = jnp.exp(dtt * A[None])  # (B, H)
        h = decay[..., None, None] * h + jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", Ct, h)
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
