"""Async edge<->server transport runtime (wire protocol + links + loops).

Decouples edge devices from the verification server behind an explicit,
versioned wire protocol so network effects — RTT, jitter, bandwidth,
stragglers, timeout fallback — are real runtime behaviour instead of
simulator-only abstractions:

  codec.py   — length-prefixed binary frames (DraftPacket / Verdict /
               admission + fallback control) with optional fp16/int8
               quantization of the draft-probability payload; v2 Verdicts
               carry acceptance + queue-depth feedback for adaptive k
  links.py   — channel abstraction: zero-latency loopback, a SimulatedLink
               imposing per-NetProfile latency/bandwidth/jitter/drop on
               every frame, and StreamEndpoint over real TCP/UDS sockets
               (tcp_listen / tcp_connect)
  server.py  — asyncio TransportServer fronting a ServerEngine or a
               cluster Router of N replicas (same serving surface)
  client.py  — asyncio EdgeClient: pipelined draft-ahead device loop with
               optional closed-loop AIMD spec-length control
"""

from repro.transport.codec import (
    Admit,
    Close,
    CodecError,
    DraftPacket,
    Fallback,
    FallbackAck,
    FrameDecoder,
    Hello,
    Verdict,
    decode_frame,
    encode_frame,
)
from repro.transport.links import (
    LinkStats,
    LoopbackLink,
    SimulatedLink,
    StreamEndpoint,
    make_link,
    tcp_connect,
    tcp_listen,
)

__all__ = [
    "Admit",
    "Close",
    "CodecError",
    "DraftPacket",
    "Fallback",
    "FallbackAck",
    "FrameDecoder",
    "Hello",
    "Verdict",
    "decode_frame",
    "encode_frame",
    "LinkStats",
    "LoopbackLink",
    "SimulatedLink",
    "StreamEndpoint",
    "make_link",
    "tcp_connect",
    "tcp_listen",
]
