"""qwen1.5-32b [dense]: MHA with QKV bias.

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064  [hf:Qwen/Qwen1.5]
"""
from repro.configs.base import ModelConfig, register

QWEN15_32B = register(
    ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        act="swiglu",
        notes="40 heads not divisible by TP=16: ffn/vocab TP exact, heads unevenly sharded by GSPMD",
    )
)
