"""Profiling pass of the auto-tuner (``repro tune``, step 1 of 3).

A short measured run of the fleet spec (telemetry on) calibrates everything
the candidate sweep needs to score configurations WITHOUT serving them:

  per class     acceptance + mean draft length (from per-session counters
                grouped by the spec's device->class ranges)
  server        ``server_latency_scale`` — the ratio between the verify
                spans the engine actually measured (TraceEvent.verify_s)
                and the ServerProfile roofline prediction, which maps the
                simulator's clock onto this deployment's clock
  network       per-class RTT straight from the class NetProfile

Candidate draft configs the profiled fleet is NOT running are priced by
:func:`probe_draft_config`: a tiny lock-step reference run measures the
(acceptance, mean draft length) of one ``(k, c_th, draft_layers,
draft_noise)`` combination.  Acceptance depends only on the model pair and
the drafting knobs — not on device hardware — so one cached probe prices
every class and every candidate that shares the config.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.api import ServeSpec, System
from repro.api.spec import FleetSpec
from repro.serving.devices import NETS, ServerProfile


@dataclasses.dataclass(frozen=True)
class ClassCalibration:
    """Measured priors for one resolved fleet class."""

    index: int
    profile: str             # hardware profile name (serving/devices.py)
    count: int
    k: int
    c_th: float
    acceptance: float        # accepted / drafted over the profiling run
    mean_draft_len: float    # drafted / rounds (c_th cuts drafts short)
    draft_rate: float        # MEASURED drafted tokens per device-second
    commit_rate: float       # MEASURED committed tokens per device-second
    hardware_rate: float     # profile-table tokens/s for the profiled combo
    rtt_mean: float          # class link RTT (0 off simulated links)


@dataclasses.dataclass(frozen=True)
class FleetCalibration:
    """Everything the sweep's simulator scoring needs, all measured."""

    classes: Tuple[ClassCalibration, ...]
    server_latency_scale: float
    verify_s_mean: float
    queue_s_mean: float
    round_latency_mean: float   # queue + verify + wire, per resolved round
    round_latency_p95: float    # tail of the same spans (deadline anchor)
    mean_batch_fill: float
    wstgr: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _span_latencies(sessions) -> list:
    """Per-round service latency (queue + verify + wire) from the traces."""
    return [
        ev.queue_s + ev.verify_s + ev.wire_s
        for s in sessions
        for ev in (s.trace or [])
    ]


def _trace_span_rate(rows, total_fn, wall: float) -> float:
    """Mean per-session steady rate: each session's total (tokens, drafts)
    after the first verdict, over its own first->last verdict span.

    Run-to-completion fleets all commit exactly ``max_new`` tokens, so
    ``total / shared_wall`` is identical for every class by construction —
    the per-class signal lives in each stream's time-to-finish.  Sessions
    too short to span two verdicts fall back to ``total / wall``."""
    rates = []
    for s in rows:
        ts = [ev.t for ev in (s.trace or [])]
        total = total_fn(s)
        if len(ts) >= 2 and max(ts) > min(ts):
            rates.append((total - total / len(ts)) / (max(ts) - min(ts)))
        elif wall > 0:
            rates.append(total / wall)
    return sum(rates) / len(rates) if rates else 0.0


def class_commit_rate(rows, *, wall: float = 0.0) -> float:
    """Per-device committed tokens/s (the goodput the floors guard)."""
    return _trace_span_rate(rows, lambda s: len(s.tokens), wall)


def class_draft_rate(rows, *, wall: float = 0.0) -> float:
    """Per-device DRAFTING tokens/s — the simulator's pacing clock.

    Transport clients measure the draft span per round, so the throttled
    (emulated-hardware) rate falls straight out of ``sum k / sum draft_s``;
    in-process backends never fill ``draft_s`` and fall back to the
    trace-span drafted rate (drafting there is compute-bound and cheap, so
    the cadence-diluted estimate is the honest pacing clock)."""
    num = sum(ev.k for s in rows for ev in (s.trace or []) if ev.draft_s > 0)
    den = sum(ev.draft_s for s in rows for ev in (s.trace or []))
    if num and den > 0:
        return num / den
    return _trace_span_rate(rows, lambda s: s.drafted, wall)


def profile_fleet(
    spec: ServeSpec,
    *,
    server: ServerProfile,
    target_params: float,
    models=None,
    kits=None,
    steps=None,
    max_new: Optional[int] = None,
) -> FleetCalibration:
    """One short telemetry-on serve of the fleet spec -> FleetCalibration."""
    if not spec.fleet.active:
        raise ValueError("profile_fleet needs a spec with an active fleet")
    pspec = dataclasses.replace(spec, telemetry=True)
    system = System.build(pspec, models=models, kits=kits, steps=steps)
    result = system.serve(max_new=max_new)

    wall = max(result.wall_seconds, 1e-9)
    sim_links = pspec.backend == "transport" and pspec.transport.link == "sim"
    classes = []
    for rc in pspec.resolved_classes():
        rows = [s for s in result.sessions if rc.lo <= s.device_id < rc.hi]
        drafted = sum(s.drafted for s in rows)
        accepted = sum(s.accepted for s in rows)
        rounds = sum(s.rounds for s in rows)
        classes.append(ClassCalibration(
            index=rc.index,
            profile=rc.spec.profile,
            count=rc.count,
            k=rc.k,
            c_th=rc.c_th,
            acceptance=accepted / max(drafted, 1),
            mean_draft_len=drafted / max(rounds, 1),
            # measured, not assumed: throttled transport runs measure the
            # emulated hardware rate; free-drafting in-process runs measure
            # the round-trip-bound rate — either way the simulator's clock
            # matches what validation will observe
            draft_rate=class_draft_rate(rows, wall=wall),
            commit_rate=class_commit_rate(rows, wall=wall),
            hardware_rate=rc.hardware_rate(),
            # only simulated links pay the class NetProfile; loopback and
            # in-process rounds have no wire (sim floors rtt at ~1 ms)
            rtt_mean=NETS[rc.net].rtt_mean if sim_links else 0.0,
        ))

    verify = [ev.verify_s for s in result.sessions for ev in (s.trace or [])]
    queue = [ev.queue_s for s in result.sessions for ev in (s.trace or [])]
    lat = _span_latencies(result.sessions)
    verify_mean = sum(verify) / max(len(verify), 1)
    fill = max(result.engine.mean_batch_fill, 1.0)
    k_top = max(rc.k for rc in pspec.resolved_classes())
    predicted = server.verify_latency(target_params, int(round(fill)), k_top + 1)
    return FleetCalibration(
        classes=tuple(classes),
        # the scale folds the gap between the roofline's paper-scale server
        # model and this deployment's measured verify spans, so simulator
        # latencies land in the same clock the validation runs measure
        server_latency_scale=verify_mean / max(predicted, 1e-9),
        verify_s_mean=verify_mean,
        queue_s_mean=sum(queue) / max(len(queue), 1),
        round_latency_mean=sum(lat) / max(len(lat), 1),
        round_latency_p95=(
            sorted(lat)[max(int(0.95 * len(lat)) - 1, 0)] if lat else 0.0
        ),
        mean_batch_fill=fill,
        wstgr=result.engine.wstgr,
    )


def probe_draft_config(
    spec: ServeSpec,
    *,
    k: int,
    c_th: float,
    draft_layers: Optional[int],
    draft_noise: float,
    devices: int = 2,
    max_new: int = 12,
    cache: Optional[Dict[tuple, Tuple[float, float]]] = None,
) -> Tuple[float, float]:
    """Measured ``(acceptance, mean_draft_len)`` for one draft config.

    A tiny lock-step reference serve — the cheapest honest measurement of
    how a candidate's drafting knobs behave on the actual model pair."""
    key = (k, round(c_th, 4), draft_layers, round(draft_noise, 4), devices, max_new)
    if cache is not None and key in cache:
        return cache[key]
    ref = spec.with_backend(
        "reference",
        fleet=FleetSpec(),
        devices=devices,
        k_max=k,
        c_th=c_th,
        max_new=max_new,
        telemetry=False,
        model=dataclasses.replace(
            spec.model, draft_layers=draft_layers, draft_noise=draft_noise
        ),
    )
    res = System.build(ref).serve()
    drafted = sum(s.drafted for s in res.sessions)
    accepted = sum(s.accepted for s in res.sessions)
    rounds = sum(s.rounds for s in res.sessions)
    out = (accepted / max(drafted, 1), drafted / max(rounds, 1))
    if cache is not None:
        cache[key] = out
    return out


def make_prober(
    spec: ServeSpec, *, devices: int = 2, max_new: int = 12
) -> Callable[..., Tuple[float, float]]:
    """A cached probe bound to one base spec — what the sweep hands around."""
    cache: Dict[tuple, Tuple[float, float]] = {}

    def probe(*, k, c_th, draft_layers, draft_noise):
        return probe_draft_config(
            spec, k=k, c_th=c_th, draft_layers=draft_layers,
            draft_noise=draft_noise, devices=devices, max_new=max_new,
            cache=cache,
        )

    return probe
