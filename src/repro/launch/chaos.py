"""``repro chaos`` — run a deterministic fault schedule against a fleet.

A thin launcher over the unified ``repro.api`` front door: build a ServeSpec
whose ``faults`` schedule kills / hangs / flaps replicas at fixed rounds,
serve it, and report what the supervision layer did about it — evictions,
respawns, recovered vs shed streams — plus (``--check``, default on) a
token-identity verdict against the fault-free twin of the same spec.

    repro chaos                                   # 2 replicas, kill #1 at
                                                  # round 5, recovery on
    repro chaos --kill 1:5 --kill 0:9             # two kills
    repro chaos --no-recover                      # today's evict-only path
    repro chaos --flavor remote                   # real worker processes
    repro chaos --spec chaos.json --json out.json # from / to artifacts

Exit status is non-zero when --check finds divergence, so CI can gate on a
committed chaos schedule staying token-identical under recovery.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

from repro.api import ClusterSpec, FaultSpec, ModelSpec, ServeSpec, System


def _parse_kill(text: str) -> dict:
    """``REPLICA:ROUND`` (or ``kind:REPLICA:ROUND``) -> FaultEvent dict."""
    parts = text.split(":")
    if len(parts) == 2:
        kind, replica, rnd = "kill", parts[0], parts[1]
    elif len(parts) == 3:
        kind, replica, rnd = parts
    else:
        raise argparse.ArgumentTypeError(
            f"bad fault {text!r} (want REPLICA:ROUND or KIND:REPLICA:ROUND)"
        )
    return {"kind": kind, "replica": int(replica), "round": int(rnd)}


def spec_from_args(args) -> ServeSpec:
    events = tuple(args.kill) if args.kill else ({"kind": "kill", "replica": 1, "round": 5},)
    faults_policy = {
        "respawn": args.recover,
        "recover_streams": args.recover,
        "backoff_base_s": args.backoff,
        "backoff_max_s": max(args.backoff * 8, args.backoff),
    }
    replicas: object = args.replicas
    if args.flavor == "remote":
        replicas = [{"flavor": "remote"} for _ in range(args.replicas)]
    return ServeSpec(
        backend="cluster",
        model=ModelSpec(vocab_size=128, target_layers=2, draft_layers=1,
                        draft_noise=0.03),
        cluster=ClusterSpec(replicas=replicas, faults=faults_policy),
        devices=args.devices,
        max_new=args.max_new,
        k_max=4,
        faults=FaultSpec(seed=args.seed, events=events),
        telemetry=True,
    )


def run_chaos(spec: ServeSpec, *, check: bool = True) -> dict:
    """Serve the chaos spec, print the supervision report, return the
    BENCH-shaped record.  Raises AssertionError on --check divergence."""
    fault_free = dataclasses.replace(spec, faults=FaultSpec())
    system = System.build(spec)
    kinds = [f"{e.kind}@r{e.round}->replica{e.replica}" for e in spec.faults.events]
    print(
        f"chaos: {spec.cluster.n_replicas} replicas, {spec.devices} devices, "
        f"schedule [{', '.join(kinds)}] (seed {spec.faults.seed}), "
        f"recovery {'ON' if spec.cluster.faults.recover_streams else 'OFF'}"
    )
    t0 = time.time()
    try:
        result = system.serve()
    except BaseException:
        system.close()
        raise
    wall = time.time() - t0
    router = system.engine
    fired = list(getattr(getattr(router, "chaos", None), "fired", []) or [])
    report = {
        "fired": [{"round": r, "kind": k, "replica": i} for r, k, i in fired],
        "evictions": getattr(router, "evictions", 0),
        "respawns": getattr(router, "respawns", 0),
        "recovered_streams": getattr(router, "recovered_streams", 0),
        "shed_streams": getattr(router, "shed_streams", 0),
        "lost_devices": sorted(result.lost_devices),
        "committed_tokens": result.total_tokens,
        "wall_seconds": wall,
        "tokens_per_s": result.total_tokens / max(wall, 1e-9),
    }
    system.close()
    for r, k, i in fired:
        print(f"  fired {k} on replica {i} at round {r}")
    print(
        f"supervision: {report['evictions']} evictions, "
        f"{report['respawns']} respawns, "
        f"{report['recovered_streams']} streams recovered, "
        f"{report['shed_streams']} shed {report['lost_devices']}"
    )
    print(
        f"served {result.total_tokens} tokens in {wall:.1f}s "
        f"({report['tokens_per_s']:.1f} tok/s)"
    )
    if check:
        ref = System.build(fault_free, models=system.models).serve()
        if spec.cluster.faults.recover_streams:
            match = ref.outputs == result.outputs
            print(f"fault-free token identity: {'OK' if match else 'MISMATCH'}")
            assert match, "recovered run must be token-identical to fault-free"
        else:
            # without recovery shed streams end early; survivors must still
            # match and every shed stream must be a clean prefix
            ok = True
            for s in result.sessions:
                ref_toks = ref.outputs[s.device_id]
                ok &= (s.tokens == ref_toks if not s.shed
                       else ref_toks[: len(s.tokens)] == s.tokens)
            print(f"survivor identity + shed prefixes: {'OK' if ok else 'MISMATCH'}")
            assert ok, "shed streams must end as clean prefixes of fault-free"
        report["check"] = "ok"
    return {"spec": spec.to_json(), "result": result.to_json(), "chaos": report}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro chaos",
        description="deterministic fault injection against a replica fleet",
    )
    ap.add_argument("--spec", type=str, default="",
                    help="ServeSpec JSON artifact (its faults schedule runs as-is)")
    ap.add_argument("--kill", action="append", type=_parse_kill, default=None,
                    metavar="REPLICA:ROUND",
                    help="fault event (repeatable); KIND:REPLICA:ROUND for "
                         "hang/drop/delay/flap")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--flavor", choices=("local", "remote"), default="local",
                    help="remote = spawned worker processes (real SIGKILL)")
    ap.add_argument("--recover", action=argparse.BooleanOptionalAction, default=True,
                    help="respawn + device-replay recovery (off = evict-only)")
    ap.add_argument("--backoff", type=float, default=0.05,
                    help="respawn backoff base seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action=argparse.BooleanOptionalAction, default=True,
                    help="compare against the fault-free twin run")
    ap.add_argument("--json", type=str, default="",
                    help="write the BENCH artifact (spec + result + chaos report)")
    return ap


def main(argv: Optional[List[str]] = None) -> None:
    args = build_parser().parse_args(argv)
    if args.spec:
        with open(args.spec) as f:
            spec = ServeSpec.from_json(f.read())
    else:
        spec = spec_from_args(args)
    record = run_chaos(spec, check=args.check)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, default=str)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
