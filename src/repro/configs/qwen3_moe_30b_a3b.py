"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8, GQA kv=4.

48L d_model=2048 32H (kv=4) d_ff=768/expert vocab=151936  [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ModelConfig, register

QWEN3_MOE = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=768,
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        act="swiglu",
        notes="EP: 128 experts / 16 model shards = 8 experts per device",
    )
)
