"""Pallas TPU kernels: SLED batched-verification attention (dense + paged).

The server's hot loop attends Sq = K+1 fresh tokens per request against a
long KV cache.  TPU adaptation (vs the CUDA "append attention" kernels GPU
serving engines use — DESIGN.md §3):

  * the MXU wants >= 8 x 128 tiles, but Sq is tiny (5).  We PACK the GQA
    group dimension into the query rows: rows = Sq * G (granite MQA: 5 x 48
    = 240 rows — full MXU occupancy from what would be a 5-row matmul);
  * the KV cache streams HBM->VMEM once in ``block_k`` chunks along the
    sequence — verification at small K is HBM-bound, so one pass over the
    cache IS the roofline;
  * online-softmax state (m, l, acc) lives in fp32 VMEM scratch across the
    kv-chunk grid axis (TPU grids iterate the last axis sequentially);
  * the causal offset mask (query i sits at absolute position
    kv_valid - Sq + i) is computed from iota over packed rows — no mask
    tensor is ever materialised;
  * ``Skv`` need not divide ``block_k``: the final partial chunk is handled
    by masking the out-of-range lanes (scores forced to NEG_INF, the
    corresponding V rows zeroed so unspecified out-of-bounds data can never
    poison the accumulator).

Two entry points share that math:

``verify_attention_packed`` — dense layout: each batch row owns its own
contiguous (Skv, Hkv, D) K/V buffer.  The lock-step server path.

``verify_attention_paged`` — pool layout for continuous batching: K/V live
in one shared pool of cache rows shaped ``(n_slots + 1, Skv, Hkv, D)`` (the
+1 row is the scratch slot that pads partial batches), and a ``(B,)``
``slots`` vector names which pool row each batch entry attends against.
``slots`` is a *scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``):
it lands in SMEM before the kernel body runs, so the BlockSpec index maps
can compute each K/V tile's HBM address as ``(slots[b], j, h, 0)`` — the
grid walks ``(B, Hkv, n_blk)`` and every chunk DMA reads straight out of
the pool row the slot map points at.  Nothing is ever gathered into a dense
sub-batch and nothing but the O(K+1) fresh rows is ever written back, which
deletes the gather/scatter paging tax the engine's verify step used to pay
(benchmarks/verify_kernel.py --engine measures it).  Duplicate slot ids are
legal (padding rows all point at the scratch slot); their outputs are
garbage by construction and discarded by the caller.

The gather path still exists for model families whose caches hold
non-attention leaves (Mamba2 SSM state / conv windows, hybrid checkpoints):
those leaves are recurrent state, not position-indexed K/V, so they cannot
be slot-indexed by this kernel and keep riding ``kvcache.gather_slots`` —
they are tiny next to the attention pool.

Layouts: q is pre-packed to (B, Hkv, Sq*G, D) by ops.py (tiny transpose);
k/v stay (B, Skv, Hkv, D) / (n_slots+1, Skv, Hkv, D) — BlockSpec index maps
stride the head dim, so the multi-GB cache is never transposed.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attend_chunk(kv_valid, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, block_k: int, sq: int, skv: int, scale: float,
                  k_scale=None, v_scale=None):
    """One online-softmax step over the current kv chunk (grid axis 2).

    Shared by the dense and paged kernels — only how the chunk was addressed
    differs (BlockSpec index maps), never the math.  Requires
    ``kv_valid >= sq`` (the Sq fresh rows are in the cache), which makes the
    first chunk contain at least one valid position for every packed row.

    ``k_scale``/``v_scale`` (f32 scalars for this (slot, head)) switch on the
    int8 path: the K/V tiles arrive quantized and are dequantized HERE, on
    the VMEM-resident chunk — the HBM stream is int8, so the cache read
    halves, and no bf16 pool copy ever exists.  The dequant arithmetic
    mirrors layers.kv_dequant (int8 -> f32 * scale -> bf16) so the kernel
    tracks the XLA serving path's numerics.
    """
    j_blk = pl.program_id(2)
    n_blk = pl.num_programs(2)

    @pl.when(j_blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (rows, D) rows = Sq*G
    k = k_ref[0, :, 0, :]  # (block_k, D)
    v = v_ref[0, :, 0, :]
    if k_scale is not None:
        k = (k.astype(jnp.float32) * k_scale).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * v_scale).astype(jnp.bfloat16)
    rows = q.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (rows, block_k)

    # packed row r -> query index i = r // G; abs position = kv_valid - Sq + i
    g = rows // sq
    i_vec = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
    j_vec = jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1) + j_blk * block_k
    mask = (j_vec <= (kv_valid - sq + i_vec)) & (j_vec < skv)
    s = jnp.where(mask, s, NEG_INF)
    # Partial tail chunk: lanes past Skv read unspecified data (NaN in
    # interpret mode).  Their weights are exactly 0, but 0 * NaN = NaN would
    # still poison acc — zero the out-of-range V rows explicitly.
    col = jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0) + j_blk * block_k
    v = jnp.where(col < skv, v, jnp.zeros((), v.dtype))

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j_blk == n_blk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _kernel(kv_valid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_k: int, sq: int, skv: int, scale: float):
    _attend_chunk(kv_valid_ref[0], q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, block_k=block_k, sq=sq, skv=skv, scale=scale)


def _paged_kernel(slots_ref, kv_valid_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref,
                  *, block_k: int, sq: int, skv: int, scale: float):
    # slots_ref is consumed by the BlockSpec index maps (scalar prefetch);
    # the body only needs the per-request valid length.
    del slots_ref
    b = pl.program_id(0)
    _attend_chunk(kv_valid_ref[b], q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, block_k=block_k, sq=sq, skv=skv, scale=scale)


def _paged_quant_kernel(slots_ref, kv_valid_ref, k_scale_ref, v_scale_ref,
                        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                        *, block_k: int, sq: int, skv: int, scale: float):
    # int8 pool: the per-(slot, head) dequant scales ride scalar prefetch
    # next to slots/kv_valid — SMEM-resident before the body runs, looked up
    # here with the same slot map the index maps use for the K/V tiles.
    b = pl.program_id(0)
    h = pl.program_id(1)
    row = slots_ref[b]
    _attend_chunk(kv_valid_ref[b], q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, block_k=block_k, sq=sq, skv=skv, scale=scale,
                  k_scale=k_scale_ref[row, h], v_scale=v_scale_ref[row, h])


def verify_attention_packed(
    q: jax.Array,        # (B, Hkv, rows=Sq*G, D)
    k: jax.Array,        # (B, Skv, Hkv, D)
    v: jax.Array,
    kv_valid: jax.Array,  # (B,) int32
    *,
    sq: int,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,  # CPU container: interpret; flip off on TPU
) -> jax.Array:
    B, Hkv, rows, D = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, Skv)
    n_blk = -(-Skv // block_k)  # partial tail chunk is masked in-kernel

    kernel = functools.partial(_kernel, block_k=block_k, sq=sq, skv=Skv,
                               scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_blk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),                 # kv_valid
            pl.BlockSpec((1, 1, rows, D), lambda b, h, j: (b, h, 0, 0)),   # q
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),  # k
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # m
            pltpu.VMEM((rows, 1), jnp.float32),   # l
            pltpu.VMEM((rows, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(kv_valid, q, k, v)


def verify_attention_paged(
    q: jax.Array,        # (B, Hkv, rows=Sq*G, D)
    k_pool: jax.Array,   # (n_slots+1, Skv, Hkv, D) — the PagedKVCache pool
    v_pool: jax.Array,
    slots: jax.Array,     # (B,) int32 pool row per batch entry (dups legal)
    kv_valid: jax.Array,  # (B,) int32 valid entries incl. the Sq fresh rows
    *,
    sq: int,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,
    k_scale: Optional[jax.Array] = None,  # (n_slots+1, Hkv) f32 dequant
    v_scale: Optional[jax.Array] = None,  # scales for an int8 pool
) -> jax.Array:
    """Slot-indexed verification attention over a shared cache-row pool.

    ``slots`` and ``kv_valid`` ride scalar prefetch: the index maps address
    each (block_k, D) K/V tile as ``(slots[b], j, h, 0)`` directly in the
    pool, so the chunk DMAs stream exactly the scheduled rows — no dense
    gather ever exists (see module docstring).

    With an int8 pool, pass the PagedKVCache's per-(slot, head) dequant
    scales as ``k_scale``/``v_scale``: they join the scalar-prefetch
    operands and each chunk is dequantized IN-KERNEL on its VMEM tile
    (``_attend_chunk``), so HBM streams the cache at 1 byte/element —
    that halved stream is the whole point of the quantized pool.
    """
    B, Hkv, rows, D = q.shape
    Skv = k_pool.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, Skv)
    n_blk = -(-Skv // block_k)

    quant = k_pool.dtype == jnp.int8
    if quant and (k_scale is None or v_scale is None):
        raise ValueError("int8 k_pool/v_pool require k_scale/v_scale operands")

    scratch = [
        pltpu.VMEM((rows, 1), jnp.float32),   # m
        pltpu.VMEM((rows, 1), jnp.float32),   # l
        pltpu.VMEM((rows, D), jnp.float32),   # acc
    ]
    if quant:
        kernel = functools.partial(_paged_quant_kernel, block_k=block_k, sq=sq,
                                   skv=Skv, scale=float(scale))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,  # slots, kv_valid, k_scale, v_scale
            grid=(B, Hkv, n_blk),
            in_specs=[
                pl.BlockSpec((1, 1, rows, D),
                             lambda b, h, j, slots, kvv, ks, vs: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, j, slots, kvv, ks, vs: (slots[b], j, h, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, j, slots, kvv, ks, vs: (slots[b], j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, D),
                                   lambda b, h, j, slots, kvv, ks, vs: (b, h, 0, 0)),
            scratch_shapes=scratch,
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
            interpret=interpret,
        )(slots.astype(jnp.int32), kv_valid.astype(jnp.int32),
          k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
          q, k_pool, v_pool)

    kernel = functools.partial(_paged_kernel, block_k=block_k, sq=sq, skv=Skv,
                               scale=float(scale))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # slots, kv_valid
        grid=(B, Hkv, n_blk),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D), lambda b, h, j, slots, kvv: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, j, slots, kvv: (slots[b], j, h, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, D), lambda b, h, j, slots, kvv: (slots[b], j, h, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D), lambda b, h, j, slots, kvv: (b, h, 0, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        interpret=interpret,
    )(slots.astype(jnp.int32), kv_valid.astype(jnp.int32), q, k_pool, v_pool)
