"""Heterogeneous fleets + the profiling-driven auto-tuner (``repro tune``).

Covers the FleetSpec/DeviceClassSpec layer (validation, JSON round trip,
sentinel inheritance, device-id ranges), the per-class serving path on the
engine backend (token identity against per-class lock-step references),
the ConfidenceController feedback loop, the heterogeneous simulator
surface the tuner scores against, and the tuning pipeline's measurement
helpers.  The full tune() loop runs in the slow tier.
"""

import dataclasses
import types

import pytest

from repro.api import (
    DeviceClassSpec,
    FleetSpec,
    KitCache,
    ModelSpec,
    SchedulerSpec,
    ServeSpec,
    SpecError,
    System,
    TransportSpec,
    build_models,
)
from repro.serving.devices import DEVICES, SERVERS
from repro.serving.simulator import ClassLoad, SimConfig, capacity, simulate
from repro.serving.speclen import ConfidenceController, make_confidence_controller
from repro.telemetry.trace import TraceEvent
from repro.tuning import (
    TuneConfig,
    at_multiplier,
    class_commit_rate,
    class_draft_rate,
    profile_fleet,
    scaled_fleet,
    tune,
    with_class,
)

V = 64


def _fleet_spec(**kw) -> ServeSpec:
    base = dict(
        backend="engine",
        model=ModelSpec(vocab_size=V, target_layers=2, draft_layers=1, draft_noise=0.03),
        transport=TransportSpec(stagger_s=0.0),
        scheduler=SchedulerSpec(stagger_ticks=0, slots=4),
        fleet=FleetSpec(
            classes=(
                DeviceClassSpec(
                    profile="jetson-orin-nano", count=2,
                    draft_model="llama-1b-draft", bits=4,
                    k=4, c_th=0.0, draft_noise=0.02,
                ),
                DeviceClassSpec(
                    profile="rpi4b", count=2,
                    draft_model="llama-1b-draft", bits=4,
                    k=2, c_th=0.4, draft_noise=0.3,
                ),
            ),
        ),
        prompt_len=8,
        max_new=8,
        k_max=4,
        c_th=0.3,
    )
    base.update(kw)
    return ServeSpec(**base)


# ---------------------------------------------------------------------------
# spec validation + serialization
# ---------------------------------------------------------------------------


def test_fleet_spec_valid_and_devices_derived():
    spec = _fleet_spec()
    assert spec.fleet.active
    assert spec.devices == 4  # derived from class counts, not the default


def test_unknown_profile_rejected():
    with pytest.raises(SpecError, match="profile 'gameboy' not in"):
        _fleet_spec(fleet=FleetSpec(classes=(DeviceClassSpec(profile="gameboy"),)))


def test_absent_rate_combo_lists_available():
    with pytest.raises(SpecError, match="available combos"):
        _fleet_spec(fleet=FleetSpec(classes=(
            DeviceClassSpec(profile="rpi4b", draft_model="llama-1b-draft", bits=3),
        )))


def test_class_c_th_bounds():
    with pytest.raises(SpecError, match="c_th must be in"):
        _fleet_spec(fleet=FleetSpec(classes=(DeviceClassSpec(c_th=1.5),)))


def test_class_count_floor():
    with pytest.raises(SpecError, match="count must be >= 1"):
        _fleet_spec(fleet=FleetSpec(classes=(DeviceClassSpec(count=0),)))


def test_rate_scale_positive():
    with pytest.raises(SpecError, match="rate_scale"):
        _fleet_spec(fleet=dataclasses.replace(
            _fleet_spec().fleet, rate_scale=0.0))


def test_reference_backend_rejects_fleet():
    with pytest.raises(SpecError, match="heterogeneous fleet"):
        _fleet_spec(backend="reference")


def test_fleet_json_round_trip():
    spec = _fleet_spec()
    again = ServeSpec.from_json(spec.to_json())
    assert again == spec
    assert [c.profile for c in again.fleet.classes] == ["jetson-orin-nano", "rpi4b"]


def test_resolved_classes_inherit_sentinels():
    spec = _fleet_spec(fleet=FleetSpec(classes=(
        DeviceClassSpec(profile="rpi5", count=3),  # all sentinels
    )))
    (rc,) = spec.resolved_classes()
    assert (rc.lo, rc.hi) == (0, 3)
    assert rc.k == spec.k_max
    assert rc.c_th == spec.c_th
    assert rc.draft_noise == spec.model.draft_noise


def test_class_of_contiguous_ranges():
    spec = _fleet_spec()
    owners = [spec.class_of(i).index for i in range(spec.devices)]
    assert owners == [0, 0, 1, 1]
    assert spec.class_of(99) is None


def test_device_rate_error_names_combos():
    with pytest.raises(KeyError, match="llama-1b-draft"):
        DEVICES["rpi4b"].rate("llama-1b-draft", 3)


# ---------------------------------------------------------------------------
# per-class serving: engine backend vs per-class lock-step references
# ---------------------------------------------------------------------------


def test_engine_fleet_matches_per_class_references():
    spec = _fleet_spec()
    system = System.build(spec)
    result = system.serve()
    prompts = system.prompts()
    for lo, hi, refspec in spec.fleet_reference_specs():
        ref = System.build(refspec).serve(prompts[lo:hi])
        for i in range(hi - lo):
            assert ref.outputs[i] == result.outputs[lo + i], (
                f"class [{lo},{hi}) device {lo + i} diverged")


def test_kit_for_routes_class_kits():
    spec = _fleet_spec()
    system = System.build(spec)
    k_fast = system.kit_for(0)
    k_slow = system.kit_for(spec.devices - 1)
    assert k_fast.k_max == 4 and k_slow.k_max == 2
    assert k_fast.c_th == pytest.approx(0.0)
    assert k_slow.c_th == pytest.approx(0.4)


def test_rate_for_emulation_scales_hardware_rate():
    spec = _fleet_spec(fleet=dataclasses.replace(
        _fleet_spec().fleet, emulate_rates=True, rate_scale=10.0))
    system = System.build(spec)
    jet = DEVICES["jetson-orin-nano"].rate("llama-1b-draft", 4)
    rpi = DEVICES["rpi4b"].rate("llama-1b-draft", 4)
    assert system.rate_for(0) == pytest.approx(jet * 10.0)
    assert system.rate_for(spec.devices - 1) == pytest.approx(rpi * 10.0)


# ---------------------------------------------------------------------------
# confidence controller (cctl=adaptive)
# ---------------------------------------------------------------------------


def test_confidence_controller_tightens_on_low_acceptance():
    ctl = ConfidenceController(c_init=0.3, step=0.05)
    for _ in range(4):
        ctl.update(0.1, 0)
    assert ctl.c > 0.3 and ctl.raises >= 1


def test_confidence_controller_relaxes_on_high_acceptance():
    ctl = ConfidenceController(c_init=0.3, step=0.05)
    for _ in range(10):
        ctl.update(1.0, 0)
    assert ctl.c == pytest.approx(ctl.c_min)
    assert ctl.lowers >= 1


def test_confidence_controller_congestion_tightens_despite_acceptance():
    ctl = ConfidenceController(c_init=0.3, step=0.05, queue_hi=2)
    ctl.update(1.0, 10)
    assert ctl.c == pytest.approx(0.35)


def test_make_confidence_controller_modes():
    assert make_confidence_controller("fixed", c_init=0.2) is None
    ctl = make_confidence_controller("adaptive", c_init=0.2, device_id=3)
    assert ctl is not None and ctl.c == pytest.approx(0.2)
    with pytest.raises(ValueError, match="unknown cctl"):
        make_confidence_controller("nope", c_init=0.2)


# ---------------------------------------------------------------------------
# heterogeneous simulator surface
# ---------------------------------------------------------------------------


def _hetero_sim(**kw) -> SimConfig:
    base = dict(
        mode="sled",
        batch_policy="continuous",
        sim_time=6.0,
        seed=0,
        classes=(
            ClassLoad(count=3, device_rate=200.0, spec_len=4, acceptance=0.9),
            ClassLoad(count=3, device_rate=10.0, spec_len=2, acceptance=0.3),
        ),
    )
    base.update(kw)
    return SimConfig(**base)


def test_sim_reports_per_class_rates():
    r = simulate(_hetero_sim(), SERVERS["a100x4"])
    assert len(r.class_device_rates) == 2
    assert r.class_device_rates[0] > r.class_device_rates[1] > 0


def test_sim_capacity_rejects_classes():
    with pytest.raises(ValueError, match="ClassLoad.count"):
        capacity(_hetero_sim(), SERVERS["a100x4"])


# ---------------------------------------------------------------------------
# tuning measurement helpers
# ---------------------------------------------------------------------------


def _session(tokens, drafted, events):
    return types.SimpleNamespace(
        tokens=list(range(tokens)), drafted=drafted, trace=list(events))


def _ev(t, k=2, draft_s=0.0):
    return TraceEvent(device_id=0, round=0, t=t, k=k, n_accepted=1,
                      n_commit=1, draft_s=draft_s)


def test_class_commit_rate_uses_per_session_spans():
    # 8 tokens over 4 rounds spanning 1s -> steady rate (8 - 8/4) / 1 = 6
    fast = _session(8, 8, [_ev(t) for t in (0.0, 0.33, 0.66, 1.0)])
    assert class_commit_rate([fast], wall=10.0) == pytest.approx(6.0)
    # traceless sessions fall back to total / wall
    bare = _session(8, 8, [])
    assert class_commit_rate([bare], wall=4.0) == pytest.approx(2.0)


def test_class_draft_rate_prefers_measured_draft_spans():
    rows = [_session(4, 8, [_ev(0.0, k=4, draft_s=0.5),
                            _ev(1.0, k=4, draft_s=0.5)])]
    assert class_draft_rate(rows, wall=10.0) == pytest.approx(8.0)


def test_flatten_row_nested_dicts_and_skip():
    from benchmarks.common import flatten_row

    flat = flatten_row({
        "a": 1,
        "b": {"x": 2, "y": {"z": 3}},
        "runs": [{"m": 1}, {"m": 2}],
        "spec": {"huge": "blob"},
    })
    assert flat["a"] == 1 and flat["b.x"] == 2 and flat["b.y.z"] == 3
    assert flat["runs.0.m"] == 1 and flat["runs.1.m"] == 2
    assert not any(k.startswith("spec") for k in flat)


def test_with_class_and_scaled_fleet():
    spec = _fleet_spec()
    moved = with_class(spec, 1, k=3, c_th=0.2)
    assert moved.fleet.classes[1].k == 3
    assert moved.fleet.classes[0] == spec.fleet.classes[0]
    big = scaled_fleet(spec, 3)
    assert big.devices == spec.devices * 3
    assert [c.count for c in big.fleet.classes] == [6, 6]
    # fractional multipliers round per class and never drop below one device
    frac = scaled_fleet(spec, 1.5)
    assert [c.count for c in frac.fleet.classes] == [3, 3]
    tiny = scaled_fleet(spec, 0.1)
    assert [c.count for c in tiny.fleet.classes] == [1, 1]


def test_at_multiplier_provisions_slots_to_fleet():
    spec = _fleet_spec()
    grown = at_multiplier(spec, 2)
    assert grown.devices == spec.devices * 2
    assert grown.scheduler.slots == grown.fleet.total


def test_profile_fleet_measures_per_class_priors():
    spec = _fleet_spec()
    models = build_models(spec.model)
    kits = KitCache()
    cal = profile_fleet(spec, server=SERVERS["a100x4"], target_params=11e9,
                        models=models, kits=kits)
    assert len(cal.classes) == 2
    jet, rpi = cal.classes
    assert jet.profile == "jetson-orin-nano" and rpi.profile == "rpi4b"
    # the noisy rpi draft (noise 0.3, c_th 0.4) accepts less than the jetson
    assert jet.acceptance > rpi.acceptance
    assert jet.commit_rate > 0 and rpi.commit_rate > 0
    assert cal.server_latency_scale > 0


@pytest.mark.slow
def test_tune_quick_emits_valid_winner():
    spec = _fleet_spec()
    tcfg = TuneConfig(quick=True, n_validate=1, sim_time=4.0, passes=1)
    res = tune(spec, tcfg)
    res.winner.validate()
    again = ServeSpec.from_json(res.winner.to_json())
    assert again == res.winner
    assert res.deadline_s > 0
    assert res.rows and res.validated
