"""Sharding policy + dry-run machinery on a small debug mesh.

Multi-device tests run in a SUBPROCESS so the host-device-count flag never
leaks into the rest of the suite (smoke tests must see 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.base import SHAPES, get_config
from repro.roofline.analysis import Roofline, model_flops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_policy_specs_divisible():
    """Every emitted spec divides its dim on the production mesh (this is
    what pjit enforces — run for every arch x entry point)."""
    code = textwrap.dedent("""
        import jax
        from repro.configs.base import get_config, SHAPES, list_configs, shape_applicable
        from repro.launch.cells import build_cell
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 4)
        checked = 0
        for name in list_configs():
            cfg = get_config(name)
            if cfg.notes.startswith("paper-"):
                continue
            for shape in SHAPES.values():
                if not shape_applicable(cfg, shape):
                    continue
                cell = build_cell(cfg, shape, mesh, attn_chunk=256)
                def walk(sds, sh):
                    global checked
                    import numpy as np
                    spec = sh.spec
                    for dim, ax in zip(sds.shape, spec):
                        if ax is None: continue
                        axes = ax if isinstance(ax, tuple) else (ax,)
                        n = 1
                        for a in axes: n *= mesh.shape[a]
                        assert dim % n == 0, (name, shape.name, sds.shape, spec)
                import jax.tree_util as jtu
                for sds, sh in zip(jtu.tree_leaves(cell.args), jtu.tree_leaves(cell.in_shardings)):
                    walk(sds, sh)
                checked += 1
        print("checked", checked)
    """)
    out = _run_sub(code)
    assert "checked" in out and int(out.split()[-1]) >= 30


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "decode_32k"),
    ("granite-moe-3b-a800m", "decode_32k"),   # f-TP MoE + seq-shard cache
    ("mamba2-370m", "long_500k"),
    ("zamba2-1.2b", "decode_32k"),
    # whisper train_4k lowers+compiles for 40s+: slow tier
    pytest.param("whisper-tiny", "train_4k", marks=pytest.mark.slow),
])
def test_debug_mesh_lower_compile(arch, shape):
    """lower+compile succeeds on a small mesh for representative cells
    (the full 512-device x 40-cell sweep is launch/dryrun.py)."""
    code = textwrap.dedent(f"""
        import jax, dataclasses
        from repro.compat import use_mesh
        from repro.configs.base import get_config, SHAPES
        from repro.launch.cells import build_cell
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(2, 4)
        cfg = get_config("{arch}")
        # shrink the giant dims so the debug compile stays fast, keep family
        cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 4))
        shape = dataclasses.replace(SHAPES["{shape}"],
                                    seq_len=2048, global_batch=8)
        cell = build_cell(cfg, shape, mesh, attn_chunk=256)
        with use_mesh(mesh):
            compiled = cell.lower().compile()
        ma = compiled.memory_analysis()
        print("ok", ma.temp_size_in_bytes)
    """)
    out = _run_sub(code)
    assert out.startswith("ok")


def test_sp_attention_numerics_under_mesh():
    """Sequence-parallel flash-decoding == single-device reference."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import use_mesh
        from repro.models.layers import MeshContext, flash_attention
        from repro.distributed.collectives import sp_append_attend
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = MeshContext(mesh=mesh, batch_axes=("data",), model_axis="model",
                          seq_shard_kv=True)
        B, Sq, Hq, Hkv, S, D = 4, 3, 8, 2, 64, 16
        ks = jax.random.split(jax.random.key(0), 6)
        q = jax.random.normal(ks[0], (B, Sq, Hq, D))
        kc = jax.random.normal(ks[1], (B, S, Hkv, D))
        vc = jax.random.normal(ks[2], (B, S, Hkv, D))
        kn = jax.random.normal(ks[3], (B, Sq, Hkv, D))
        vn = jax.random.normal(ks[4], (B, Sq, Hkv, D))
        clen = jnp.full((B,), 30, jnp.int32)
        start = jnp.int32(30)
        with use_mesh(mesh):
            out, kc2, vc2 = jax.jit(lambda *a: sp_append_attend(*a, ctx, chunk=16))(
                q, kc, vc, kn, vn, clen, start)
        kref = kc.at[:, 30:33].set(kn)
        vref = vc.at[:, 30:33].set(vn)
        q_pos = clen[:, None] + jnp.arange(Sq)[None]
        want = flash_attention(q, kref, vref, q_pos=q_pos, kv_valid=clen + Sq, chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-3)
        np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kref))
        print("ok")
    """)
    assert _run_sub(code).startswith("ok")


def test_moe_shard_map_matches_single_device():
    """EP/f-TP moe_block under a mesh == single-device moe math."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.compat import use_mesh
        from repro.configs.base import get_config
        from repro.models.layers import MeshContext, init_moe, moe_block, NO_MESH
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for E in (8, 6):  # 8 % 4 == 0 -> EP; 6 % 4 != 0 -> f-TP
            cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                                      num_experts=E, experts_per_token=2, moe_d_ff=32)
            p = init_moe(jax.random.key(0), cfg)
            x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.bfloat16)
            ref, _ = moe_block(x, p, cfg, NO_MESH)
            ctx = MeshContext(mesh=mesh, batch_axes=("data",), model_axis="model")
            with use_mesh(mesh):
                out, _ = jax.jit(lambda x, p: moe_block(x, p, cfg, ctx))(x, p)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32), rtol=6e-2, atol=6e-2)
        print("ok")
    """)
    assert _run_sub(code).startswith("ok")


def test_roofline_terms_computable():
    r = Roofline(arch="x", shape="y", mesh="pod", chips=256,
                 hlo_flops=1e12, hlo_bytes=1e10, collective_bytes=1e8,
                 model_flops=2.56e14, arg_bytes=1, temp_bytes=1, out_bytes=1)
    assert r.bottleneck == "memory"
    assert 0 < r.roofline_frac <= 1.5
    d = r.to_dict()
    assert set(d) >= {"t_compute", "t_memory", "t_collective", "bottleneck"}


def test_model_flops_sane():
    cfg = get_config("phi3-mini-3.8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > de  # training a full batch >> verifying K+1 tokens
    assert tr > 6 * 3.5e9 * SHAPES["train_4k"].global_batch * 4096 * 0.9
