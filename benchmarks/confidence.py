"""Paper Fig. 3: draft-token confidence vs acceptance rate, with REAL models.

A small target model is trained briefly on the synthetic corpus; the draft
model is a noise-perturbed copy (the realistic regime: draft approximates
target).  We run the actual SLED loop (core/engine_loop.py), collect
(confidence, accepted) pairs, and bin — the paper's finding is a strong
positive correlation, which is what licenses Eq. 1's confidence-thresholded
dynamic drafting.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.engine_loop import sled_generate
from repro.models.model_zoo import build_model
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def _trained_pair(vocab: int = 256, steps: int = 60, noise: float = 0.35):
    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=steps),
                       loss_chunk=16, attn_chunk=16)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    err = None
    dcfg = DataConfig(vocab_size=vocab, seq_len=33, global_batch=16, seed=3,
                      mode="markov", det_frac=0.85)
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, s).items()}
        params, opt, err, _ = step(params, opt, err, b)
    # draft = target + RELATIVE parameter noise (a weaker approximation of
    # the target, the realistic draft/target regime)
    keys = iter(jax.random.split(jax.random.key(42), 200))

    def perturb(p):
        if p.ndim < 2:
            return p
        scale = noise * jnp.std(p.astype(jnp.float32))
        return (p.astype(jnp.float32)
                + scale * jax.random.normal(next(keys), p.shape)).astype(p.dtype)

    draft = jax.tree.map(perturb, params)
    return model, params, draft, dcfg


def run(quick: bool = False) -> list:
    model, target_params, draft_params, dcfg = _trained_pair(
        steps=30 if quick else 60)
    prompts = jnp.asarray(batch_at(dcfg, 999)["tokens"][:4, :12])
    _, stats, pairs = sled_generate(
        model, draft_params, model, target_params, prompts,
        max_new=24 if quick else 48, k_max=6, greedy=True,
        collect_confidence=True,
    )
    pairs = np.array(pairs)  # (n, 2): confidence, accepted
    bins = np.array([0.0, 0.2, 0.4, 0.6, 0.8, 0.95, 1.0001])
    rows = []
    for lo, hi in zip(bins[:-1], bins[1:]):
        sel = (pairs[:, 0] >= lo) & (pairs[:, 0] < hi)
        if sel.sum() == 0:
            continue
        rows.append({
            "conf_bin": f"{lo:.2f}-{hi:.2f}",
            "acceptance_rate": round(float(pairs[sel, 1].mean()), 3),
            "n": int(sel.sum()),
        })
    # correlation is the paper's qualitative claim
    corr = float(np.corrcoef(pairs[:, 0], pairs[:, 1])[0, 1]) if len(pairs) > 2 else 0.0
    rows.append({"pearson_r": round(corr, 3),
                 "overall_acceptance": round(stats.acceptance_rate, 3)})
    emit(rows, "fig3_confidence")
    return rows


if __name__ == "__main__":
    run()
