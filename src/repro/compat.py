"""Version portability shims for the narrow band of jax APIs we use.

The repo targets the modern public API (``jax.shard_map``, ``jax.set_mesh``),
but CI and edge boxes pin older jax (0.4.x) where those live under
``jax.experimental.shard_map`` / don't exist.  Everything else we call is
stable across the supported range, so the shim surface stays tiny: import
``shard_map`` / ``use_mesh`` from here instead of ``jax``.
"""

from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` with fallback to the 0.4.x experimental entry point.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); both default
    off because our collectives intentionally produce per-shard values.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def use_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager activating ``mesh``: ``jax.set_mesh`` when available,
    else the legacy resource-env behaviour of ``with mesh:`` (jax 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
