"""Per-architecture sharding policy -> PartitionSpec pytrees.

Decisions (rationale in DESIGN.md §5):
  * attention: shard q heads over `model` when num_heads >= tp (GSPMD pads
    uneven head counts, e.g. qwen1.5's 40 heads); kv likewise — small-kv GQA
    (kv < tp) replicates kv heads (Megatron GQA convention) so the KV cache
    shards over batch only;
  * mlp: d_ff over `model` (every assigned arch has d_ff % 16 == 0);
  * vocab: embedding rows + lm_head columns over `model` (vocab-TP CE);
  * MoE: expert-parallel over `model` when E % tp == 0 (qwen3: 8/device),
    else expert-internal f-TP (granite-moe: 512/16) — matches the shard_map
    interior in models/layers.py:moe_block;
  * SSM: baseline replicates the (small) mamba weights over `model`; batch
    shards over `data`.  The §Perf hillclimb shards SSD heads explicitly;
  * FSDP (train mode): every matrix leaf additionally shards its largest
    remaining dim over `data` when divisible — opt-state masters/moments use
    the same spec (ZeRO-3 layout), GSPMD inserts the per-layer all-gathers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import MeshContext


@dataclasses.dataclass(frozen=True)
class Policy:
    cfg: Any
    mesh: Any
    mode: str  # "train" | "serve"
    fsdp: bool

    @property
    def tp(self) -> int:
        return self.mesh.shape["model"]

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def n_batch_shards(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def ctx(self) -> MeshContext:
        return MeshContext(mesh=self.mesh, batch_axes=self.batch_axes,
                           model_axis="model", fsdp=self.fsdp,
                           seq_shard_kv=self.seq_shard_kv())

    # -- parameters ---------------------------------------------------------
    # pjit rejects uneven input shardings, so every rule checks divisibility.

    def shard_attn_q(self) -> str:
        """'heads' | 'flat' | 'none'.

        'flat' shards the packed (H*hd) dim when H itself doesn't divide
        (qwen1.5's 40 heads): weight memory shards perfectly; GSPMD
        re-gathers activations around the per-head reshape (compute dup —
        an explicitly documented trade, see DESIGN.md §5).
        """
        cfg, tp = self.cfg, self.tp
        if cfg.num_heads == 0:
            return "none"
        if cfg.num_heads % tp == 0:
            return "heads"
        if (cfg.num_heads * cfg.head_dim) % tp == 0 and cfg.num_heads > tp:
            return "flat"
        return "none"

    def shard_attn_kv(self) -> bool:
        return self.cfg.num_kv_heads > 0 and self.cfg.num_kv_heads % self.tp == 0

    def seq_shard_kv(self) -> bool:
        """Sequence-shard attention caches when kv heads can't shard: the
        replicated cache would not fit (granite MQA: ~12 GB/device)."""
        cfg = self.cfg
        if self.mode == "train" or cfg.num_kv_heads == 0:
            return False
        return not self.shard_attn_kv()

    def shard_vocab(self) -> bool:
        return self.cfg.vocab_size % self.tp == 0

    def _fsdp_axis(self, spec: Tuple, shape: Tuple[int, ...], threshold: int = 1 << 21):
        """Add 'data' on the largest unsharded divisible dim of big leaves."""
        if not self.fsdp or "data" not in self.mesh.axis_names:
            return spec
        n = 1
        for s in shape:
            n *= s
        if n < threshold:
            return spec
        dp = self.mesh.shape["data"]
        cands = [
            (shape[i], i) for i in range(len(shape))
            if spec[i] is None and shape[i] % dp == 0 and shape[i] >= dp
        ]
        if not cands:
            return spec
        _, i = max(cands)
        out = list(spec)
        out[i] = "data"
        return tuple(out)

    def param_spec_for(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg, tp = self.cfg, self.tp
        name = path.split("/")[-1]
        stacked = "layers" in path  # leading L (or group) axes
        lead = ()
        # stacked layer params may have 1 (L) or 2 (group, in-group) lead axes
        if stacked:
            known_tail = {
                "wq": 2, "wk": 2, "wv": 2, "wo": 2, "bq": 1, "bk": 1, "bv": 1,
                "wg": 2, "wu": 2, "wd": 2, "wi": 2, "router": 2,
                "scale": 1, "bias": 1,
                "in_proj": 2, "conv_w": 2, "conv_b": 1, "A_log": 1, "D": 1,
                "dt_bias": 1, "gnorm": 1, "out_proj": 2,
            }
            in_moe = "/moe/" in path
            tail = known_tail.get(name, len(shape))
            if in_moe and name in ("wg", "wu", "wd"):
                tail = 3  # (E, d, f)
            lead = (None,) * (len(shape) - tail)
        body = shape[len(lead):]

        def out(*spec):
            return P(*self._fsdp_axis(lead + spec, shape))

        if name in ("scale", "bias", "conv_b", "A_log", "D", "dt_bias", "gnorm",
                    "pos_embed", "conv_w"):
            return P(*((None,) * len(shape)))
        if name == "embed":
            return out("model", None) if self.shard_vocab() else out(None, None)
        if name == "lm_head":
            return out(None, "model") if self.shard_vocab() else out(None, None)
        q_mode = self.shard_attn_q()
        kv_mode = self.shard_attn_kv() or (q_mode == "flat")
        if name in ("wq",):
            return out(None, "model") if q_mode != "none" else out(None, None)
        if name in ("wk", "wv"):
            return out(None, "model") if kv_mode else out(None, None)
        if name == "wo":
            return out("model", None) if q_mode != "none" else out(None, None)
        if name == "bq":
            return out("model") if q_mode != "none" else out(None)
        if name in ("bk", "bv"):
            return out("model") if kv_mode else out(None)
        if name == "router":
            return out(None, None)
        if "/moe/" in path and name in ("wg", "wu"):
            if cfg.num_experts % tp == 0:
                return out("model", None, None)
            return out(None, None, "model")
        if "/moe/" in path and name == "wd":
            if cfg.num_experts % tp == 0:
                return out("model", None, None)
            return out(None, "model", None)
        if name in ("wg", "wu", "wi"):  # dense mlp
            return out(None, "model")
        if name == "wd":
            return out("model", None)
        if name == "in_proj":
            return out(None, None)
        if name == "out_proj":
            return out(None, None)
        return P(*((None,) * len(shape)))

    def param_specs(self, params_tree: Any, fsdp: Optional[bool] = None) -> Any:
        """``fsdp`` override supports the ZeRO-2 layout: live params keep TP
        only (replicated over data: no per-microbatch all-gathers), while
        the fp32 master/moments stay fully sharded (§Perf iteration C2)."""
        pol = self if fsdp is None else dataclasses.replace(self, fsdp=fsdp)

        def walk(path, leaf):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
            return pol.param_spec_for(key, leaf.shape)

        return jax.tree_util.tree_map_with_path(walk, params_tree)

    # -- caches & batches ----------------------------------------------------

    def _bspec(self, batch_size: int):
        """Batch axis spec; replicate when the batch can't shard evenly."""
        if batch_size % max(self.n_batch_shards, 1) == 0 and batch_size >= self.n_batch_shards:
            ax = self.batch_axes
            return ax if len(ax) > 1 else ax[0] if ax else None
        return None

    def cache_spec_for(self, key: str, shape: Tuple[int, ...]) -> P:
        cfg = self.cfg
        if key == "length":
            return P(self._bspec(shape[0]))
        if key in ("cross_k", "cross_v"):  # (L, B, F, Hkv, hd) — encoder side
            h = "model" if self.shard_attn_kv() else None
            return P(None, self._bspec(shape[1]), None, h, None)
        if key in ("k", "v"):  # (L|n, B, S, Hkv, hd)
            if self.seq_shard_kv() and shape[2] % self.tp == 0:
                return P(None, self._bspec(shape[1]), "model", None, None)
            h = "model" if self.shard_attn_kv() else None
            return P(None, self._bspec(shape[1]), None, h, None)
        if key.startswith("ssm"):  # (L, B, H, P, N)
            h = "model" if cfg.ssm_heads % self.tp == 0 else None
            return P(None, self._bspec(shape[1]), h, None, None)
        if key.startswith("conv"):  # (L, B, w, C)
            return P(None, self._bspec(shape[1]), None, None)
        return P(*((None,) * len(shape)))

    def cache_specs(self, cache_tree: Any) -> Any:
        return {k: self.cache_spec_for(k, v.shape) for k, v in cache_tree.items()}

    def batch_specs(self, batch_tree: Any) -> Any:
        def spec(k, v):
            if v.ndim == 0:
                return P()
            return P(self._bspec(v.shape[0]), *((None,) * (v.ndim - 1)))

        return {k: spec(k, v) for k, v in batch_tree.items()}

    # -- helpers -------------------------------------------------------------

    def named(self, spec_tree: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )


def make_policy(cfg, mesh, *, mode: str = "serve", fsdp: Optional[bool] = None) -> Policy:
    if fsdp is None:
        fsdp = mode == "train"
    return Policy(cfg=cfg, mesh=mesh, mode=mode, fsdp=fsdp)
