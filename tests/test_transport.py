"""Transport runtime: codec round-trips, link invariants, end-to-end serving.

The load-bearing test mirrors PR 1's engine equivalence one level up the
stack: N async EdgeClients talking to a TransportServer over zero-latency
loopback links — the full wire protocol, admission, pipelined draft-ahead —
must commit exactly the tokens the lock-step reference loop commits.  The
network may change *when* things happen, never *what* is generated; only the
§III-A fallback (exercised with a deliberately lossy link) is allowed to
release unverified tokens, and even then client and server streams must
agree token-for-token with each other.
"""

import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine_loop import sled_generate
from repro.core.server_engine import EdgeDeviceKit, ServerEngine
from repro.models.model_zoo import build_model, perturb_params
from repro.serving.devices import NETS, NetProfile
from repro.transport import codec
from repro.transport.client import EdgeClient
from repro.transport.links import LoopbackLink, SimulatedLink, make_link
from repro.transport.server import TransportServer

V = 128


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------


def _roundtrip(msg):
    buf = codec.encode_frame(msg)
    out, used = codec.decode_frame(buf)
    assert used == len(buf)
    return out


def test_codec_roundtrip_all_messages():
    toks = np.asarray([5, 0, 127, 3], np.int32)
    hello = _roundtrip(codec.Hello(device_id=7, prompt=toks))
    assert hello.device_id == 7
    np.testing.assert_array_equal(hello.prompt, toks)

    admit = _roundtrip(codec.Admit(device_id=7, ok=True, slot=3))
    assert admit.ok and admit.slot == 3

    d = _roundtrip(codec.DraftPacket(device_id=1, seq=42, tokens=toks))
    assert (d.seq, d.qmode) == (42, "none") and d.draft_q is None
    np.testing.assert_array_equal(d.tokens, toks)

    v = _roundtrip(
        codec.Verdict(device_id=1, seq=42, n_accepted=2, tokens=toks[:3], next_prev=-1)
    )
    assert v.n_accepted == 2 and v.next_prev == -1 and v.flags == 0
    np.testing.assert_array_equal(v.tokens, toks[:3])

    f = _roundtrip(codec.Fallback(device_id=2, seq=9, tokens=toks))
    np.testing.assert_array_equal(f.tokens, toks)
    a = _roundtrip(codec.FallbackAck(device_id=2, seq=9, next_prev=77))
    assert a.next_prev == 77
    assert _roundtrip(codec.Close(device_id=3)).device_id == 3


def test_codec_empty_token_vector():
    d = _roundtrip(codec.DraftPacket(device_id=0, seq=0, tokens=np.zeros((0,), np.int32)))
    assert d.tokens.shape == (0,)


def test_codec_rejects_bad_frames():
    good = codec.encode_frame(codec.Close(device_id=1))
    with pytest.raises(codec.CodecError, match="magic"):
        codec.decode_frame(b"XX" + good[2:])
    with pytest.raises(codec.CodecError, match="version"):
        codec.decode_frame(good[:2] + bytes([99]) + good[3:])
    with pytest.raises(codec.CodecError, match="unknown message type"):
        codec.decode_frame(good[:3] + bytes([200]) + good[4:])
    # payload longer than the message needs -> trailing bytes
    padded = good[:4] + (len(good) - 8 + 2).to_bytes(4, "big") + good[8:] + b"\x00\x00"
    with pytest.raises(codec.CodecError, match="trailing"):
        codec.decode_frame(padded)


def test_codec_rejects_every_truncation():
    frame = codec.encode_frame(
        codec.DraftPacket(
            device_id=3, seq=1, tokens=np.asarray([1, 2, 3], np.int32),
            draft_q=np.asarray([0.5, 0.25, 0.125], np.float32), qmode="int8",
        )
    )
    for cut in range(len(frame)):
        with pytest.raises(codec.CodecError):
            codec.decode_frame(frame[:cut])


@pytest.mark.parametrize("qmode,atol", [("f32", 0.0), ("f16", 1e-3), ("int8", 1e-2)])
def test_codec_quantized_q_payload(qmode, atol):
    rngq = np.random.default_rng(0)
    q = rngq.uniform(0.0, 1.0, size=11).astype(np.float32)
    msg = codec.DraftPacket(
        device_id=0, seq=0, tokens=np.arange(11, dtype=np.int32), draft_q=q, qmode=qmode
    )
    out = _roundtrip(msg)
    assert out.qmode == qmode
    np.testing.assert_allclose(out.draft_q, q, atol=max(atol, 1e-7))
    # the whole point: quantized payloads are smaller on the wire
    size = {
        m: len(codec.encode_frame(dataclasses.replace(msg, qmode=m)))
        for m in ("f32", "f16", "int8")
    }
    assert size["int8"] < size["f16"] < size["f32"]


def test_codec_property_roundtrip():
    pytest.importorskip("hypothesis", reason="dev extra: pip install -e .[dev]")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        dev=st.integers(0, 2**32 - 1),
        seq=st.integers(0, 2**32 - 1),
        toks=st.lists(st.integers(-(2**31), 2**31 - 1), max_size=40),
        qmode=st.sampled_from(codec.QMODES),
        qseed=st.integers(0, 2**16),
    )
    def check(dev, seq, toks, qmode, qseed):
        toks = np.asarray(toks, np.int32)
        q = None
        if qmode != "none":
            q = np.random.default_rng(qseed).uniform(0, 1, size=len(toks)).astype(np.float32)
        out = _roundtrip(codec.DraftPacket(dev, seq, toks, draft_q=q, qmode=qmode))
        assert (out.device_id, out.seq, out.qmode) == (dev, seq, qmode)
        np.testing.assert_array_equal(out.tokens, toks)
        if qmode == "none":
            assert out.draft_q is None
        else:
            np.testing.assert_allclose(out.draft_q, q, atol=2e-2)

    check()


def test_frame_decoder_reassembles_byte_stream():
    frames = [
        codec.encode_frame(codec.Hello(1, np.asarray([1, 2], np.int32))),
        codec.encode_frame(codec.DraftPacket(1, 0, np.asarray([3], np.int32))),
        codec.encode_frame(codec.Close(1)),
    ]
    stream = b"".join(frames)
    dec = codec.FrameDecoder()
    got = []
    for i in range(0, len(stream), 3):  # arbitrary chunking
        dec.feed(stream[i : i + 3])
        got.extend(dec)
    assert [type(m).__name__ for m in got] == ["Hello", "DraftPacket", "Close"]


# ---------------------------------------------------------------------------
# links
# ---------------------------------------------------------------------------


def test_loopback_link_immediate_fifo():
    async def inner():
        link = LoopbackLink()
        for i in range(5):
            await link.device.send(bytes([i]))
        got = [await link.server.recv() for _ in range(5)]
        assert got == [bytes([i]) for i in range(5)]
        assert link.device.stats.frames_tx == 5 and link.server.stats.frames_rx == 5
        link.device.close()
        assert await link.server.recv() is None

    asyncio.run(inner())


def test_simulated_link_latency_and_order():
    net = NetProfile("t", rtt_mean=0.02, rtt_jitter=0.01, bandwidth_bps=1e6)

    async def inner():
        link = SimulatedLink(net, seed=3)
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        payloads = [bytes([i]) * 100 for i in range(10)]
        for p in payloads:
            await link.device.send(p)
        got, times = [], []
        for _ in payloads:
            got.append(await link.server.recv())
            times.append(loop.time() - t0)
        # jitter must never reorder (FIFO invariant) ...
        assert got == payloads
        assert times == sorted(times)
        # ... and every frame pays at least serialization + some propagation
        assert times[0] >= 100 * 8 / 1e6
        # 10 x 100B back-to-back on a 1 Mb/s line: serialization alone is 8ms
        assert times[-1] >= 10 * 100 * 8 / 1e6

    asyncio.run(inner())


def test_simulated_link_drop_accounting():
    net = NetProfile("lossy", rtt_mean=0.001, rtt_jitter=0.0, bandwidth_bps=1e9, drop_prob=1.0)

    async def inner():
        link = SimulatedLink(net, seed=0)
        for i in range(4):
            await link.device.send(bytes([i]))
        assert link.device.stats.frames_dropped == 4
        link.device.close()  # close still rides through
        assert await link.server.recv() is None

    asyncio.run(inner())


def test_make_link_factory():
    assert isinstance(make_link("loopback"), LoopbackLink)
    assert isinstance(make_link("sim", NETS["wlan"]), SimulatedLink)
    with pytest.raises(ValueError):
        make_link("sim")
    with pytest.raises(ValueError):
        make_link("tcp")


# ---------------------------------------------------------------------------
# end-to-end over the wire
# ---------------------------------------------------------------------------


def _models():
    tcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="tgt", vocab_size=V, num_layers=3
    )
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    dm, tm = build_model(dcfg), build_model(tcfg)
    dp = perturb_params(dm.init_params(jax.random.key(1)), 0.03)  # partial acceptance
    return dm, dp, tm, tm.init_params(jax.random.key(2))


def _run_fleet(dm, dp, tm, tp, prompts, *, policy, max_new, k_max=4, link_factory=None,
               verify_timeout=30.0, pipeline=True):
    n_dev = prompts.shape[0]
    engine = ServerEngine(
        tm, tp, n_slots=n_dev, max_len=128, k_max=k_max, policy=policy,
        max_wait=0.01, attn_chunk=32,
    )
    kit = EdgeDeviceKit(dm, dp, k_max=k_max, c_th=0.3, greedy=True, attn_chunk=32)
    retired = {}
    orig_retire = engine.retire
    engine.retire = lambda dev: retired.setdefault(dev, orig_retire(dev))

    async def inner():
        server = TransportServer(engine)
        clients = []
        for i in range(n_dev):
            link = link_factory(i) if link_factory else LoopbackLink()
            server.attach(link.server)
            clients.append(
                EdgeClient(
                    kit, i, np.asarray(prompts[i]), link.device,
                    max_new=max_new, max_len=128, pipeline=pipeline,
                    verify_timeout=verify_timeout, admit_timeout=verify_timeout,
                    seed=100 + i,
                )
            )
        outs = await asyncio.gather(*(c.run() for c in clients))
        for _ in range(500):
            if not engine.streams:
                break
            await asyncio.sleep(0.01)
        stats = server.stats()
        await server.stop()
        return outs, clients, stats

    outs, clients, stats = asyncio.run(inner())
    return outs, clients, stats, retired


def test_transport_loopback_matches_lockstep_reference():
    """Zero-latency loopback, continuous policy, pipelining on: the full wire
    path must be output-identical to sled_generate."""
    dm, dp, tm, tp = _models()
    B, max_new = 3, 10
    prompts = jax.random.randint(jax.random.key(3), (B, 12), 0, V)
    outs, clients, stats, _ = _run_fleet(
        dm, dp, tm, tp, prompts, policy="continuous", max_new=max_new
    )
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(np.array(outs), np.asarray(ref))
    assert stats.streams_served == B
    assert stats.bytes_rx > 0 and stats.bytes_tx > 0  # wire stats populated
    assert stats.fallback_tokens == 0
    # rejections happened, so the pipelined speculation must have missed too
    assert stats.acceptance_rate < 1.0
    assert sum(c.stats.pipeline_misses for c in clients) > 0


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["static", "deadline"])
def test_transport_loopback_all_policies(policy):
    dm, dp, tm, tp = _models()
    B, max_new = 2, 8
    prompts = jax.random.randint(jax.random.key(4), (B, 12), 0, V)
    outs, _, _, _ = _run_fleet(dm, dp, tm, tp, prompts, policy=policy, max_new=max_new)
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(np.array(outs), np.asarray(ref))


@pytest.mark.slow
def test_transport_sim_link_matches_reference():
    """Latency and jitter (lossless) reorder nothing and change no tokens."""
    dm, dp, tm, tp = _models()
    B, max_new = 2, 8
    prompts = jax.random.randint(jax.random.key(5), (B, 12), 0, V)
    fast = NetProfile("fast", rtt_mean=0.004, rtt_jitter=0.002, bandwidth_bps=1e8)
    outs, _, _, _ = _run_fleet(
        dm, dp, tm, tp, prompts, policy="continuous", max_new=max_new,
        link_factory=lambda i: SimulatedLink(fast, seed=i),
    )
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(np.array(outs), np.asarray(ref))


class _DropNthDraft(LoopbackLink):
    """Loopback that eats exactly the n-th DraftPacket on the uplink."""

    def __init__(self, n: int):
        super().__init__()
        self._n = n
        self._count = 0
        inner_put = self.device._out.put

        async def put(frame):
            msg, _ = codec.decode_frame(frame)
            if isinstance(msg, codec.DraftPacket):
                self._count += 1
                if self._count == self._n:
                    self.device.stats.frames_dropped += 1
                    return
            await inner_put(frame)

        self.device._out.put = put


@pytest.mark.slow
def test_transport_fallback_resync_on_lost_request():
    """A lost DraftPacket times out device-side: the device releases its
    drafts locally (§III-A) and the server force-extends the stream, so both
    sides stay token-identical even though the round was never verified."""
    dm, dp, tm, tp = _models()
    max_new = 10
    prompts = jax.random.randint(jax.random.key(6), (1, 12), 0, V)
    link = _DropNthDraft(2)
    outs, clients, stats, retired = _run_fleet(
        dm, dp, tm, tp, prompts, policy="continuous", max_new=max_new,
        link_factory=lambda i: link, verify_timeout=1.5,
    )
    c = clients[0]
    assert c.stats.fallback_rounds == 1
    assert c.stats.fallback_tokens > 0
    assert stats.fallback_tokens == c.stats.fallback_tokens
    assert stats.fallback_rounds == 1
    assert len(outs[0]) == max_new
    # client and server committed streams agree exactly, including the
    # unverified fallback run
    assert retired[0].committed == c.device.committed


def test_transport_client_reconnects_after_midround_link_death():
    """Regression: a link severed mid-round (server's sending half closed,
    verdict lost with it) used to escape as a ConnectionError and kill the
    session coroutine.  With a reconnect hook the client redials, re-Hellos
    (the server resends Admit for the admitted stream), resyncs the open
    round through Fallback arbitration — and the committed stream stays
    token-identical to the lock-step reference."""
    dm, dp, tm, tp = _models()
    max_new = 10
    prompts = jax.random.randint(jax.random.key(8), (1, 12), 0, V)
    engine = ServerEngine(
        tm, tp, n_slots=1, max_len=128, k_max=4, policy="continuous",
        max_wait=0.01, attn_chunk=32,
    )
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)

    async def inner():
        server = TransportServer(engine)
        link = LoopbackLink()
        server.attach(link.server)

        async def redial():
            fresh = LoopbackLink()
            server.attach(fresh.server)
            return fresh.device

        client = EdgeClient(
            kit, 0, np.asarray(prompts[0]), link.device,
            max_new=max_new, max_len=128,
            verify_timeout=0.5, admit_timeout=0.5, seed=100,
            reconnect=redial,
        )

        # sever the ORIGINAL link as the 2nd verdict goes out: the verdict
        # is lost with the link, so the client sees a dead socket mid-round
        orig_send = server._send
        sent = {"verdicts": 0}

        async def chaotic_send(dev, frame):
            msg, _ = codec.decode_frame(frame)
            if isinstance(msg, codec.Verdict):
                sent["verdicts"] += 1
                if sent["verdicts"] == 2:
                    link.server.close()
                    return  # frame dies with the link
            await orig_send(dev, frame)

        server._send = chaotic_send
        out = await client.run()
        for _ in range(500):
            if not engine.streams:
                break
            await asyncio.sleep(0.01)
        await server.stop()
        return out, client, server

    out, client, server = asyncio.run(inner())
    assert client.stats.reconnects == 1, "exactly one redial should heal it"
    assert client.stats.late_verdicts >= 1  # round resolved by resent verdict
    assert server.late_verdicts_resent >= 1
    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=4, c_th=0.3, greedy=True
    )
    np.testing.assert_array_equal(np.array([out]), np.asarray(ref))


def test_transport_client_without_hook_still_raises():
    """No reconnect hook installed -> legacy behavior: the ConnectionError
    escapes (callers that want the old semantics keep them)."""
    dm, dp, _, _ = _models()
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)

    async def inner():
        link = LoopbackLink()
        client = EdgeClient(
            kit, 0, np.arange(8, dtype=np.int32), link.device,
            max_new=4, max_len=64, admit_timeout=0.2, seed=1,
        )
        link.server.close()  # server side gone before admission
        with pytest.raises(ConnectionError):
            await client._recv(1.0)
        with pytest.raises(ConnectionError):
            await client._redial(ConnectionError("boom"))

    asyncio.run(inner())


# ---------------------------------------------------------------------------
# engine hooks behind the transport
# ---------------------------------------------------------------------------


def test_engine_cancel_and_force_extend():
    _, _, tm, tp = _models()
    engine = ServerEngine(tm, tp, n_slots=1, max_len=64, k_max=4, attn_chunk=32)
    prompt = jax.random.randint(jax.random.key(7), (8,), 0, V)
    engine.admit(0, prompt, 0.0)
    assert not engine.cancel_request(0)  # nothing queued
    engine.submit(0, np.asarray([1, 2, 3], np.int32), 0.0)
    assert engine.cancel_request(0)
    assert engine.queue_depth == 0

    before_len = int(engine.pool.lengths()[0])
    stream = engine.streams[0]
    prev = engine.force_extend(0, np.asarray([9, 8, 7], np.int32))
    assert prev == 7 and stream.prev_token == 7
    assert stream.committed[-3:] == [9, 8, 7]
    assert int(engine.pool.lengths()[0]) == before_len + 3
    assert engine.stats(1.0).fallback_tokens == 3
    # the stream still verifies fine from the resynced tail
    engine.submit(0, np.asarray([1], np.int32), 1.0)
    verdicts = engine.step(1.1)
    assert verdicts and verdicts[0].device_id == 0
