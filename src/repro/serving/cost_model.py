"""Paper Eq. 2: $ per 1K generated tokens from CAPEX + OPEX.

    Cost = 1000/(3600 R) * ( P_device/(3*8760*0.70) + P_avg/1000 * 0.083 )

R = tokens/s, P_device = purchase price, P_avg = watts; 3-year amortisation
at 70% utilisation; electricity 0.083 $/kWh.  Applies uniformly to edge
devices and the shared server (server cost is divided across the devices it
serves, proportional to their verification usage).
"""
from __future__ import annotations

from repro.serving.devices import ELECTRICITY_USD_PER_KWH, DeviceProfile, ServerProfile

AMORT_HOURS = 3 * 8760 * 0.70


def hourly_cost(price_usd: float, power_w: float) -> float:
    return price_usd / AMORT_HOURS + power_w / 1000.0 * ELECTRICITY_USD_PER_KWH


def cost_per_1k_tokens(rate_tok_s: float, price_usd: float, power_w: float) -> float:
    """Eq. 2 verbatim."""
    if rate_tok_s <= 0:
        return float("inf")
    return 1000.0 / (3600.0 * rate_tok_s) * (
        price_usd / AMORT_HOURS + power_w / 1000.0 * ELECTRICITY_USD_PER_KWH
    )


def sled_cost_per_1k(device_rate: float, device: DeviceProfile,
                     server: ServerProfile, server_share: float) -> float:
    """SLED: device cost + the device's share of the shared server.

    ``server_share`` = fraction of server capacity this device consumes
    (verification-only — the SLED cost advantage the paper claims: devices
    pay for verification cycles, not full generation).
    """
    if device_rate <= 0:
        return float("inf")
    dev = hourly_cost(device.price_usd, device.power_w)
    srv = hourly_cost(server.price_usd, server.power_w) * server_share
    return 1000.0 / (3600.0 * device_rate) * (dev + srv)


def fleet_cost_per_1k(
    class_rates: list, server: ServerProfile, *, server_busy_frac: float = 1.0
) -> float:
    """Eq. 2 over a heterogeneous fleet: ``class_rates`` is
    ``[(count, committed_tok_s_per_device, DeviceProfile), ...]`` — one
    entry per device class.  Device hours are paid per class; the ONE
    shared server's hourly cost (scaled by how busy verification keeps it)
    is spread over every token the fleet commits, which is what makes
    packing slow cheap devices next to fast ones pay off."""
    total_rate = sum(n * r for n, r, _ in class_rates)
    if total_rate <= 0:
        return float("inf")
    dev_hourly = sum(n * hourly_cost(p.price_usd, p.power_w) for n, _, p in class_rates)
    srv_hourly = hourly_cost(server.price_usd, server.power_w) * server_busy_frac
    return 1000.0 / (3600.0 * total_rate) * (dev_hourly + srv_hourly)
