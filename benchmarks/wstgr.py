"""Paper Fig. 4: Whole-System Token Generation Rate vs server batch size.

SLED vs centralized serving for 11B and 70B target models; the server is
kept saturated (N = 8x batch devices) so WSTGR reflects server-side
efficiency.  Expected shape: WSTGR rises with batch (weight-stream
amortisation), SLED sits >2x above centralized at equal batch — the paper's
x2.2 system-throughput claim.

``--engine`` switches to the REAL continuous-batching engine
(core/server_engine.py) with tiny models: the same SimResult-style fields
(wstgr, mean_batch_fill, rounds) are measured from an actual serving run and
emitted next to the discrete-event simulator's prediction for a matched
arrival pattern, so simulator claims can be cross-checked end-to-end.

``--transport`` goes one level further: the fleet runs over the async
transport runtime (wire protocol + SimulatedLink with the paper's WLAN
RTT/jitter), and the measured runtime stats — wstgr, batch fill, queue
depth, bytes on the wire — are cross-checked against the discrete-event
simulator's prediction for the SAME network profile, with the simulator's
device rate / acceptance / server latency calibrated from the measured run
(the sim predicts *dynamics*, the calibration pins the *rates*).  The wstgr
ratio is expected within 15%.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import emit
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, simulate


def run(quick: bool = False) -> list:
    rows = []
    batches = (1, 2, 4, 8, 16, 32) if not quick else (2, 8, 32)
    for target_p, tname in ((11e9, "11B"), (70e9, "70B")):
        for b in batches:
            base = SimConfig(
                mode="sled", spec_len=4, acceptance=0.90,
                device_rate=RPI5.rate("llama-3b-draft", 4),
                target_params=target_p, server_batch=b,
                batch_policy="deadline", n_devices=64 * b,
                sim_time=10.0 if quick else 20.0,
            )
            sled = simulate(base, A100_X4)
            cent = simulate(dataclasses.replace(base, mode="centralized"), A100_X4)
            rows.append({
                "target": tname, "batch": b,
                "wstgr_sled": round(sled.wstgr, 1),
                "wstgr_centralized": round(cent.wstgr, 1),
                "ratio": round(sled.wstgr / max(cent.wstgr, 1e-9), 2),
                "sled_busy": round(sled.server_busy_frac, 2),
            })
    emit(rows, "fig4_wstgr")
    return rows


def run_engine(quick: bool = False) -> list:
    """Real-model continuous batching: serve a small staggered fleet through
    ServerEngine per policy and report measured SimResult-style stats next to
    the simulator's batch-fill prediction for the same fleet."""
    import jax

    from repro.configs.base import get_config
    from repro.core.server_engine import EdgeDeviceKit, ServerEngine
    from repro.models.model_zoo import build_model

    vocab = 128
    tcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    dcfg = dataclasses.replace(tcfg, name="draft", num_layers=1)
    target, draft = build_model(tcfg), build_model(dcfg)
    tp = target.init_params(jax.random.key(0))
    dp = draft.init_params(jax.random.key(1))

    n_dev, max_new, k_max = (3, 8, 4) if quick else (6, 16, 4)
    prompts = jax.random.randint(jax.random.key(2), (n_dev, 12), 0, vocab)
    rows = []
    for policy in (("continuous",) if quick else ("continuous", "deadline")):
        engine = ServerEngine(target, tp, n_slots=n_dev, max_len=128, k_max=k_max,
                              policy=policy, max_wait=0.0, attn_chunk=32)
        kit = EdgeDeviceKit(draft, dp, k_max=k_max, c_th=0.3, greedy=True, attn_chunk=32)
        devices, outputs = {}, {}
        t0 = time.time()
        tick = 0
        while len(outputs) < n_dev:
            tick += 1
            for i in range(n_dev):
                if i not in devices and i not in outputs and i * 2 <= tick:
                    engine.admit(i, prompts[i], time.time() - t0)
                    devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=i)
            for i, dev in devices.items():
                if not dev.awaiting:
                    engine.submit(i, dev.draft(), time.time() - t0)
            verdicts = engine.step(time.time() - t0)
            for v in verdicts or []:
                devices[v.device_id].on_verdict(v)
                if len(devices[v.device_id].committed) >= max_new:
                    outputs[v.device_id] = devices[v.device_id].committed[:max_new]
                    engine.retire(v.device_id)
                    del devices[v.device_id]
        st = engine.stats(time.time() - t0)
        sim = simulate(
            SimConfig(mode="sled", n_devices=n_dev, spec_len=k_max,
                      server_batch=n_dev, batch_policy=policy,
                      sim_time=5.0 if quick else 10.0),
            A100_X4,
        )
        rows.append({
            "policy": policy,
            "wstgr_measured": round(st.wstgr, 1),
            "mean_batch_fill": round(st.mean_batch_fill, 2),
            "partial_rounds": st.partial_rounds,
            "rounds": st.rounds,
            "sim_mean_batch_fill": round(sim.mean_batch_fill, 2),
        })
    emit(rows, "engine_wstgr")
    return rows


def _solve_acceptance(tokens_per_round: float, k: int) -> float:
    """alpha such that the simulator's E[tokens/round] = 1 + sum_i alpha^i
    matches the measured rate (truncated-geometric acceptance model)."""
    lo, hi = 0.0, 1.0
    for _ in range(48):
        mid = (lo + hi) / 2
        if 1.0 + sum(mid**i for i in range(1, k + 1)) < tokens_per_round:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def run_transport(quick: bool = False) -> list:
    """Async transport runtime over simulated WLAN links vs the discrete-event
    simulator under a matched network/rate configuration."""
    import asyncio

    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.core.server_engine import EdgeDeviceKit, ServerEngine
    from repro.models.model_zoo import build_model, perturb_params
    from repro.serving.devices import NETS, RPI5, ServerProfile
    from repro.transport.client import ClientStats, EdgeClient
    from repro.transport.links import make_link
    from repro.transport.server import TransportServer

    vocab = 128
    tcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="tgt", vocab_size=vocab, num_layers=3
    )
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    target, draft = build_model(tcfg), build_model(dcfg)
    tp = target.init_params(jax.random.key(0))
    # random-init pairs agree greedily (acceptance 1.0); perturb to ~0.9
    dp = perturb_params(draft.init_params(jax.random.key(1)), 0.02)

    n_dev, max_new, k_max = (3, 10, 4) if quick else (6, 24, 4)
    net = NETS["wlan"]  # paper-style service-area RTT/jitter
    # emulate RPi5-class drafting (int4 1B draft): reduced models draft far
    # faster than real boards, and the throttle also restores fleet
    # concurrency that single-process compute would otherwise serialize
    device_rate = RPI5.rate("llama-1b-draft", 4)
    rows = []
    for policy in (("continuous",) if quick else ("continuous", "deadline")):
        engine = ServerEngine(
            target, tp, n_slots=n_dev, max_len=128, k_max=k_max,
            policy=policy, max_wait=0.02, attn_chunk=32,
        )
        kit = EdgeDeviceKit(draft, dp, k_max=k_max, c_th=0.0, greedy=True, attn_chunk=32)

        async def fleet(ids, new_tokens, engine=engine, kit=kit):
            server = TransportServer(engine)
            clients = []
            for j, i in enumerate(ids):
                prompt = np.asarray(
                    jax.random.randint(jax.random.key(i), (12,), 0, vocab)
                )
                link = make_link("sim", net=net, seed=i)
                server.attach(link.server)
                clients.append(
                    EdgeClient(
                        kit, i, prompt, link.device, max_new=new_tokens, max_len=128,
                        pipeline=True, verify_timeout=30.0, draft_rate=device_rate,
                        seed=i,
                    )
                )
            t0 = time.time()
            await asyncio.gather(*(c.run() for c in clients))
            wall = time.time() - t0
            for _ in range(500):
                if not engine.streams:
                    break
                await asyncio.sleep(0.01)
            st = server.stats()
            await server.stop()
            return clients, st, wall

        # warm every verify bucket plus the client-side jits (prefill, draft,
        # peek) so the measured fleet below sees steady-state latencies
        engine.warmup()
        asyncio.run(fleet(range(n_dev), 4))
        r0, d0, a0 = len(engine.round_log), engine._drafted, engine._accepted
        f0 = engine._fallback_tokens
        clients, st, wall = asyncio.run(fleet(range(100, 100 + n_dev), max_new))
        fleet_stats = ClientStats.merge([c.stats for c in clients])

        log = engine.round_log[r0:]
        committed = sum(r.n_commit for r in log)
        # per-request committed tokens per verify round (sim: 1 + E[m])
        tokens_per_round = committed / max(sum(r.size for r in log), 1)
        step_s = float(np.median([r.step_seconds for r in log]))
        fill = sum(r.size for r in log) / max(len(log), 1)
        qdepth = sum(r.queue_depth for r in log) / max(len(log), 1)
        wstgr_meas = n_dev * max_new / wall
        accept_ratio = (engine._accepted - a0) / max(engine._drafted - d0, 1)

        # the simulator predicts the *dynamics* (batching, RTT overlap,
        # draft-ahead) given the rates we measured on the real runtime
        measured_server = ServerProfile(
            name="measured-cpu", price_usd=0.0, power_w=0.0,
            peak_flops=1e30, hbm_bw=1e30, launch_overhead_s=step_s,
        )
        sim = simulate(
            SimConfig(
                mode="sled", n_devices=n_dev, spec_len=k_max,
                acceptance=_solve_acceptance(tokens_per_round, k_max),
                device_rate=device_rate, server_batch=n_dev,
                batch_policy=policy, max_wait=0.02,
                rtt_mean=net.rtt_mean, rtt_jitter=net.rtt_jitter,
                draft_ahead=k_max, sim_time=30.0, verify_timeout=30.0,
            ),
            measured_server,
        )
        rows.append({
            "policy": policy,
            "wstgr_measured": round(wstgr_meas, 2),
            "wstgr_sim": round(sim.wstgr, 2),
            "wstgr_ratio": round(wstgr_meas / max(sim.wstgr, 1e-9), 3),
            "mean_batch_fill": round(fill, 2),
            "sim_mean_batch_fill": round(sim.mean_batch_fill, 2),
            "mean_queue_depth": round(qdepth, 2),
            "acceptance": round(accept_ratio, 3),
            "device_rate_tok_s": round(device_rate, 1),
            "verify_step_s": round(step_s, 4),
            "pipeline_hits": fleet_stats.pipeline_hits,
            "pipeline_misses": fleet_stats.pipeline_misses,
            "bytes_up": st.bytes_rx,
            "bytes_down": st.bytes_tx,
            "frames": st.frames_rx + st.frames_tx,
            "frames_dropped": st.frames_dropped + fleet_stats.frames_dropped,
            "fallback_tokens": st.fallback_tokens - f0,  # this fleet only
        })
        ok = abs(rows[-1]["wstgr_ratio"] - 1.0) <= 0.15
        print(
            f"[{policy}] measured {wstgr_meas:.2f} tok/s vs sim {sim.wstgr:.2f} "
            f"(ratio {rows[-1]['wstgr_ratio']:.3f}) — {'OK' if ok else 'OUTSIDE 15%'}"
        )
    emit(rows, "transport_wstgr")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="run the real-model continuous-batching engine")
    ap.add_argument("--transport", action="store_true",
                    help="run the async transport runtime over simulated links "
                         "and cross-check against the discrete-event simulator")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    fn = run_transport if a.transport else (run_engine if a.engine else run)
    fn(quick=a.quick)
