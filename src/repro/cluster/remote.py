"""RemoteReplica: the Router's proxy for a worker process on the far side
of a socket.

The Router (cluster/router.py) drives every replica through one synchronous
surface — admit / submit / step / retire / export / import / stats / warmup.
A :class:`RemoteReplica` implements that surface by proxying each call over
codec v3 control frames on a blocking :class:`ControlChannel` (plain socket
+ FrameDecoder; the Router stays synchronous, and concurrency across
workers comes from the Router stepping its remotes on a thread pool).

Client-side SHADOW state keeps the hot paths local: the replica mirrors
each stream's server-side record (slot, prev token, committed tokens,
lifetime counters) from admit/verdict/retire traffic, so placement
decisions (``n_free``, ``streams``, ``has_inflight``) never pay a round
trip — only actual engine work (admit's prefill, step's verification,
migration's row copy) crosses the wire.

Supervision is reconnect-or-evict: a transport failure on a SIDE-EFFECT-FREE
RPC (stats) is retried once over a fresh connection.  Side-effectful RPCs
(admit / submit / step / retire / migration) carry a codec-v4 per-channel
``seq``, so when the Router's :class:`~repro.api.spec.FaultPolicy` enables
``retry_rpcs`` they too get ONE reconnect-and-resend — the worker's replay
cache returns the original reply if the first copy landed, so the retry can
never double-apply a round.  A failure that survives the retry raises
:class:`ReplicaGone` and the Router evicts (and, policy permitting,
revives) the replica.  A worker-side handler error arrives as an ErrorReply
and raises :class:`WorkerError` (the worker is alive; the request was just
invalid).
"""

from __future__ import annotations

import json
import os
import shutil
import socket
import subprocess
import sys
import tempfile
import time
import uuid
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.admission import DeviceStream
from repro.core.engine import EngineStats, Verdict
from repro.transport import codec
from repro.transport.links import parse_addr

DEFAULT_TIMEOUT = 120.0  # control RPCs; crash shows up as EOF, not timeout
WARMUP_TIMEOUT = 900.0  # warmup compiles every verify bucket


class ReplicaGone(ConnectionError):
    """The worker is unreachable (crash, kill, network partition)."""


class WorkerError(ValueError):
    """The worker handled the request and rejected it (engine-level error)."""


class ControlChannel:
    """Blocking request/reply frame channel to one worker (TCP or UDS)."""

    def __init__(self, address: str, *, timeout: float = DEFAULT_TIMEOUT):
        self.address = address
        self.timeout = timeout
        self._sock: Optional[socket.socket] = None
        self._decoder = codec.FrameDecoder()
        self._seq = 0  # per-channel RPC seq (v4 replay keys); 0 = unused

    def next_seq(self) -> int:
        """Monotonic non-zero seq for side-effectful RPCs.  Survives
        reconnects of THIS channel (the worker's replay cache is keyed by
        it); a respawned worker gets a fresh channel and a fresh count."""
        self._seq += 1
        return self._seq

    def connect(self) -> None:
        parsed = parse_addr(self.address)
        try:
            if parsed[0] == "uds":
                sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                sock.settimeout(self.timeout)
                sock.connect(parsed[1])
            else:
                sock = socket.create_connection(
                    (parsed[1], parsed[2]), timeout=self.timeout
                )
        except OSError as e:
            raise ReplicaGone(f"cannot dial worker at {self.address}: {e}") from e
        self._sock = sock
        self._decoder = codec.FrameDecoder()

    @property
    def connected(self) -> bool:
        return self._sock is not None

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def reconnect(self) -> None:
        self.close()
        self.connect()

    def request(self, msg: codec.Message, *, timeout: Optional[float] = None):
        """Send one frame, block for its reply.  ErrorReply -> WorkerError;
        any transport failure -> ReplicaGone (this channel is closed)."""
        if self._sock is None:
            self.connect()
        sock = self._sock
        try:
            if timeout is not None:
                sock.settimeout(timeout)
            sock.sendall(codec.encode_frame(msg))
            while True:
                raw = self._decoder.next_raw()
                if raw is not None:
                    break
                data = sock.recv(65536)
                if not data:
                    raise ReplicaGone(
                        f"worker at {self.address} closed the control connection"
                    )
                self._decoder.feed(data)
        except ReplicaGone:
            self.close()
            raise
        except (OSError, codec.CodecError) as e:
            self.close()
            raise ReplicaGone(f"worker at {self.address} failed: {e}") from e
        finally:
            if timeout is not None and self._sock is not None:
                self._sock.settimeout(self.timeout)
        reply, _ = codec.decode_frame(raw)
        if isinstance(reply, codec.ErrorReply):
            raise WorkerError(reply.message)
        return reply


def repro_python_env() -> dict:
    """Env for a spawned worker: this interpreter's repro must be importable
    even when the parent runs from a source tree via PYTHONPATH=src."""
    import repro

    env = dict(os.environ)
    pkg_dir = (  # namespace packages have __file__=None; __path__ still points in
        os.path.dirname(repro.__file__) if getattr(repro, "__file__", None)
        else list(repro.__path__)[0]
    )
    src_root = os.path.dirname(os.path.abspath(pkg_dir))
    parts = [src_root] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    return env


def worker_sock_dir(address: str) -> Optional[str]:
    """The private ``repro-worker-*`` temp dir behind a spawned worker's UDS
    address, or None when the address is not one of ours."""
    if not address.startswith("uds:"):
        return None
    d = os.path.dirname(address[len("uds:"):])
    if os.path.basename(d).startswith("repro-worker-"):
        return d
    return None


def cleanup_worker_dir(address: str) -> None:
    """Remove the private socket dir a spawned worker was listening under."""
    d = worker_sock_dir(address)
    if d is not None:
        shutil.rmtree(d, ignore_errors=True)


def kill_worker_proc(proc: Optional[subprocess.Popen], *, wait_s: float = 5.0) -> None:
    """Reap a worker subprocess: terminate, bounded wait, then kill —
    a SIGTERM the worker ignores (hung in a compile, SIGSTOPped by the
    chaos harness) must not leave a zombie behind."""
    if proc is None or proc.poll() is not None:
        return
    proc.terminate()
    try:
        proc.wait(timeout=wait_s)
    except subprocess.TimeoutExpired:
        proc.kill()
        try:
            proc.wait(timeout=wait_s)
        except subprocess.TimeoutExpired:
            pass


def spawn_worker(
    address: Optional[str] = None,
    *,
    spec_path: str = "",
    startup_timeout: float = 120.0,
):
    """Start a ``repro worker`` subprocess and wait until it accepts a dial.

    Returns ``(proc, address)``.  Without an explicit address the worker
    listens on a fresh UDS socket under a private temp dir (no port to
    guess, no parsing of the worker's stdout); the dir is removed by
    RemoteReplica.close()/drain(), or here if startup fails."""
    made_dir = None
    if address is None:
        made_dir = tempfile.mkdtemp(prefix="repro-worker-")
        address = f"uds:{os.path.join(made_dir, uuid.uuid4().hex[:8] + '.sock')}"
    cmd = [sys.executable, "-m", "repro.cli", "worker", "--listen", address]
    if spec_path:
        cmd += ["--spec", spec_path]
    proc = subprocess.Popen(
        cmd, env=repro_python_env(), stdout=subprocess.DEVNULL
    )
    deadline = time.time() + startup_timeout
    probe = ControlChannel(address, timeout=5.0)
    while True:
        if proc.poll() is not None:
            if made_dir is not None:
                shutil.rmtree(made_dir, ignore_errors=True)
            raise RuntimeError(
                f"worker exited with code {proc.returncode} during startup "
                f"(cmd: {' '.join(cmd)})"
            )
        try:
            probe.connect()
            probe.close()
            return proc, address
        except ReplicaGone:
            if time.time() > deadline:
                kill_worker_proc(proc)
                if made_dir is not None:
                    shutil.rmtree(made_dir, ignore_errors=True)
                raise RuntimeError(
                    f"worker at {address} did not come up within {startup_timeout}s"
                ) from None
            time.sleep(0.05)


class RemoteReplica:
    """One worker process behind the replica driver surface.

    Mirrors the parts of :class:`~repro.core.server_engine.ServerEngine`
    the Router and the serving loops touch; see the module docstring for
    the shadow-state and supervision rules.
    """

    flavor = "remote"

    def __init__(
        self,
        channel: ControlChannel,
        *,
        address: str = "",
        proc: Optional[subprocess.Popen] = None,
    ):
        self.channel = channel
        self.address = address or channel.address
        self.proc = proc  # set when this replica spawned its worker
        self.spawned = proc is not None  # revive() respawns vs redials
        self.dead = False
        self.suspect = False  # heartbeat monitor: peer stopped answering
        self.retry_rpcs = False  # FaultPolicy: one-shot retry over reconnect
        self.retries = 0
        self.spec = None  # the placed ServeSpec subtree (revive re-places it)
        self._placed = False
        self._n_slots = 0
        self.k_max = 0
        self.max_len = 0
        self.greedy = True
        self.paged_attention = True
        self._streams: Dict[int, DeviceStream] = {}
        self._pending: Dict[int, int] = {}  # device -> tokens in flight
        self._queue_depth = 0
        self._hint: Optional[float] = None
        self.last_telemetry: Optional[dict] = None  # worker payload from stats()
        self._hb_channel: Optional[ControlChannel] = None  # heartbeat probes
        # tests/chaos override: how revive() obtains a fresh channel; the
        # default respawns the worker process or redials the address
        self.channel_factory: Optional[Callable[[], ControlChannel]] = None

    @classmethod
    def dial(cls, address: str, *, timeout: float = DEFAULT_TIMEOUT) -> "RemoteReplica":
        channel = ControlChannel(address, timeout=timeout)
        channel.connect()
        return cls(channel, address=address)

    # -- placement -----------------------------------------------------------

    def place(self, spec) -> None:
        """Ship the ServeSpec subtree; the worker builds its engine from it.
        The spec is kept so a supervised revive() can re-place it."""
        ack = self.channel.request(
            codec.PlaceReplica(spec.to_json_str()), timeout=WARMUP_TIMEOUT
        )
        if not isinstance(ack, codec.PlaceAck):
            raise WorkerError(f"expected PlaceAck, got {type(ack).__name__}")
        if not ack.ok:
            raise WorkerError(f"worker at {self.address} refused placement: {ack.error}")
        self.spec = spec
        self._placed = True
        self._n_slots = ack.n_slots
        self.k_max = ack.k_max
        self.max_len = ack.max_len
        self.greedy = ack.greedy
        self.paged_attention = ack.paged_attention

    # -- supervision: retryable RPCs, chaos hooks, revive ---------------------

    def _request(self, msg: codec.Message, *, timeout: Optional[float] = None):
        """Side-effectful RPC with v4 replay protection.  The frame already
        carries a fresh non-zero seq; when ``retry_rpcs`` is on, one
        ReplicaGone is absorbed by reconnecting and RESENDING the same frame
        — the worker's replay cache dedups it if the first copy landed."""
        try:
            return self.channel.request(msg, timeout=timeout)
        except ReplicaGone:
            if not self.retry_rpcs or getattr(msg, "seq", 0) == 0:
                raise
            self.retries += 1
            self.channel.reconnect()
            return self.channel.request(msg, timeout=timeout)

    def ping(self, *, timeout: float = 2.0) -> bool:
        """Heartbeat probe on a DEDICATED channel — the main channel is
        driven by the router thread and is not shareable.  False on any
        failure (dial refused, timeout, bad reply); the failed channel is
        torn down so the next probe redials from scratch."""
        try:
            if self._hb_channel is None:
                self._hb_channel = ControlChannel(self.address, timeout=timeout)
                self._hb_channel.connect()
            reply = self._hb_channel.request(
                codec.Ping(seq=self._hb_channel.next_seq(), t=time.monotonic()),
                timeout=timeout,
            )
            return isinstance(reply, codec.Pong)
        except Exception:
            ch, self._hb_channel = self._hb_channel, None
            if ch is not None:
                ch.close()
            return False

    def chaos_kill(self) -> None:
        """Deterministic fault injection: make this worker unreachable the
        way a real crash would — SIGKILL a spawned process, or sever the
        control link of a dialed/faked one.  The Router discovers it on the
        next RPC exactly as it would a genuine failure."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            try:
                self.proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                pass
        kill = getattr(self.channel, "kill", None)
        if kill is not None:
            kill()  # test channels: flip their killed flag
        else:
            self.channel.close()

    def chaos_hang(self) -> None:
        """SIGSTOP a spawned worker: connected but silent (partition-like);
        only the heartbeat monitor or an RPC timeout can notice."""
        import signal

        if self.proc is not None and self.proc.poll() is None:
            self.proc.send_signal(signal.SIGSTOP)
        else:
            hang = getattr(self.channel, "hang", None)
            if hang is not None:
                hang()

    def can_revive(self) -> bool:
        return self.channel_factory is not None or self.spawned or bool(self.address)

    def revive(self) -> None:
        """Bring a dead replica back: respawn the worker (or redial the
        address), re-place the stored spec, re-warmup.  The new engine is
        rebuilt deterministically from the spec's model seed, so recovered
        streams stay token-identical.  Raises ReplicaGone/RuntimeError on
        failure; the caller owns backoff and retry budgets."""
        if self.spec is None and self.channel_factory is None:
            raise ReplicaGone(f"replica at {self.address} was never placed")
        old_addr = self.address
        self.channel.close()
        if self._hb_channel is not None:
            self._hb_channel.close()
            self._hb_channel = None
        if self.channel_factory is not None:
            self.channel = self.channel_factory()
        elif self.spawned:
            kill_worker_proc(self.proc)
            cleanup_worker_dir(old_addr)
            self.proc, self.address = spawn_worker()
            self.channel = ControlChannel(self.address, timeout=self.channel.timeout)
            self.channel.connect()
        else:
            self.channel = ControlChannel(self.address, timeout=self.channel.timeout)
            self.channel.connect()
        self._streams.clear()
        self._pending.clear()
        self._queue_depth = 0
        self._hint = None
        self._placed = False
        if self.spec is not None:
            try:
                self.place(self.spec)
                self.warmup()
            except WorkerError as e:
                raise ReplicaGone(f"revived worker refused placement: {e}") from e
        self.dead = False
        self.suspect = False

    @property
    def fingerprint(self) -> tuple:
        # kv_dtype comes from the placed spec (the worker builds its pool
        # from it), mirroring LocalReplica's engine-derived fingerprint
        kv_dtype = getattr(self.spec, "kv_dtype", "bf16") if self.spec is not None else "bf16"
        return (self.k_max, self.max_len, self.greedy, self.paged_attention, kv_dtype)

    # -- shadowed introspection (no round trips) -----------------------------

    @property
    def streams(self) -> Dict[int, DeviceStream]:
        return self._streams

    @property
    def n_free(self) -> int:
        return self._n_slots - len(self._streams)

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    @property
    def steps(self):
        """Compiled executables cannot cross processes; never shareable."""
        return None

    def has_inflight(self, device_id: int) -> bool:
        return device_id in self._pending

    def next_event_hint(self, now: float) -> Optional[float]:
        return self._hint

    # -- driver surface (proxied) --------------------------------------------

    def admit(self, device_id: int, prompt, now: float = 0.0) -> Optional[DeviceStream]:
        reply = self._request(
            codec.AdmitRequest(
                device_id, np.asarray(prompt, np.int32), now,
                seq=self.channel.next_seq(),
            )
        )
        if not reply.ok:
            return None
        stream = DeviceStream(
            device_id=device_id,
            slot=reply.slot,
            prev_token=int(reply.prev_token),
            admitted_at=now,
        )
        self._streams[device_id] = stream
        return stream

    def submit(self, device_id: int, draft_tokens, now: float, draft_q=None) -> None:
        toks = np.asarray(draft_tokens, np.int32).reshape(-1)
        self._request(
            codec.SubmitRequest(
                device_id, toks, now,
                draft_q=None if draft_q is None else np.asarray(draft_q, np.float32),
                qmode="none" if draft_q is None else "f32",
                seq=self.channel.next_seq(),
            )
        )
        self._pending[device_id] = int(toks.shape[0])

    def step(self, now: float) -> Optional[List[Verdict]]:
        if not self._pending:
            return None  # nothing queued on this worker: skip the round trip
        reply = self._request(codec.StepRequest(now, seq=self.channel.next_seq()))
        self._queue_depth = reply.queue_depth
        self._hint = reply.hint
        verdicts: List[Verdict] = []
        for rec in reply.verdicts:
            stream = self._streams.get(rec.device_id)
            drafted = self._pending.pop(rec.device_id, 0)
            if stream is not None:
                stream.committed.extend(int(t) for t in rec.tokens)
                stream.prev_token = int(rec.next_prev)
                stream.rounds += 1
                stream.drafted += drafted
                stream.accepted += int(rec.n_accepted)
            verdicts.append(
                Verdict(
                    device_id=rec.device_id,
                    n_accepted=int(rec.n_accepted),
                    tokens=np.asarray(rec.tokens, np.int32),
                    next_prev=int(rec.next_prev),
                    accept_rate=float(rec.accept_rate),
                    queue_depth=int(rec.queue_depth),
                    queue_s=float(rec.queue_s),
                    verify_s=float(rec.verify_s),
                )
            )
        return verdicts or None

    def retire(self, device_id: int) -> DeviceStream:
        reply = self._request(
            codec.RetireRequest(device_id, seq=self.channel.next_seq())
        )
        self._pending.pop(device_id, None)
        self._streams.pop(device_id, None)
        from repro.transport.worker import state_to_stream

        return state_to_stream(reply.stream)

    def cancel_request(self, device_id: int) -> bool:
        reply = self._request(
            codec.CancelRequest(device_id, seq=self.channel.next_seq())
        )
        if reply.ok:
            self._pending.pop(device_id, None)
        return reply.ok

    def force_extend(self, device_id: int, tokens) -> int:
        reply = self._request(
            codec.ForceExtendRequest(
                device_id, np.asarray(tokens, np.int32), seq=self.channel.next_seq()
            )
        )
        stream = self._streams.get(device_id)
        if stream is not None:
            stream.committed.extend(int(t) for t in np.asarray(tokens).reshape(-1))
            stream.prev_token = int(reply.next_prev)
        return int(reply.next_prev)

    # -- migration (streams cross the wire bit-exactly) ----------------------

    def export_stream(self, device_id: int):
        reply = self._request(
            codec.ExportStream(device_id, seq=self.channel.next_seq())
        )
        self._pending.pop(device_id, None)
        self._streams.pop(device_id, None)
        from repro.transport.worker import state_to_stream

        return state_to_stream(reply.stream), dict(reply.stream.row)

    def import_stream(self, stream: DeviceStream, row_cache) -> DeviceStream:
        from repro.transport.worker import stream_to_state

        reply = self._request(
            codec.ImportStream(
                stream_to_state(stream, row_cache), seq=self.channel.next_seq()
            )
        )
        stream.slot = reply.slot
        self._streams[stream.device_id] = stream
        return stream

    # -- stats / warmup / lifecycle ------------------------------------------

    def stats(self, now: Optional[float] = None) -> EngineStats:
        req = codec.StatsRequest(
            now=0.0 if now is None else float(now), has_now=now is not None
        )
        try:
            reply = self.channel.request(req)
        except ReplicaGone:
            # side-effect-free: one reconnect-and-retry before giving up
            self.channel.reconnect()
            reply = self.channel.request(req)
        if reply.telemetry_json:
            self.last_telemetry = json.loads(reply.telemetry_json)
        return EngineStats(**json.loads(reply.stats_json))

    def warmup(self, buckets=None) -> Dict[int, float]:
        reply = self.channel.request(codec.WarmupRequest(), timeout=WARMUP_TIMEOUT)
        return {int(k): v for k, v in json.loads(reply.compile_json).items()}

    def drain(self) -> None:
        """Best-effort: ask the worker to exit; reap a spawned process and
        remove its private socket dir."""
        try:
            if self.channel.connected or not self.dead:
                self.channel.request(codec.Drain(), timeout=10.0)
        except (ReplicaGone, WorkerError):
            pass
        self.close()
        if self.proc is not None:
            try:
                self.proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                kill_worker_proc(self.proc)
            self.proc = None

    def close(self) -> None:
        self.channel.close()
        if self._hb_channel is not None:
            self._hb_channel.close()
            self._hb_channel = None
        if self.spawned:
            cleanup_worker_dir(self.address)
