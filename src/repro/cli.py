"""``repro`` console entry point.

    repro serve --spec spec.json [--check]     run a ServeSpec artifact
    repro serve --devices 4 --dump-spec        resolve flags into a spec
    repro serve --transport sim --net wlan     legacy-flag serving
    repro worker --listen tcp:0.0.0.0:7001     run one replica worker process
    repro top --connect tcp:host:7001          live fleet table (control plane)
    repro trace --spec spec.json               per-round trace JSONL dump
    repro chaos --kill 1:5 --check             seeded fault injection + identity
    repro tune --spec fleet.json --quick       auto-tune a heterogeneous fleet

A global ``--log-level LEVEL`` (anywhere on the command line) configures the
``repro.*`` logger hierarchy before the subcommand runs; ``REPRO_LOG_LEVEL``
is the env fallback.  Subcommands are lazy-imported so ``repro --help``
stays instant (no jax import until a command actually runs).
"""

from __future__ import annotations

import sys
from typing import List, Optional, Tuple

_USAGE = """\
usage: repro [--log-level LEVEL] <command> [args...]

commands:
  serve    serve a SLED deployment from a ServeSpec (see: repro serve --help)
  worker   run one engine replica behind a TCP/UDS control socket, to be
           placed and driven by a cluster Router (see: repro worker --help)
  top      live refreshing per-replica fleet table, polled over worker
           control sockets (see: repro top --help)
  trace    run a spec with telemetry on and dump the per-round trace as
           JSONL (see: repro trace --help)
  chaos    run a deterministic fault schedule (kill/hang/drop/delay/flap at
           fixed rounds) against a replica fleet and report what the
           supervision layer recovered (see: repro chaos --help)
  tune     profile a heterogeneous fleet spec, sweep per-class candidates
           through the calibrated simulator + cost model, and emit the
           winning ServeSpec + BENCH artifact (see: repro tune --help)

Run configurations are declarative ServeSpec JSON artifacts; `repro serve
--dump-spec` converts any flag combination into one.
"""


def _split_log_level(argv: List[str]) -> Tuple[Optional[str], List[str]]:
    """Strip a global --log-level[=LEVEL] from anywhere in argv."""
    level: Optional[str] = None
    rest: List[str] = []
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--log-level" and i + 1 < len(argv):
            level = argv[i + 1]
            i += 2
            continue
        if arg.startswith("--log-level="):
            level = arg.split("=", 1)[1]
            i += 1
            continue
        rest.append(arg)
        i += 1
    return level, rest


def main(argv: Optional[List[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    level, argv = _split_log_level(argv)
    if level is not None:
        from repro.telemetry import setup_logging

        setup_logging(level)
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE, end="")
        return
    cmd, rest = argv[0], argv[1:]
    if cmd == "serve":
        from repro.launch.serve import main as serve_main

        serve_main(rest)
        return
    if cmd == "worker":
        from repro.transport.worker import main as worker_main

        worker_main(rest)
        return
    if cmd == "top":
        from repro.telemetry.top import main_top

        main_top(rest)
        return
    if cmd == "trace":
        from repro.telemetry.top import main_trace

        main_trace(rest)
        return
    if cmd == "chaos":
        from repro.launch.chaos import main as chaos_main

        chaos_main(rest)
        return
    if cmd == "tune":
        from repro.launch.tune import main as tune_main

        tune_main(rest)
        return
    print(_USAGE, end="", file=sys.stderr)
    raise SystemExit(f"repro: unknown command {cmd!r}")


if __name__ == "__main__":
    main()
