"""Server-side batched verification (SLED §III-B) — the serve_step we deploy.

Invariant (shared with core/drafting.py):
  * ``cache.length`` counts K/V-committed tokens = (#committed tokens) - 1.
  * ``verify_step`` feeds ``tokens_in = [prev_committed, d_1 .. d_K]``
    (K+1 tokens), so ``logits[i]`` judges ``d_{i+1}`` and ``logits[m]`` is
    the correction/bonus distribution (core/speculative.py).
  * commit: attention caches set ``length += n_commit``; SSM/hybrid caches
    select the per-position state checkpoint (models emit them).

This module builds the jittable step functions that the dry-run lowers for
the decode shapes and the serving engine runs: the target model's compute is
one chunked-attention forward over (B, K+1) tokens against (B, S) caches —
SLED's entire server-side hot loop.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.speculative import VerifyResult, speculative_verify
from repro.models.kvcache import gather_slots, scatter_slots, supports_paged_attention
from repro.models.layers import MeshContext, NO_MESH


def make_verify_batch(prev_token, draft_tokens, lengths, draft_q=None, seed=0):
    """Assemble the padded verification request batch (host or device side)."""
    B, K = draft_tokens.shape
    batch = {
        "tokens_in": jnp.concatenate([prev_token[:, None], draft_tokens], axis=1),
        "draft_tokens": draft_tokens.astype(jnp.int32),
        "lengths": lengths.astype(jnp.int32),
        "seed": jnp.asarray(seed, jnp.uint32),
    }
    if draft_q is not None:
        batch["draft_q"] = draft_q
    return batch


def verify_batch_spec(batch_size: int, k_max: int, *, sampling: bool = False):
    """ShapeDtypeStruct stand-ins for the verification request (dry-run)."""
    spec = {
        "tokens_in": jax.ShapeDtypeStruct((batch_size, k_max + 1), jnp.int32),
        "draft_tokens": jax.ShapeDtypeStruct((batch_size, k_max), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        "seed": jax.ShapeDtypeStruct((), jnp.uint32),
    }
    if sampling:
        spec["draft_q"] = jax.ShapeDtypeStruct((batch_size, k_max), jnp.float32)
    return spec


def make_verify_step(
    model,
    *,
    ctx: MeshContext = NO_MESH,
    greedy: bool = True,
    temperature: float = 1.0,
    attn_chunk: int = 1024,
    uniform: bool = False,  # static padded batches: in-place cache append
):
    """Returns verify_step(params, cache, batch) -> (VerifyResult, cache')."""
    kw = {"uniform": uniform} if model.cfg.family not in ("ssm", "hybrid") else {}

    def verify_step(params, cache, batch) -> Tuple[VerifyResult, Any]:
        h, ck_cache, _ = model.decode_forward(
            params, cache, batch["tokens_in"], ctx, attn_chunk=attn_chunk, **kw
        )
        logits = model.lm_head(params, h)  # (B, K+1, V) fp32
        key = jax.random.key(batch["seed"])
        res = speculative_verify(
            batch["draft_tokens"],
            logits,
            key,
            lengths=batch["lengths"],
            draft_q=batch.get("draft_q"),
            draft_q_full=batch.get("draft_q_full"),
            temperature=temperature,
            greedy=greedy,
        )
        new_cache = model.commit(ck_cache, res.n_commit)
        return res, new_cache

    return verify_step


def make_paged_verify_step(
    model,
    *,
    scratch_slot: int,
    ctx: MeshContext = NO_MESH,
    greedy: bool = True,
    temperature: float = 1.0,
    attn_chunk: int = 1024,
    paged_attention: bool = True,
):
    """Slot-indexed verify step for continuous batching over a row pool.

    Returns ``verify_step(params, pool, slots, batch) -> (VerifyResult, pool')``
    where ``pool`` is a PagedKVCache.cache pytree, ``slots`` is (B_bucket,)
    int32 pool-row indices, and ``batch`` is a padded verify request of the
    same bucket size.  The jitted shapes depend only on (bucket, k_max),
    never on which devices happen to be scheduled, so heterogeneous partial
    fills reuse one executable per bucket.

    Two dispatch modes (kvcache.py module note):

      * ``paged_attention=True`` (default) on attention-cache families: the
        forward runs directly against the pool — ``decode_forward(slots=)``
        scatters the K+1 fresh K/V rows into pool rows and attention streams
        slot-indexed chunks, so the per-round gather/scatter round-trip of
        every cache leaf disappears; commit is an O(B) ``length`` update at
        the slot rows (rollback stays O(1)).
      * gather fallback (``paged_attention=False``, or any SSM/hybrid model
        — their recurrent state leaves cannot be slot-indexed): rows are
        gathered into a dense sub-cache, verified by the model's ordinary
        decode_forward/commit path, and scattered back.

    Padding convention (both modes): unused entries point at
    ``scratch_slot`` with ``lengths = 0``; the step resets the scratch row's
    committed length so repeated padding can never walk scratch state off
    the end of the buffer.
    """
    use_paged = paged_attention and supports_paged_attention(model.cfg)

    def _verify_logits(params, h, batch) -> VerifyResult:
        logits = model.lm_head(params, h)  # (B_bucket, K+1, V) fp32
        key = jax.random.key(batch["seed"])
        return speculative_verify(
            batch["draft_tokens"],
            logits,
            key,
            lengths=batch["lengths"],
            draft_q=batch.get("draft_q"),
            draft_q_full=batch.get("draft_q_full"),
            temperature=temperature,
            greedy=greedy,
        )

    def paged_verify_step(params, pool, slots, batch) -> Tuple[VerifyResult, Any]:
        base_len = jnp.take(pool["length"], slots, axis=0)
        h, new_pool, _ = model.decode_forward(
            params, pool, batch["tokens_in"], ctx, attn_chunk=attn_chunk, slots=slots
        )
        res = _verify_logits(params, h, batch)
        # commit = per-slot length bump; duplicate scratch entries race, but
        # the scratch row is reset right after (and never read as committed)
        length = new_pool["length"].at[slots].set(
            (base_len + res.n_commit).astype(jnp.int32)
        )
        length = length.at[scratch_slot].set(0)
        return res, {**new_pool, "length": length}

    def gather_verify_step(params, pool, slots, batch) -> Tuple[VerifyResult, Any]:
        sub = gather_slots(pool, slots)
        h, ck_sub, _ = model.decode_forward(
            params, sub, batch["tokens_in"], ctx, attn_chunk=attn_chunk
        )
        res = _verify_logits(params, h, batch)
        new_sub = model.commit(ck_sub, res.n_commit)
        new_pool = scatter_slots(pool, slots, new_sub)
        new_pool["length"] = new_pool["length"].at[scratch_slot].set(0)
        return res, new_pool

    verify_step = paged_verify_step if use_paged else gather_verify_step
    verify_step.paged_attention = use_paged  # introspection for engine/tests
    return verify_step


def make_force_extend_step(model, *, ctx: MeshContext = NO_MESH, attn_chunk: int = 1024,
                           paged_attention: bool = True):
    """Slot-indexed forced cache extension (no verification, no sampling).

    Returns ``extend_step(params, pool, slots, tokens_in, n) -> pool'`` that
    appends ``n[i]`` tokens of ``tokens_in[i]`` (padded to a fixed width) to
    pool row ``slots[i]``.  Used by the transport server to resync a stream
    after a §III-A timeout fallback: the device already released its local
    drafts to the user, so the server force-commits those exact tokens into
    the stream's row and verification resumes from the new tail — lossy by
    construction (that is the paper's fallback trade), but state-consistent.

    Same two dispatch modes as ``make_paged_verify_step``: pool-resident
    slot-indexed forward on attention families, gather/scatter fallback
    otherwise.
    """
    use_paged = paged_attention and supports_paged_attention(model.cfg)

    def paged_extend_step(params, pool, slots, tokens_in, n):
        base_len = jnp.take(pool["length"], slots, axis=0)
        _, new_pool, _ = model.decode_forward(
            params, pool, tokens_in, ctx, attn_chunk=attn_chunk, slots=slots
        )
        length = new_pool["length"].at[slots].set(
            (base_len + n).astype(jnp.int32)
        )
        return {**new_pool, "length": length}

    def gather_extend_step(params, pool, slots, tokens_in, n):
        sub = gather_slots(pool, slots)
        _, ck_sub, _ = model.decode_forward(
            params, sub, tokens_in, ctx, attn_chunk=attn_chunk
        )
        new_sub = model.commit(ck_sub, n.astype(jnp.int32))
        return scatter_slots(pool, slots, new_sub)

    extend_step = paged_extend_step if use_paged else gather_extend_step
    extend_step.paged_attention = use_paged
    return extend_step


def make_prefill_step(model, *, ctx: MeshContext = NO_MESH, attn_chunk: int = 1024,
                      with_frontend: bool = False, uniform: bool = False):
    """Returns prefill_step(params, cache, tokens, [stub_embeds]) for serving.

    Leaves the cache at ``length = prompt_len - 1`` and returns the last
    prompt token separately — satisfying the "all committed but the last"
    invariant so the first verify round can feed it.
    """

    def prefill_step(params, cache, tokens, stub=None):
        kw = {}
        if with_frontend and model.cfg.family == "encdec":
            kw["enc_frames"] = stub
        if with_frontend and model.cfg.family == "vlm":
            kw["embeds_prefix"] = stub
        if uniform and model.cfg.family not in ("ssm", "hybrid"):
            kw["uniform"] = True
        logits, cache = model.prefill(params, tokens[:, :-1], cache, ctx,
                                      attn_chunk=attn_chunk, **kw)
        return logits, cache, tokens[:, -1]

    return prefill_step
