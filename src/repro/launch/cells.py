"""Cell builder: (architecture x workload shape x mesh) -> jittable step + specs.

A "cell" is one entry of the assigned 40-cell grid.  ``build_cell`` returns
everything the dry-run (and the benchmarks) need:

    fn            the step function (train_step / prefill_step / verify_step)
    args          ShapeDtypeStruct pytrees for every input (no allocation)
    in_shardings  matching NamedSharding pytrees from sharding/policy.py

The decode shapes lower the SLED ``verify_step`` (K=4 draft tokens + 1) —
NOT a train step — per the assignment and per the paper: the server's only
job is batched verification.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import verification
from repro.models.model_zoo import build_model, frontend_stub
from repro.sharding.policy import Policy, make_policy
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    policy: Policy
    kind: str
    donate: Tuple[int, ...] = ()

    def lower(self):
        return jax.jit(
            self.fn, in_shardings=self.in_shardings, donate_argnums=self.donate
        ).lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """[vlm] cells spend part of the cell's seq budget on patch positions."""
    if cfg.family == "vlm":
        return seq_len - cfg.num_patches
    return seq_len


def _max_pos(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.use_rope and not cfg.is_encdec:
        return 0
    return shape.seq_len + shape.spec_len + 8


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    attn_chunk: int = 1024,
    loss_chunk: int = 512,
    greedy: bool = True,
    fsdp: Optional[bool] = None,
    kv_bits: int = 16,
) -> Cell:
    model = build_model(cfg)
    mode = "train" if shape.kind == "train" else "serve"
    policy = make_policy(cfg, mesh, mode=mode, fsdp=fsdp)
    ctx = policy.ctx
    B, S, K = shape.global_batch, shape.seq_len, shape.spec_len
    max_pos = _max_pos(cfg, shape)

    params = model.init_params_spec(max_pos=max_pos) if max_pos else model.init_params_spec()
    pspecs = policy.param_specs(params)

    needs_stub = cfg.family in ("encdec", "vlm")
    stub = frontend_stub(cfg, B, spec_only=True) if needs_stub else None
    stub_spec = None
    if needs_stub:
        from jax.sharding import PartitionSpec as P

        stub_spec = P(policy._bspec(B), None, None)

    if shape.kind == "train":
        # grad_accum: microbatch so live activations are ~2 rows/device —
        # the remat-saved per-layer residuals alone are tens of GB/device
        # otherwise (granite-34b: 16 rows x 4096 x 6144 x 88 layers).
        n_bs = max(policy.n_batch_shards, 1)
        accum = max(1, B // n_bs // 2)
        tcfg = TrainConfig(
            optimizer=AdamWConfig(),
            remat=True,
            loss_chunk=loss_chunk,
            attn_chunk=attn_chunk,
            grad_accum=accum,
        )
        # ZeRO-2 layout: live params TP-only (replicated over data — no
        # per-microbatch FSDP gathers), opt state fully sharded; grads are
        # pinned to the opt layout so XLA reduce-scatters them (§Perf C2).
        pspecs = policy.param_specs(params, fsdp=False)
        opt_pspecs = policy.param_specs(params, fsdp=True)
        step = make_train_step(model, tcfg, ctx,
                               grad_shardings=policy.named(opt_pspecs))

        def fn(p, opt, batch):
            p2, opt2, _, metrics = step(p, opt, None, batch)
            return p2, opt2, metrics["loss"]

        opt = jax.eval_shape(adamw_init, params)
        ospecs = type(opt)(
            step=jax.sharding.PartitionSpec(),
            master=opt_pspecs, m=opt_pspecs, v=opt_pspecs,
        )
        S_tok = _token_len(cfg, S)
        batch = {
            "tokens": _sds((B, S_tok), jnp.int32),
            "labels": _sds((B, S_tok), jnp.int32),
        }
        if needs_stub:
            batch["frontend"] = stub
        bspecs = policy.batch_specs(batch)
        return Cell(cfg, shape, fn, (params, opt, batch),
                    policy.named((pspecs, ospecs, bspecs)), policy, "train",
                    donate=(0, 1))

    if shape.kind == "prefill":
        cache = model.make_cache(B, S + K + 8, spec_only=True, attn_chunk=attn_chunk)
        cspecs = policy.cache_specs(cache)
        pf = verification.make_prefill_step(model, ctx=ctx, attn_chunk=attn_chunk,
                                            with_frontend=needs_stub, uniform=True)
        S_tok = _token_len(cfg, S)
        tokens = _sds((B, S_tok), jnp.int32)
        from jax.sharding import PartitionSpec as P

        tok_spec = P(policy._bspec(B), None)
        if needs_stub:
            fn = lambda p, c, t, st: pf(p, c, t, st)
            args = (params, cache, tokens, stub)
            shardings = policy.named((pspecs, cspecs, tok_spec, stub_spec))
        else:
            fn = lambda p, c, t: pf(p, c, t)
            args = (params, cache, tokens)
            shardings = policy.named((pspecs, cspecs, tok_spec))
        return Cell(cfg, shape, fn, args, shardings, policy, "prefill",
                    donate=(1,))

    # decode: the SLED batched-verification step over a seq_len-deep cache
    ckw = {}
    if kv_bits == 8 and cfg.family not in ("ssm", "hybrid"):
        ckw["kv_dtype"] = jnp.int8
    cache = model.make_cache(B, S + K + 8, spec_only=True, attn_chunk=attn_chunk, **ckw)
    cspecs = policy.cache_specs(cache)
    batch = verification.verify_batch_spec(B, K, sampling=not greedy)
    bspecs = policy.batch_specs(batch)
    vs = verification.make_verify_step(model, ctx=ctx, greedy=greedy,
                                       attn_chunk=attn_chunk, uniform=True)

    def fn(p, c, b):
        res, new_cache = vs(p, c, b)
        return res.out_tokens, res.n_commit, new_cache

    return Cell(cfg, shape, fn, (params, cache, batch),
                policy.named((pspecs, cspecs, bspecs)), policy, "decode",
                donate=(1,))
