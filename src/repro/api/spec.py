"""ServeSpec: one declarative, serializable config for every SLED backend.

The repo grew four ways to run the same system — the lock-step reference
loop, the in-process ServerEngine, the asyncio transport runtime, and the
replica-sharded cluster router — and every driver used to re-wire models,
pools, planners, and links by hand.  A :class:`ServeSpec` is the single
source of truth instead: a validated tree of frozen dataclasses that names
the model pair, the execution backend, and every serving knob, and that
round-trips through JSON (``to_json`` / ``from_json``) so a *run
configuration is an artifact* — sweepable, diffable, committable, and (the
ROADMAP's cross-process follow-on) shippable to another host as a placement
RPC.

``System.build(spec)`` (api/system.py) turns a spec into a running backend.
Validation happens at construction: invalid combinations (replicas on the
reference loop, adaptive spec-length control without the v2 feedback codec,
unknown policies) fail here with a message, not deep inside a driver.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple, Union

BACKENDS = ("reference", "engine", "transport", "cluster")
LINKS = ("loopback", "sim")
KCTLS = ("fixed", "adaptive")
CCTLS = ("fixed", "adaptive")
POLICIES = ("continuous", "deadline", "static")
PLACEMENTS = ("least-loaded", "affinity", "round-robin", "class-affinity")
QMODES = ("none", "f32", "f16", "int8")
QUANT_BITS = (4, 8, 16)
# server-pool KV storage dtype: "int8" stores pool rows quantized with
# per-(slot, layer, head) dequant scales (core/engine.py KV_DTYPES)
KV_DTYPES = ("bf16", "int8")
# v1: no Verdict feedback fields; v2: feedback wire; v3: + the
# Router<->worker control plane (PlaceReplica / driver RPCs / Drain);
# v4: + per-RPC sequence ids (replay-safe retries) and Ping/Pong heartbeat
CODEC_VERSIONS = (1, 2, 3, 4)
FLAVORS = ("inproc", "remote")
FAULT_KINDS = ("kill", "hang", "drop", "delay", "flap")


class SpecError(ValueError):
    """A ServeSpec names an invalid value or an invalid combination."""


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """The draft/target model pair (reduced configs, deterministic init).

    ``seed`` keys the target's init params; the draft uses ``seed + 1`` —
    one integer pins the whole weight state, which is what makes a spec a
    reproducible artifact.  ``draft_noise`` Gaussian-perturbs the draft
    (random-init reduced pairs otherwise agree greedily, so acceptance is a
    trivial 1.0); ``bits`` < 16 serves a weight-only-quantized target.
    """

    arch: str = "qwen2-1.5b"
    draft_arch: str = "qwen2-1.5b"
    vocab_size: int = 256
    target_layers: Optional[int] = None  # None: the reduced config's own depth
    draft_layers: Optional[int] = 1
    bits: int = 16
    draft_noise: float = 0.0
    seed: int = 0

    def validate(self) -> None:
        _check(bool(self.arch), "model.arch must name a config")
        _check(bool(self.draft_arch), "model.draft_arch must name a config")
        _check(self.vocab_size >= 8, f"model.vocab_size {self.vocab_size} too small")
        _check(self.bits in QUANT_BITS, f"model.bits {self.bits} not in {QUANT_BITS}")
        _check(self.draft_noise >= 0.0, "model.draft_noise must be >= 0")
        for name in ("target_layers", "draft_layers"):
            v = getattr(self, name)
            _check(v is None or v >= 1, f"model.{name} must be None or >= 1")


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """Wire-runtime knobs (``backend="transport"`` only).

    ``codec_version`` declares the frame protocol the deployment speaks;
    v1 Verdicts carried no accept_rate/queue_depth feedback, so adaptive
    spec-length control is rejected on a v1 codec at validation time.
    """

    link: str = "loopback"  # loopback | sim
    net: str = "wlan"  # NetProfile name for link="sim"
    qmode: str = "none"
    pipeline: bool = True  # draft ahead while a round is in flight
    verify_timeout: float = 30.0  # device-side round timeout (s)
    stagger_s: float = 0.0  # client i joins i * stagger_s seconds in
    draft_rate: Optional[float] = None  # emulated device tokens/s (None: unthrottled)
    codec_version: int = 4

    def validate(self) -> None:
        _check(self.link in LINKS, f"transport.link {self.link!r} not in {LINKS}")
        _check(self.qmode in QMODES, f"transport.qmode {self.qmode!r} not in {QMODES}")
        _check(
            self.codec_version in CODEC_VERSIONS,
            f"transport.codec_version {self.codec_version} not in {CODEC_VERSIONS}",
        )
        _check(self.verify_timeout > 0, "transport.verify_timeout must be > 0")
        _check(self.stagger_s >= 0, "transport.stagger_s must be >= 0")
        _check(
            self.draft_rate is None or self.draft_rate > 0,
            "transport.draft_rate must be None or > 0",
        )
        # net is validated for every link (serving resolves the profile even
        # on loopback): a typo'd spec must fail here, not deep in a driver
        from repro.serving.devices import NETS  # lazy: keep spec import light

        _check(self.net in NETS, f"transport.net {self.net!r} not in {sorted(NETS)}")


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One replica's placement: where it runs and how it is reached.

    ``flavor="inproc"`` constructs a ServerEngine in the driving process
    (the pre-PR-6 behaviour).  ``flavor="remote"`` places the replica in a
    ``repro worker`` process: with an ``address`` the System DIALS a worker
    you already started (``repro worker --listen ADDR``); with no address
    it SPAWNS one on a private unix socket and reaps it on close.
    ``slots`` overrides the pool rows for this replica alone (0 = the
    spec-level ``slots_per_replica`` split).
    """

    flavor: str = "inproc"
    address: str = ""  # remote only: tcp:HOST:PORT or uds:/path.sock
    slots: int = 0  # per-replica pool-row override; 0 = spec-level split

    def validate(self) -> None:
        _check(self.flavor in FLAVORS, f"replica.flavor {self.flavor!r} not in {FLAVORS}")
        _check(self.slots >= 0, "replica.slots must be >= 0 (0 = spec split)")
        if self.flavor == "inproc":
            _check(
                not self.address,
                f"replica.address {self.address!r} is meaningless for an inproc "
                f"replica (set flavor='remote' to dial a worker)",
            )
        elif self.address:
            from repro.transport.links import parse_addr  # lazy: keep spec light

            try:
                parse_addr(self.address)
            except ValueError as e:
                raise SpecError(f"replica.address invalid: {e}") from e


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Fault-tolerance knobs for the replica Router (``cluster.faults``).

    Everything defaults to today's fail-fast behaviour: a dead replica is
    evicted, its streams are reported in ``lost_devices``, and an all-dead
    cluster raises.  Flip ``respawn`` / ``recover_streams`` to get
    supervised worker restarts and device-replay stream recovery instead.

    ``heartbeat_interval_s > 0`` starts a background monitor that Pings
    each remote replica over its own control connection (codec v4); a peer
    that misses ``heartbeat_misses`` consecutive pings within
    ``heartbeat_timeout_s`` each is marked suspect and evicted at the next
    router step — seconds, not the 120 s RPC timeout.
    """

    respawn: bool = False  # restart spawned workers / redial dialed ones
    recover_streams: bool = False  # re-admit lost streams by device replay
    max_respawns: int = 3  # per replica, across its lifetime
    backoff_base_s: float = 0.2  # first respawn delay
    backoff_max_s: float = 5.0  # exponential backoff cap
    backoff_jitter: float = 0.1  # +- fraction of the delay, seeded
    redial_interval_s: float = 1.0  # dead dial-only replicas: retry cadence
    all_dead_deadline_s: float = 30.0  # all-dead: keep respawning this long
    heartbeat_interval_s: float = 0.0  # 0 = heartbeat monitor off
    heartbeat_timeout_s: float = 2.0  # per-ping reply deadline
    heartbeat_misses: int = 3  # consecutive misses before suspect
    rpc_timeout_s: float = 0.0  # control-plane RPC timeout; 0 = codec default
    retry_rpcs: bool = True  # one-shot idempotent retry over reconnect (v4)

    def validate(self) -> None:
        _check(self.max_respawns >= 0, "faults.max_respawns must be >= 0")
        _check(self.backoff_base_s > 0, "faults.backoff_base_s must be > 0")
        _check(
            self.backoff_max_s >= self.backoff_base_s,
            "faults.backoff_max_s must be >= backoff_base_s",
        )
        _check(
            0.0 <= self.backoff_jitter < 1.0,
            "faults.backoff_jitter must be in [0, 1)",
        )
        _check(self.redial_interval_s > 0, "faults.redial_interval_s must be > 0")
        _check(self.all_dead_deadline_s >= 0, "faults.all_dead_deadline_s must be >= 0")
        _check(self.heartbeat_interval_s >= 0, "faults.heartbeat_interval_s must be >= 0")
        _check(self.heartbeat_timeout_s > 0, "faults.heartbeat_timeout_s must be > 0")
        _check(self.heartbeat_misses >= 1, "faults.heartbeat_misses must be >= 1")
        _check(self.rpc_timeout_s >= 0, "faults.rpc_timeout_s must be >= 0 (0 = default)")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Replica fleet shape (``backend="cluster"`` or ``"transport"``).

    ``replicas`` is either the legacy bare int — shorthand for N identical
    in-process replicas — or a per-replica list of :class:`ReplicaSpec`
    objects (JSON: a list of objects).  Migration table::

        before (shorthand)   after (per-replica)                     meaning
        ------------------   -------------------------------------   -------
        "replicas": 2        "replicas": [{}, {}]                    2 inproc
        "replicas": 2        "replicas": [{"flavor": "inproc"},      same,
                                          {"flavor": "inproc"}]      explicit
        (not expressible)    "replicas": [{"flavor": "remote"},      spawn 2
                                          {"flavor": "remote"}]      workers
        (not expressible)    "replicas": [{"flavor": "remote",       dial 2
                               "address": "tcp:host-a:7001"},        running
                              {"flavor": "remote",                   workers
                               "address": "tcp:host-b:7001"}]

    The int shorthand stays first-class: it validates, round-trips, and
    expands to N inproc ReplicaSpecs via :attr:`replica_specs`.
    """

    replicas: Union[int, Tuple[ReplicaSpec, ...]] = 1
    placement: str = "least-loaded"
    migrate_on_retire: bool = True
    faults: FaultPolicy = dataclasses.field(default_factory=FaultPolicy)

    def __post_init__(self) -> None:
        # normalize list/tuple forms (JSON gives a list of dicts) into a
        # tuple of ReplicaSpec so the frozen dataclass stays hashable
        reps = self.replicas
        if isinstance(reps, (list, tuple)):
            object.__setattr__(
                self, "replicas", tuple(_replica_from(r) for r in reps)
            )
        if isinstance(self.faults, dict):
            object.__setattr__(
                self, "faults", _sub_from_dict(FaultPolicy, "cluster.faults", self.faults)
            )

    @property
    def n_replicas(self) -> int:
        return self.replicas if isinstance(self.replicas, int) else len(self.replicas)

    @property
    def replica_specs(self) -> Tuple[ReplicaSpec, ...]:
        """Per-replica form; the int shorthand expands to N inproc specs."""
        if isinstance(self.replicas, int):
            return tuple(ReplicaSpec() for _ in range(self.replicas))
        return self.replicas

    @property
    def has_remote(self) -> bool:
        return any(r.flavor == "remote" for r in self.replica_specs)

    def validate(self) -> None:
        if isinstance(self.replicas, int):
            _check(
                self.replicas >= 1, f"cluster.replicas must be >= 1, got {self.replicas}"
            )
        else:
            _check(
                len(self.replicas) >= 1,
                "cluster.replicas list must name at least one replica",
            )
            for r in self.replicas:
                r.validate()
        _check(
            self.placement in PLACEMENTS,
            f"cluster.placement {self.placement!r} not in {PLACEMENTS}",
        )
        self.faults.validate()


def _replica_from(r) -> ReplicaSpec:
    if isinstance(r, ReplicaSpec):
        return r
    if not isinstance(r, dict):
        raise SpecError(
            f"cluster.replicas entries must be objects, got {type(r).__name__}"
        )
    known = {f.name for f in dataclasses.fields(ReplicaSpec)}
    unknown = sorted(set(r) - known)
    if unknown:
        raise SpecError(f"unknown replica keys {unknown}")
    try:
        return ReplicaSpec(**r)
    except SpecError:
        raise
    except (TypeError, ValueError) as e:
        raise SpecError(f"bad replica value: {e}") from e


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: *what* happens to *which* replica at which
    router step.  ``round`` counts Router.step() calls (the cluster's
    logical clock), so a schedule is deterministic across runs.

      kill    SIGKILL a spawned worker / sever a dialed control channel
      hang    SIGSTOP a spawned worker (heartbeat detects; no clean close)
      drop    fail the next ``count`` control RPCs with a connection error
      delay   stall the next ``count`` control RPCs by ``delay_s`` each
      flap    sever the control link once, then heal (retryable blip)
    """

    kind: str = "kill"
    replica: int = 0
    round: int = 1
    count: int = 1  # drop/delay: how many RPCs are affected
    delay_s: float = 0.0  # delay: per-RPC stall seconds

    def validate(self) -> None:
        _check(self.kind in FAULT_KINDS, f"fault.kind {self.kind!r} not in {FAULT_KINDS}")
        _check(self.replica >= 0, "fault.replica must be >= 0")
        _check(self.round >= 0, "fault.round must be >= 0")
        _check(self.count >= 1, "fault.count must be >= 1")
        _check(self.delay_s >= 0, "fault.delay_s must be >= 0")
        if self.kind == "delay":
            _check(self.delay_s > 0, "fault kind 'delay' needs delay_s > 0")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A deterministic chaos schedule (``ServeSpec.faults``).

    ``seed`` keys every random choice the harness makes (backoff jitter,
    injector tie-breaks), so a chaos run is exactly reproducible: same
    spec, same kills, same recovery, same tokens.
    """

    seed: int = 0
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self) -> None:
        evs = self.events
        if isinstance(evs, (list, tuple)):
            object.__setattr__(self, "events", tuple(_fault_event_from(e) for e in evs))

    def validate(self) -> None:
        for e in self.events:
            e.validate()

    @property
    def active(self) -> bool:
        return bool(self.events)


def _fault_event_from(e) -> FaultEvent:
    if isinstance(e, FaultEvent):
        return e
    if not isinstance(e, dict):
        raise SpecError(f"faults.events entries must be objects, got {type(e).__name__}")
    return _sub_from_dict(FaultEvent, "faults.events", e)


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """BatchPlanner policy + pool sizing for the engine-backed backends."""

    policy: str = "continuous"
    max_wait: float = 0.05
    slots: int = 0  # pool rows PER REPLICA; 0 = ceil(devices / replicas)
    straggler_timeout: float = 30.0
    stagger_ticks: int = 3  # in-process driver: device i joins i*stagger ticks in

    def validate(self) -> None:
        _check(self.policy in POLICIES, f"scheduler.policy {self.policy!r} not in {POLICIES}")
        _check(self.max_wait >= 0, "scheduler.max_wait must be >= 0")
        _check(self.slots >= 0, "scheduler.slots must be >= 0 (0 = auto)")
        _check(self.straggler_timeout > 0, "scheduler.straggler_timeout must be > 0")
        _check(self.stagger_ticks >= 0, "scheduler.stagger_ticks must be >= 0")


@dataclasses.dataclass(frozen=True)
class DeviceClassSpec:
    """One homogeneous slice of a heterogeneous edge fleet.

    A class names a *hardware profile* (``serving/devices.DEVICES`` — Jetson
    Orin Nano, RPi 4B/5), how many devices of that class join the fleet, and
    the per-class serving configuration the paper's ConfigSpec-style tuner
    selects: draft model family + weight precision (keying the profile's
    measured tokens/s table), speculation length ``k``, drafting confidence
    ``c_th``, and the network profile the class reaches the server over.

    Sentinel defaults inherit the spec-level value, so a class only states
    what differs: ``k=0`` -> ``ServeSpec.k_max``, ``c_th=-1`` ->
    ``ServeSpec.c_th``, ``net=""`` -> ``transport.net``, ``draft_layers=0``
    -> ``model.draft_layers``, ``draft_noise=-1`` -> ``model.draft_noise``.
    ``draft_layers``/``draft_noise`` emulate the class's draft *model* in
    reduced-model land (a deeper, less-perturbed draft stands in for a
    larger family member with higher acceptance).
    """

    profile: str = "rpi5"
    count: int = 1
    draft_model: str = "llama-1b-draft"  # family in the profile's rate table
    bits: int = 4  # draft weight precision for the rate lookup
    k: int = 0  # per-class speculation length; 0 = spec k_max
    c_th: float = -1.0  # per-class confidence bar; -1 = spec c_th
    net: str = ""  # per-class NetProfile; "" = transport.net
    draft_layers: int = 0  # emulated draft depth; 0 = model.draft_layers
    draft_noise: float = -1.0  # emulated draft quality; -1 = model.draft_noise

    def validate(self) -> None:
        # lazy: keep spec import light (same pattern as TransportSpec.net)
        from repro.serving.devices import DEVICES, NETS

        _check(
            self.profile in DEVICES,
            f"fleet class profile {self.profile!r} not in {sorted(DEVICES)}",
        )
        _check(self.count >= 1, f"fleet class count must be >= 1, got {self.count}")
        table = DEVICES[self.profile].draft_rate
        _check(
            (self.draft_model, self.bits) in table,
            f"fleet class {self.profile!r} has no draft rate for "
            f"(draft_model={self.draft_model!r}, bits={self.bits}); available "
            f"combos: {', '.join(f'({m!r}, {b})' for m, b in sorted(table))}",
        )
        _check(
            self.c_th == -1.0 or 0.0 <= self.c_th <= 1.0,
            f"fleet class c_th must be in [0, 1] (or -1 to inherit), got {self.c_th}",
        )
        _check(self.k >= 0, "fleet class k must be >= 0 (0 = spec k_max)")
        _check(
            not self.net or self.net in NETS,
            f"fleet class net {self.net!r} not in {sorted(NETS)}",
        )
        _check(self.draft_layers >= 0, "fleet class draft_layers must be >= 0")
        _check(
            self.draft_noise == -1.0 or self.draft_noise >= 0.0,
            "fleet class draft_noise must be >= 0 (or -1 to inherit)",
        )


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A heterogeneous device fleet: an ordered list of device classes.

    When ``classes`` is non-empty the fleet is *active*: ``ServeSpec.devices``
    is derived from the class counts (device ids are assigned contiguously in
    class order: class 0 gets ids ``[0, count_0)``, class 1 the next
    ``count_1``, ...), and every backend resolves per-device k / c_th /
    draft model / net from the owning class.

    ``emulate_rates`` throttles each class's drafting to its hardware
    profile's measured tokens/s (times ``rate_scale``, so benchmarks can
    compress wall-clock while preserving the RPi-vs-Jetson ratios) — the
    transport runtime sleeps between drafted tokens exactly like the
    single-rate ``transport.draft_rate`` knob, but per class.
    """

    classes: Tuple[DeviceClassSpec, ...] = ()
    emulate_rates: bool = False
    rate_scale: float = 1.0

    def __post_init__(self) -> None:
        cls = self.classes
        if isinstance(cls, (list, tuple)):
            object.__setattr__(
                self, "classes", tuple(_device_class_from(c) for c in cls)
            )

    @property
    def active(self) -> bool:
        return bool(self.classes)

    @property
    def total(self) -> int:
        return sum(c.count for c in self.classes)

    def validate(self) -> None:
        for c in self.classes:
            c.validate()
        _check(self.rate_scale > 0, "fleet.rate_scale must be > 0")


def _device_class_from(c) -> DeviceClassSpec:
    if isinstance(c, DeviceClassSpec):
        return c
    if not isinstance(c, dict):
        raise SpecError(
            f"fleet.classes entries must be objects, got {type(c).__name__}"
        )
    return _sub_from_dict(DeviceClassSpec, "fleet.classes", c)


@dataclasses.dataclass(frozen=True)
class ResolvedClass:
    """A fleet class with spec-level defaults filled in and its device-id
    range assigned — what System / the tuner / the simulator consume."""

    index: int
    lo: int  # device ids [lo, hi) belong to this class
    hi: int
    spec: DeviceClassSpec
    k: int
    c_th: float
    net: str
    draft_layers: Optional[int]
    draft_noise: float

    @property
    def count(self) -> int:
        return self.hi - self.lo

    def hardware_rate(self) -> float:
        """The class's measured drafting tokens/s from its hardware profile."""
        from repro.serving.devices import DEVICES

        return DEVICES[self.spec.profile].rate(self.spec.draft_model, self.spec.bits)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The full deployment: model pair + backend + workload + every knob.

    ``backend`` selects the execution stack ``System.build`` constructs:

      reference   lock-step sled_generate loop (algorithmic ground truth)
      engine      one in-process ServerEngine (continuous batching)
      cluster     Router over N in-process engine replicas + placement
      transport   asyncio wire runtime (codec frames over loopback/sim links),
                  fronting one engine or a replica Router

    All four commit token-identical streams for the same spec under greedy
    drafting on lossless links — tests/test_api.py enforces it.
    """

    backend: str = "engine"
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    transport: TransportSpec = dataclasses.field(default_factory=TransportSpec)
    cluster: ClusterSpec = dataclasses.field(default_factory=ClusterSpec)
    scheduler: SchedulerSpec = dataclasses.field(default_factory=SchedulerSpec)
    # workload: the fleet this spec serves by default.  ``fleet`` makes it
    # heterogeneous: when fleet.classes is non-empty, ``devices`` is DERIVED
    # from the class counts (any explicit value is overwritten) and each
    # device resolves k/c_th/draft/net from its owning class.
    fleet: FleetSpec = dataclasses.field(default_factory=FleetSpec)
    devices: int = 6
    prompt_len: int = 12
    prompt_seed: int = 2
    max_new: int = 24
    session_seed_base: int = 1000  # device i drafts with seed base + i
    # decoding / verification
    k_max: int = 4
    c_th: float = 0.3  # Eq. 1 dynamic-drafting confidence threshold
    greedy: bool = True
    kctl: str = "fixed"  # fixed | adaptive (closed-loop spec length)
    cctl: str = "fixed"  # fixed | adaptive (closed-loop drafting confidence)
    max_len: int = 128
    attn_chunk: int = 32
    paged_attention: bool = True
    # KV-pool storage dtype: "int8" roughly halves bytes-per-slot (doubling
    # server capacity at a fixed HBM budget) at the cost of quantized cache
    # reads; rejected for ssm/hybrid families at System.build (their
    # recurrent state has no quantized layout)
    kv_dtype: str = "bf16"
    # observability: metrics registry + per-round traces (repro.telemetry).
    # Off by default — spans wrap host-side boundaries only, and the
    # server-timing Verdict fields are populated either way, so flipping
    # this can never change the committed token streams.
    telemetry: bool = False
    # chaos: a seeded, deterministic fault schedule injected while serving
    # (kill/hang workers at a router step, drop/delay control RPCs).  Empty
    # by default — no faults, no behaviour change.
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)

    def __post_init__(self) -> None:
        if self.fleet.active:
            # devices is derived from the fleet — class counts are the single
            # source of truth, so replace(spec, fleet=...) sweeps stay coherent
            object.__setattr__(self, "devices", self.fleet.total)
        self.validate()

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        _check(self.backend in BACKENDS, f"backend {self.backend!r} not in {BACKENDS}")
        self.model.validate()
        self.transport.validate()
        self.cluster.validate()
        self.scheduler.validate()
        self.fleet.validate()
        _check(self.devices >= 1, "devices must be >= 1")
        _check(self.prompt_len >= 1, "prompt_len must be >= 1")
        _check(self.max_new >= 1, "max_new must be >= 1")
        _check(self.k_max >= 1, "k_max must be >= 1")
        _check(0.0 <= self.c_th <= 1.0, "c_th must be in [0, 1]")
        _check(self.kctl in KCTLS, f"kctl {self.kctl!r} not in {KCTLS}")
        # a stream occupies prompt + committed tokens + one in-flight round of
        # slack in its pool row; a spec that can overflow a row would silently
        # clamp dynamic_update_slice appends and corrupt the cache tail
        _check(
            self.max_len >= self.prompt_len + self.max_new + self.k_max + 1,
            f"max_len {self.max_len} cannot hold prompt_len {self.prompt_len} "
            f"+ max_new {self.max_new} + k_max+1 in-flight slack",
        )
        _check(self.attn_chunk >= 1, "attn_chunk must be >= 1")
        _check(
            self.kv_dtype in KV_DTYPES, f"kv_dtype {self.kv_dtype!r} not in {KV_DTYPES}"
        )
        # cross-field combinations
        _check(
            self.cluster.n_replicas == 1 or self.backend in ("cluster", "transport"),
            f"replicas={self.cluster.n_replicas} needs backend 'cluster' or "
            f"'transport', not {self.backend!r} (the reference loop and the "
            "bare engine are single-replica by definition)",
        )
        _check(
            not self.cluster.has_remote or self.backend in ("cluster", "transport"),
            f"remote replicas need backend 'cluster' or 'transport', not "
            f"{self.backend!r} (a worker process is a cluster member)",
        )
        _check(
            not self.cluster.has_remote or self.transport.codec_version >= 3,
            "remote replicas need codec_version >= 3 (the Router<->worker "
            "control plane — PlaceReplica, driver RPCs, stream export — is v3)",
        )
        _check(
            self.kctl != "adaptive" or self.backend == "transport",
            "kctl='adaptive' needs backend='transport': the acceptance/"
            "queue-depth feedback rides Verdict frames",
        )
        _check(
            self.kctl != "adaptive" or self.transport.codec_version >= 2,
            "kctl='adaptive' needs codec_version >= 2 (v1 Verdict frames "
            "carry no accept_rate/queue_depth feedback)",
        )
        _check(self.cctl in CCTLS, f"cctl {self.cctl!r} not in {CCTLS}")
        _check(
            self.cctl != "adaptive" or self.backend == "transport",
            "cctl='adaptive' needs backend='transport': the acceptance/"
            "queue-depth feedback rides Verdict frames",
        )
        _check(
            self.cctl != "adaptive" or self.transport.codec_version >= 2,
            "cctl='adaptive' needs codec_version >= 2 (v1 Verdict frames "
            "carry no accept_rate/queue_depth feedback)",
        )
        # heterogeneous fleets
        _check(
            not self.fleet.active or self.backend != "reference",
            "a heterogeneous fleet needs backend 'engine', 'cluster', or "
            "'transport': the lock-step reference loop batches every device "
            "through one (k, c_th, draft) configuration (use "
            "fleet_reference_specs() for per-class ground truth)",
        )
        if self.fleet.active:
            for rc in self.resolved_classes():
                _check(
                    1 <= rc.k <= self.k_max,
                    f"fleet class {rc.index} ({rc.spec.profile!r}) resolves "
                    f"k={rc.k}, outside [1, k_max={self.k_max}] (the engine's "
                    "verify width is sized by k_max)",
                )
        _check(
            self.cluster.placement != "class-affinity" or self.fleet.active,
            "cluster.placement 'class-affinity' needs a fleet: without "
            "device classes it has nothing to group by",
        )
        self.faults.validate()
        _check(
            not self.faults.active or self.backend in ("cluster", "transport"),
            f"a fault schedule needs backend 'cluster' or 'transport', not "
            f"{self.backend!r} (faults target replica workers and control links)",
        )
        if self.faults.active:
            n = self.cluster.n_replicas
            for e in self.faults.events:
                _check(
                    e.replica < n,
                    f"fault event targets replica {e.replica} but the cluster "
                    f"has only {n} replicas",
                )

    # -- derived -------------------------------------------------------------

    @property
    def slots_per_replica(self) -> int:
        """Pool rows per replica: explicit, or the fleet split evenly.
        A per-replica ``ReplicaSpec.slots`` override beats both."""
        if self.scheduler.slots:
            return self.scheduler.slots
        return -(-self.devices // self.cluster.n_replicas)  # ceil div

    def resolved_classes(self) -> Tuple[ResolvedClass, ...]:
        """The fleet with spec-level defaults filled in and contiguous
        device-id ranges assigned; empty when the fleet is inactive."""
        out, lo = [], 0
        for i, c in enumerate(self.fleet.classes):
            hi = lo + c.count
            out.append(ResolvedClass(
                index=i, lo=lo, hi=hi, spec=c,
                k=c.k or self.k_max,
                c_th=c.c_th if c.c_th >= 0 else self.c_th,
                net=c.net or self.transport.net,
                draft_layers=c.draft_layers or self.model.draft_layers,
                draft_noise=c.draft_noise if c.draft_noise >= 0 else self.model.draft_noise,
            ))
            lo = hi
        return tuple(out)

    def class_of(self, device_id: int) -> Optional[ResolvedClass]:
        """The resolved class owning ``device_id``; None without a fleet."""
        for rc in self.resolved_classes():
            if rc.lo <= device_id < rc.hi:
                return rc
        return None

    def fleet_reference_specs(self) -> Tuple[Tuple[int, int, "ServeSpec"], ...]:
        """Per-class lock-step ground truth: each fleet class is homogeneous
        (one k, c_th, draft config), so it has an exact single-class
        reference equivalent.  Returns ``(lo, hi, refspec)`` per class —
        serve the refspec with the fleet prompts' ``[lo:hi]`` slice and the
        committed streams must match token-for-token (launch/serve.py
        ``--check`` does exactly that)."""
        out = []
        for rc in self.resolved_classes():
            model = dataclasses.replace(
                self.model,
                draft_layers=rc.draft_layers,
                draft_noise=rc.draft_noise,
            )
            ref = self.with_backend(
                "reference",
                fleet=FleetSpec(),
                devices=rc.count,
                k_max=rc.k,
                c_th=rc.c_th,
                model=model,
            )
            out.append((rc.lo, rc.hi, ref))
        return tuple(out)

    def with_backend(self, backend: str, **changes) -> "ServeSpec":
        """Same deployment on a different backend (replicas reset to 1 and
        kctl/cctl to fixed where the target backend demands it, BEFORE the
        replace so the result always validates)."""
        kw = dict(changes)
        cluster = kw.pop("cluster", self.cluster)
        kctl = kw.pop("kctl", self.kctl)
        cctl = kw.pop("cctl", self.cctl)
        if backend in ("reference", "engine") and (
            cluster.n_replicas != 1 or cluster.has_remote
        ):
            cluster = dataclasses.replace(cluster, replicas=1)
        if backend != "transport":
            if kctl == "adaptive":
                kctl = "fixed"
            if cctl == "adaptive":
                cctl = "fixed"
        fleet = kw.get("fleet", self.fleet)
        if not fleet.active and cluster.placement == "class-affinity":
            cluster = dataclasses.replace(cluster, placement="least-loaded")
        return dataclasses.replace(
            self, backend=backend, cluster=cluster, kctl=kctl, cctl=cctl, **kw
        )

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """Plain-dict form (nested specs as sub-dicts); json.dumps-safe.
        A per-replica fleet serializes as a list of replica objects (the
        int shorthand stays an int)."""
        d = dataclasses.asdict(self)
        reps = d["cluster"]["replicas"]
        if isinstance(reps, tuple):
            d["cluster"]["replicas"] = [dict(r) for r in reps]
        d["faults"]["events"] = [dict(e) for e in d["faults"]["events"]]
        d["fleet"]["classes"] = [dict(c) for c in d["fleet"]["classes"]]
        return d

    def to_json_str(self, indent: int = 2) -> str:
        return json.dumps(self.to_json(), indent=indent)

    @classmethod
    def from_json(cls, data: Union[str, bytes, dict]) -> "ServeSpec":
        """Inverse of :meth:`to_json`.  Every malformation — bad JSON,
        unknown keys, wrong-typed values — surfaces as a SpecError (a typo'd
        sweep artifact must fail loudly with one exception type, not leak a
        TypeError traceback through a driver)."""
        if isinstance(data, (str, bytes)):
            try:
                data = json.loads(data)
            except json.JSONDecodeError as e:
                raise SpecError(f"spec is not valid JSON: {e}") from e
        if not isinstance(data, dict):
            raise SpecError(f"spec JSON must be an object, got {type(data).__name__}")
        data = dict(data)
        kw = {}
        for name, sub_cls in (
            ("model", ModelSpec),
            ("transport", TransportSpec),
            ("cluster", ClusterSpec),
            ("scheduler", SchedulerSpec),
            ("fleet", FleetSpec),
            ("faults", FaultSpec),
        ):
            if name in data:
                kw[name] = _sub_from_dict(sub_cls, name, data.pop(name))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise SpecError(f"unknown ServeSpec keys {unknown}")
        try:
            return cls(**kw, **data)
        except SpecError:
            raise
        except (TypeError, ValueError) as e:  # wrong-typed values
            raise SpecError(f"bad ServeSpec value: {e}") from e


def _sub_from_dict(sub_cls, name: str, d: dict):
    if not isinstance(d, dict):
        raise SpecError(f"spec key {name!r} must be an object, got {type(d).__name__}")
    known = {f.name for f in dataclasses.fields(sub_cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise SpecError(f"unknown {name} keys {unknown}")
    try:
        return sub_cls(**d)
    except SpecError:
        raise
    except (TypeError, ValueError) as e:  # wrong-typed values
        raise SpecError(f"bad {name} value: {e}") from e
