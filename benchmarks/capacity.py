"""Paper Table I: system capacity, SLED vs centralized, per device type.

Capacity = number of edge devices the system supports at the same response
rate.  The paper reports x2.60 (RPi 4B), x2.86 (RPi 5), x2.77 (Jetson) —
our validation target is ratios in that x2-3 band.

``--cluster`` switches to the REAL replica-sharded serving stack
(cluster/router.py over tiny models): sweep the replica count, drive an
offered load that oversubscribes one replica's slot pool, and measure
admitted-stream capacity (peak concurrently-admitted streams) at a fixed
per-round deadline — capacity should scale ~linearly in replicas at a
matched deadline-miss rate, which is the multi-server half of the paper's
capacity claim.  The same mode then runs an adaptive-k vs fixed-k fleet over
loopback transport (closed-loop spec length, serving/speclen.py) and reports
wstgr side by side.  ``--json PATH`` records everything as a BENCH artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import emit
from repro.serving.devices import A100_X4, DEVICES
from repro.serving.simulator import SimConfig, capacity


def run(quick: bool = False) -> list:
    rows = []
    sim_time = 20.0 if quick else 45.0
    for dev_name in ("rpi4b", "rpi5", "jetson-orin-nano"):
        dev = DEVICES[dev_name]
        base = SimConfig(
            mode="sled", spec_len=4, acceptance=0.90,
            device_rate=dev.rate("llama-1b-draft", 4),
            target_params=11e9, server_batch=16, batch_policy="deadline",
            sim_time=sim_time,
        )
        cap_sled = capacity(base, A100_X4, n_max=2048)
        cap_cent = capacity(dataclasses.replace(base, mode="centralized"),
                            A100_X4, n_max=2048)
        rows.append({
            "device": dev_name,
            "cap_sled": cap_sled,
            "cap_centralized": cap_cent,
            "improvement": round(cap_sled / max(cap_cent, 1), 2),
            "paper_claim": {"rpi4b": 2.60, "rpi5": 2.86, "jetson-orin-nano": 2.77}[dev_name],
        })
    emit(rows, "table1_capacity")
    return rows


# ---------------------------------------------------------------------------
# real cluster: replica capacity scaling + adaptive spec length
# ---------------------------------------------------------------------------


def _cluster_models(quick: bool):
    import jax

    from repro.configs.base import get_config
    from repro.models.model_zoo import build_model, perturb_params

    vocab = 128
    tcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="tgt", vocab_size=vocab,
        num_layers=2 if quick else 3,
    )
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=vocab)
    target, draft = build_model(tcfg), build_model(dcfg)
    tp = target.init_params(jax.random.key(0))
    # random-init pairs agree greedily (trivial 1.0 acceptance); perturb the
    # draft so rejections are real and the adaptive controller has a signal
    dp = perturb_params(draft.init_params(jax.random.key(1)), 0.05)
    return target, tp, draft, dp, vocab


def _capacity_rows(target, tp, draft, dp, vocab, *, quick: bool) -> list:
    """Replica sweep under oversubscribed offered load, in-process driver.

    Admission is DEADLINE-GATED: a new stream is admitted only while the
    trailing window of verdict latencies meets the per-round deadline, so
    peak admitted streams is a measured serving capacity — pool-bound when
    the replicas keep up (``gated_by: pool``), compute-bound when they
    don't (``gated_by: deadline``) — not pool-size arithmetic.  All routers
    share one VerifySteps bundle, so every replica count runs the same
    compiled executables (the sweep measures capacity, not compiles).
    """
    import jax

    from repro.cluster import Router
    from repro.core.server_engine import EdgeDeviceKit, ServerEngine

    slots, max_new, k_max = (2, 5, 4) if quick else (3, 10, 4)
    replica_counts = (1, 2) if quick else (1, 2, 4)
    n_offer = 2 * max(replica_counts) * slots  # oversubscribe every config
    deadline_s = 2.0  # generous CPU-CI round deadline (matched across sweeps)
    miss_cap = 0.1  # stop admitting while >10% of recent rounds miss
    window = 16  # trailing latencies consulted by the admission gate
    prompts = jax.random.randint(jax.random.key(2), (n_offer, 10), 0, vocab)
    kit = EdgeDeviceKit(draft, dp, k_max=k_max, c_th=0.3, greedy=True, attn_chunk=32)

    # one shared step bundle across the whole sweep (homogeneous replicas),
    # with every jitted path — verify buckets, prefill, draft — compiled up
    # front so the sweep measures capacity, not compiles
    seed_engine = ServerEngine(
        target, tp, n_slots=slots, max_len=128, k_max=k_max, attn_chunk=32
    )
    steps = seed_engine.steps
    seed_engine.warmup()
    seed_engine.admit(-1, prompts[0], 0.0)
    warm_dev = kit.spawn(-1, prompts[0], max_len=128, seed=0)
    seed_engine.submit(-1, warm_dev.draft(), 0.0)
    for v in seed_engine.step(0.0) or []:
        warm_dev.on_verdict(v)
    seed_engine.retire(-1)

    rows = []
    base_capacity = None
    for n_rep in replica_counts:
        router = Router(
            [
                ServerEngine(
                    target, tp, n_slots=slots, max_len=128, k_max=k_max,
                    attn_chunk=32, steps=steps,
                )
                for _ in range(n_rep)
            ]
        )
        devices, outputs, waiting = {}, {}, list(range(n_offer))
        submit_at, latencies = {}, []
        peak_admitted = 0
        deadline_gated = False
        t0 = time.time()
        while len(outputs) < n_offer:
            now = time.time() - t0
            recent = latencies[-window:]
            meeting_deadline = (
                sum(1 for lat in recent if lat > deadline_s)
                <= miss_cap * len(recent)
            )
            deadline_gated |= not meeting_deadline
            while waiting and router.n_free > 0 and meeting_deadline:
                i = waiting.pop(0)
                stream = router.admit(i, prompts[i], now)
                assert stream is not None, "router reported a free slot"
                devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=i)
            peak_admitted = max(peak_admitted, len(router.streams))
            for i, dev in devices.items():
                if not dev.awaiting:
                    now = time.time() - t0
                    router.submit(i, dev.draft(), now)
                    submit_at[i] = now
            verdicts = router.step(time.time() - t0)
            now = time.time() - t0
            for v in verdicts or []:
                latencies.append(now - submit_at[v.device_id])
                dev = devices[v.device_id]
                dev.on_verdict(v)
                if len(dev.committed) >= max_new:
                    outputs[v.device_id] = dev.committed[:max_new]
                    router.retire(v.device_id)
                    del devices[v.device_id]
        wall = time.time() - t0
        st = router.stats(wall)
        misses = sum(1 for lat in latencies if lat > deadline_s)
        if base_capacity is None:
            base_capacity = peak_admitted
        rows.append({
            "section": "capacity",
            "replicas": n_rep,
            "slots_per_replica": slots,
            "offered_streams": n_offer,
            "capacity_streams": peak_admitted,
            "capacity_ratio": round(peak_admitted / max(base_capacity, 1), 2),
            "gated_by": "deadline" if deadline_gated else "pool",
            "deadline_s": deadline_s,
            "deadline_miss_rate": round(misses / max(len(latencies), 1), 4),
            "streams_served": st.streams_served,
            "wstgr": round(n_offer * max_new / wall, 2),
            "rounds": st.rounds,
            "mean_batch_fill": round(st.mean_batch_fill, 2),
            "migrations": router.migrations,
            "wall_s": round(wall, 2),
        })
        print(
            f"[capacity] {n_rep} replica(s): peak {peak_admitted} admitted "
            f"({rows[-1]['capacity_ratio']}x), miss rate "
            f"{rows[-1]['deadline_miss_rate']:.1%}, "
            f"{rows[-1]['wstgr']} tok/s"
        )
    return rows


def _kctl_rows(target, tp, draft, dp, vocab, *, quick: bool) -> list:
    """Adaptive vs fixed spec length over loopback transport (real feedback
    loop: Verdict accept_rate/queue_depth -> AIMD controller -> draft k)."""
    import asyncio

    import jax
    import numpy as np

    from repro.core.server_engine import EdgeDeviceKit, ServerEngine
    from repro.transport.client import ClientStats, EdgeClient
    from repro.transport.links import make_link
    from repro.transport.server import TransportServer

    n_dev, max_new, k_max = (3, 8, 4) if quick else (4, 16, 4)
    prompts = jax.random.randint(jax.random.key(5), (n_dev, 10), 0, vocab)
    kit = EdgeDeviceKit(draft, dp, k_max=k_max, c_th=0.0, greedy=True, attn_chunk=32)

    # shared compiled steps for both fleets; warm fleet evens out first-use
    # compiles (prefill/draft/peek) before either configuration is timed
    seed = ServerEngine(target, tp, n_slots=n_dev, max_len=128, k_max=k_max, attn_chunk=32)
    steps = seed.steps
    seed.warmup()

    def fresh_engine():
        return ServerEngine(
            target, tp, n_slots=n_dev, max_len=128, k_max=k_max, attn_chunk=32,
            steps=steps,
        )

    rows = []
    warmed = False
    for kctl in ("fixed", "adaptive"):

        async def fleet(engine, kctl=kctl):
            server = TransportServer(engine)
            clients = []
            for i in range(n_dev):
                link = make_link("loopback")
                server.attach(link.server)
                clients.append(
                    EdgeClient(
                        kit, i, np.asarray(prompts[i]), link.device,
                        max_new=max_new, max_len=128, pipeline=True,
                        verify_timeout=30.0, kctl=kctl, seed=i,
                    )
                )
            t0 = time.time()
            await asyncio.gather(*(c.run() for c in clients))
            wall = time.time() - t0
            for _ in range(500):
                if not engine.streams:
                    break
                await asyncio.sleep(0.01)
            st = server.stats()
            await server.stop()
            return clients, st, wall

        if not warmed:
            asyncio.run(fleet(fresh_engine()))  # compile pass (client-side jits)
            warmed = True
        clients, st, wall = asyncio.run(fleet(fresh_engine()))
        fleet_stats = ClientStats.merge([c.stats for c in clients])
        rows.append({
            "section": "kctl",
            "kctl": kctl,
            "wstgr": round(n_dev * max_new / wall, 2),
            "acceptance": round(st.acceptance_rate, 3),
            "rounds": st.rounds,
            "k_mean": round(fleet_stats.k_mean, 2),
            "k_final": fleet_stats.k_final,
            "drafted_per_token": round(
                sum(c.device.drafted for c in clients)
                / max(n_dev * max_new, 1), 2,
            ),
            "bytes_up": st.bytes_rx,
            "wall_s": round(wall, 2),
        })
        print(
            f"[kctl {kctl}] {rows[-1]['wstgr']} tok/s, acceptance "
            f"{rows[-1]['acceptance']}, mean k {rows[-1]['k_mean']}, "
            f"{rows[-1]['drafted_per_token']} drafted/token"
        )
    return rows


def run_cluster(quick: bool = False, json_path: str = "") -> list:
    target, tp, draft, dp, vocab = _cluster_models(quick)
    rows = _capacity_rows(target, tp, draft, dp, vocab, quick=quick)
    rows += _kctl_rows(target, tp, draft, dp, vocab, quick=quick)
    emit(rows, "cluster_capacity")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "cluster_capacity", "quick": quick, "rows": rows}, f,
                      indent=2)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", action="store_true",
                    help="real replica-sharded capacity sweep + adaptive-k fleet")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", type=str, default="",
                    help="write the rows as a BENCH JSON artifact")
    a = ap.parse_args()
    if a.cluster:
        run_cluster(quick=a.quick, json_path=a.json)
    else:
        rows = run(quick=a.quick)
        if a.json:
            with open(a.json, "w") as f:
                json.dump({"benchmark": "table1_capacity", "quick": a.quick,
                           "rows": rows}, f, indent=2)
            print(f"wrote {a.json}")
