"""Synchronous single-host SLED reference loop (draft + verify, real models).

This is the algorithmic ground truth used by tests, examples, and the Fig. 3
confidence benchmark: a draft model and a target model running the full
SLED drafting/verification protocol in lock-step.  System-scale timing
behaviour (Poisson arrivals, RTT, async draft-ahead, batching across
devices) lives in serving/simulator.py; THIS loop is about token-level
correctness — e.g. greedy SLED output must equal greedy target-only output.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drafting, verification
from repro.core.speculative import PAD_TOKEN


@dataclasses.dataclass
class SledStats:
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    committed: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def tokens_per_round(self) -> float:
        return self.committed / max(self.rounds, 1)


def sled_generate(
    draft_model, draft_params,
    target_model, target_params,
    prompts: jax.Array,  # (B, P) int32
    *,
    max_new: int,
    k_max: int = 4,
    c_th: float = 0.0,
    greedy: bool = True,
    temperature: float = 1.0,
    seed: int = 0,
    attn_chunk: int = 256,
    collect_confidence: bool = False,
) -> Tuple[np.ndarray, SledStats, Optional[List[Tuple[float, bool]]]]:
    """Run SLED end-to-end. Returns (tokens (B, max_new), stats, conf_pairs).

    conf_pairs (when collect_confidence): list of (draft confidence,
    accepted?) per drafted token — the raw data behind paper Fig. 3.
    """
    B, P = prompts.shape
    max_len = P + max_new + k_max + 8

    d_cache = draft_model.make_cache(B, max_len, attn_chunk=attn_chunk)
    t_cache = target_model.make_cache(B, max_len, attn_chunk=attn_chunk)

    d_prefill = jax.jit(verification.make_prefill_step(draft_model, attn_chunk=attn_chunk))
    t_prefill = jax.jit(verification.make_prefill_step(target_model, attn_chunk=attn_chunk))
    verify = jax.jit(verification.make_verify_step(
        target_model, greedy=greedy, temperature=temperature, attn_chunk=attn_chunk))
    do_draft = jax.jit(
        lambda params, cache, prev, key: drafting.draft_round(
            draft_model, params, cache, prev, key,
            k_max=k_max, c_th=c_th, temperature=temperature, greedy=greedy,
            keep_q_full=not greedy, attn_chunk=attn_chunk,
        )
    )

    _, d_cache, prev = d_prefill(draft_params, d_cache, prompts)
    _, t_cache, _ = t_prefill(target_params, t_cache, prompts)

    key = jax.random.key(seed)
    # rows commit at different rates; a fast row may overshoot max_new by
    # (k_max+1) per round until the slowest row finishes
    out = np.full((B, max_new + 16 * (k_max + 1)), PAD_TOKEN, np.int64)
    counts = np.zeros((B,), np.int64)
    stats = SledStats()
    conf_pairs: List[Tuple[float, bool]] = [] if collect_confidence else None

    while counts.min() < max_new:
        key, k_d = jax.random.split(key)
        dres = do_draft(draft_params, d_cache, prev, k_d)
        batch = verification.make_verify_batch(
            prev, dres.tokens, dres.lengths, draft_q=None if greedy else dres.q_sel,
            seed=np.uint32(stats.rounds + seed),
        )
        if not greedy and dres.q_full is not None:
            batch["draft_q_full"] = dres.q_full
        res, t_cache = verify(target_params, t_cache, batch)

        d_cache = drafting.resume_after_verify(draft_model, dres, res.n_accepted)
        prev = res.extra_token

        toks = np.asarray(res.out_tokens)
        n_commit = np.asarray(res.n_commit)
        lengths = np.asarray(dres.lengths)
        accepted = np.asarray(res.n_accepted)
        if collect_confidence:
            confs = np.asarray(dres.confidence)
            acc_mask = np.asarray(res.accepted_mask)
            for b in range(B):
                for i in range(int(lengths[b])):
                    conf_pairs.append((float(confs[b, i]), bool(acc_mask[b, i])))
        for b in range(B):
            n = min(int(n_commit[b]), out.shape[1] - int(counts[b]))
            out[b, counts[b] : counts[b] + n] = toks[b, :n]
            counts[b] += n
        stats.rounds += 1
        stats.drafted += int(lengths.sum())
        stats.accepted += int(accepted.sum())
        stats.committed += int(n_commit.sum())

    return out[:, :max_new], stats, conf_pairs


def autoregressive_generate(
    model, params, prompts: jax.Array, *, max_new: int, greedy: bool = True,
    temperature: float = 1.0, seed: int = 0, attn_chunk: int = 256,
) -> np.ndarray:
    """Plain target-only decoding — the centralized-serving baseline."""
    B, P = prompts.shape
    cache = model.make_cache(B, P + max_new + 8, attn_chunk=attn_chunk)
    prefill = jax.jit(verification.make_prefill_step(model, attn_chunk=attn_chunk))
    _, cache, prev = prefill(params, cache, prompts)

    @jax.jit
    def step(params, cache, prev, key):
        h, ck, _ = model.decode_forward(params, cache, prev[:, None],
                                        attn_chunk=attn_chunk)
        cache = model.commit(ck, jnp.ones((B,), jnp.int32))
        logits = model.lm_head(params, h)[:, 0]
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(key, logits / temperature).astype(jnp.int32)
        return cache, nxt

    key = jax.random.key(seed)
    out = np.zeros((B, max_new), np.int64)
    for t in range(max_new):
        key, ks = jax.random.split(key)
        cache, prev = step(params, cache, prev, ks)
        out[:, t] = np.asarray(prev)
    return out
