"""Simulate a SLED service area: heterogeneous devices + one shared server.

    PYTHONPATH=src python examples/edge_serving_sim.py

Reproduces the paper's system-level story end-to-end: a mixed fleet of
RPi 4B / RPi 5 / Jetson devices drafting locally, one A100 (or TPU v5e)
server batch-verifying, versus centralized serving and all-edge decoding.
"""
import dataclasses

from repro.serving.cost_model import cost_per_1k_tokens, sled_cost_per_1k
from repro.serving.devices import A100_X4, DEVICES, V5E_16
from repro.serving.simulator import SimConfig, capacity, simulate


def main() -> None:
    print(f"{'device':18s} {'mode':12s} {'N':>4s} {'tok/s':>8s} {'per-dev':>8s} "
          f"{'$/1K':>8s} {'srv busy':>8s}")
    for server in (A100_X4, V5E_16):
        print(f"--- server: {server.name} (target 11B, K=4, acceptance 0.9)")
        for dev_name, dev in DEVICES.items():
            rate = dev.rate("llama-1b-draft", 4)
            for mode in ("sled", "centralized", "all_edge"):
                cfg = SimConfig(mode=mode, n_devices=16, device_rate=rate,
                                acceptance=0.9, spec_len=4, server_batch=16,
                                batch_policy="deadline", sim_time=30.0)
                r = simulate(cfg, server)
                if mode == "sled":
                    cost = sled_cost_per_1k(r.per_device_rate, dev, server,
                                            r.server_busy_frac / 16)
                elif mode == "centralized":
                    cost = cost_per_1k_tokens(r.wstgr, server.price_usd, server.power_w)
                else:
                    cost = cost_per_1k_tokens(rate, dev.price_usd, dev.power_w)
                print(f"{dev_name:18s} {mode:12s} {16:4d} {r.wstgr:8.1f} "
                      f"{r.per_device_rate:8.2f} {cost:8.4f} {r.server_busy_frac:8.2f}")
        # capacity comparison (paper Table I)
        dev = DEVICES["rpi5"]
        base = SimConfig(mode="sled", device_rate=dev.rate("llama-1b-draft", 4),
                         acceptance=0.9, spec_len=4, server_batch=16,
                         batch_policy="deadline", sim_time=20.0)
        cap_s = capacity(base, server, n_max=384)
        cap_c = capacity(dataclasses.replace(base, mode="centralized"), server, n_max=384)
        print(f"capacity (rpi5): SLED {cap_s} vs centralized {cap_c} "
              f"-> x{cap_s / max(cap_c, 1):.2f} (paper: x2.86)")


if __name__ == "__main__":
    main()
