"""Production meshes. A FUNCTION, not a constant: importing this module must
never touch jax device state (smoke tests see 1 device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init).

Topology: TPU v5e, 16x16 chips per pod; the multi-pod mesh adds a leading
"pod" axis across the DCN.  Axis roles:
  pod   — data parallelism across pods (training grad all-reduce crosses
          DCN) / independent service areas (serving: no cross-pod traffic)
  data  — batch (requests / data-parallel replicas) + FSDP weight sharding
  model — tensor/expert parallelism inside a pod
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Small mesh for CI-sized sharding tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"
