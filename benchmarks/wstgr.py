"""Paper Fig. 4: Whole-System Token Generation Rate vs server batch size.

SLED vs centralized serving for 11B and 70B target models; the server is
kept saturated (N = 8x batch devices) so WSTGR reflects server-side
efficiency.  Expected shape: WSTGR rises with batch (weight-stream
amortisation), SLED sits >2x above centralized at equal batch — the paper's
x2.2 system-throughput claim.

``--engine`` switches to the REAL continuous-batching engine (a ServeSpec
per policy served through repro.api) with tiny models: the same
SimResult-style fields
(wstgr, mean_batch_fill, rounds) are measured from an actual serving run and
emitted next to the discrete-event simulator's prediction for a matched
arrival pattern, so simulator claims can be cross-checked end-to-end.

``--transport`` goes one level further: the fleet runs over the async
transport runtime (wire protocol + SimulatedLink with the paper's WLAN
RTT/jitter), and the measured runtime stats — wstgr, batch fill, queue
depth, bytes on the wire — are cross-checked against the discrete-event
simulator's prediction for the SAME network profile, with the simulator's
device rate / acceptance / server latency calibrated from the measured run
(the sim predicts *dynamics*, the calibration pins the *rates*).  The wstgr
ratio is expected within 15%.
"""
from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import emit
from repro.serving.devices import A100_X4, RPI5
from repro.serving.simulator import SimConfig, simulate


def run(quick: bool = False) -> list:
    rows = []
    batches = (1, 2, 4, 8, 16, 32) if not quick else (2, 8, 32)
    for target_p, tname in ((11e9, "11B"), (70e9, "70B")):
        for b in batches:
            base = SimConfig(
                mode="sled", spec_len=4, acceptance=0.90,
                device_rate=RPI5.rate("llama-3b-draft", 4),
                target_params=target_p, server_batch=b,
                batch_policy="deadline", n_devices=64 * b,
                sim_time=10.0 if quick else 20.0,
            )
            sled = simulate(base, A100_X4)
            cent = simulate(dataclasses.replace(base, mode="centralized"), A100_X4)
            rows.append({
                "target": tname, "batch": b,
                "wstgr_sled": round(sled.wstgr, 1),
                "wstgr_centralized": round(cent.wstgr, 1),
                "ratio": round(sled.wstgr / max(cent.wstgr, 1e-9), 2),
                "sled_busy": round(sled.server_busy_frac, 2),
            })
    emit(rows, "fig4_wstgr")
    return rows


def run_engine(quick: bool = False) -> list:
    """Real-model continuous batching: one ServeSpec per policy, served
    through the repro.api front door, with measured SimResult-style stats
    next to the simulator's batch-fill prediction for the same fleet."""
    from repro.api import ModelSpec, SchedulerSpec, ServeSpec, System, build_models

    n_dev, max_new, k_max = (3, 8, 4) if quick else (6, 16, 4)
    base = ServeSpec(
        backend="engine",
        model=ModelSpec(vocab_size=128, draft_layers=1, seed=0),
        devices=n_dev,
        max_new=max_new,
        k_max=k_max,
        c_th=0.3,
        session_seed_base=0,
        scheduler=SchedulerSpec(policy="continuous", max_wait=0.0, slots=n_dev,
                                stagger_ticks=2),
    )
    sweep = [
        dataclasses.replace(base, scheduler=dataclasses.replace(base.scheduler, policy=p))
        for p in (("continuous",) if quick else ("continuous", "deadline"))
    ]
    models = build_models(base.model)
    rows = []
    for spec in sweep:
        result = System.build(spec, models=models).serve()
        st = result.engine
        policy = spec.scheduler.policy
        sim = simulate(
            SimConfig(mode="sled", n_devices=n_dev, spec_len=k_max,
                      server_batch=n_dev, batch_policy=policy,
                      sim_time=5.0 if quick else 10.0),
            A100_X4,
        )
        rows.append({
            "policy": policy,
            "wstgr_measured": round(st.wstgr, 1),
            "mean_batch_fill": round(st.mean_batch_fill, 2),
            "partial_rounds": st.partial_rounds,
            "rounds": st.rounds,
            "sim_mean_batch_fill": round(sim.mean_batch_fill, 2),
            "engine": st.to_json(),
        })
    rows.append(_telemetry_overhead_row(sweep[0], models))
    emit(rows, "engine_wstgr")
    return rows


def _telemetry_overhead_row(spec, models) -> dict:
    """Identical paired runs — telemetry off vs on — sharing models, the
    compiled step bundle, and the device kit, so the delta is the cost of the
    instrumentation alone (host-side spans + trace records).  The measured
    overhead and the per-span breakdown land in the BENCH artifact; the
    acceptance bar is within 3% of the off run."""
    import dataclasses as dc

    from repro import telemetry
    from repro.api import System

    warm = System.build(spec, models=models)
    warm.warmup()
    warm.serve()
    steps, kit = warm.steps, warm.kit

    # alternate off/on passes and keep each side's best so scheduler jitter
    # (runs are only a handful of rounds) doesn't swamp the span cost
    best_off = best_on = 0.0
    on = None
    for _ in range(3):
        telemetry.enable(False)
        off_r = System.build(spec, models=models, steps=steps, kit=kit).serve()
        on_r = System.build(
            dc.replace(spec, telemetry=True), models=models, steps=steps, kit=kit
        ).serve()
        best_off = max(best_off, off_r.total_tokens / max(off_r.wall_seconds, 1e-9))
        best_on = max(best_on, on_r.total_tokens / max(on_r.wall_seconds, 1e-9))
        on = on_r
    telemetry.enable(False)

    wstgr_off, wstgr_on = best_off, best_on
    overhead_pct = round(100.0 * (wstgr_off - wstgr_on) / max(wstgr_off, 1e-9), 2)
    snap = (on.telemetry or {}).get("snapshot", {})
    spans = {
        name: {k: round(float(h[k]), 6) for k in ("count", "mean", "p50", "p95")}
        for name, h in snap.get("histograms", {}).items()
    }
    print(
        f"[telemetry] off {wstgr_off:.1f} tok/s vs on {wstgr_on:.1f} tok/s "
        f"({overhead_pct:+.2f}% overhead), {len(spans)} instrumented spans"
    )
    return {
        "section": "telemetry-overhead",
        "wstgr_off": round(wstgr_off, 2),
        "wstgr_on": round(wstgr_on, 2),
        "overhead_pct": overhead_pct,
        "trace_events": sum(len(s.trace) for s in on.sessions),
        "spans": spans,
    }


def _solve_acceptance(tokens_per_round: float, k: int) -> float:
    """alpha such that the simulator's E[tokens/round] = 1 + sum_i alpha^i
    matches the measured rate (truncated-geometric acceptance model)."""
    lo, hi = 0.0, 1.0
    for _ in range(48):
        mid = (lo + hi) / 2
        if 1.0 + sum(mid**i for i in range(1, k + 1)) < tokens_per_round:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def run_transport(quick: bool = False) -> list:
    """Async transport runtime over simulated WLAN links vs the discrete-event
    simulator under a matched network/rate configuration — one ServeSpec per
    policy, fleets served through the repro.api front door."""
    import jax
    import numpy as np

    from repro.api import (
        ModelSpec,
        SchedulerSpec,
        ServeSpec,
        System,
        TransportSpec,
        build_models,
    )
    from repro.serving.devices import NETS, RPI5, ServerProfile

    n_dev, max_new, k_max = (3, 10, 4) if quick else (6, 24, 4)
    net = NETS["wlan"]  # paper-style service-area RTT/jitter
    # emulate RPi5-class drafting (int4 1B draft): reduced models draft far
    # faster than real boards, and the throttle also restores fleet
    # concurrency that single-process compute would otherwise serialize
    device_rate = RPI5.rate("llama-1b-draft", 4)
    base = ServeSpec(
        backend="transport",
        # random-init pairs agree greedily (acceptance 1.0); perturb to ~0.9
        model=ModelSpec(vocab_size=128, target_layers=3, draft_layers=None,
                        draft_noise=0.02, seed=0),
        transport=TransportSpec(link="sim", net="wlan", pipeline=True,
                                verify_timeout=30.0, stagger_s=0.0,
                                draft_rate=device_rate),
        scheduler=SchedulerSpec(policy="continuous", max_wait=0.02, slots=n_dev),
        devices=n_dev,
        max_new=max_new,
        k_max=k_max,
        c_th=0.0,
        session_seed_base=0,
    )
    sweep = [
        dataclasses.replace(base, scheduler=dataclasses.replace(base.scheduler, policy=p))
        for p in (("continuous",) if quick else ("continuous", "deadline"))
    ]
    models = build_models(base.model)
    vocab = models.vocab
    rows = []
    for spec in sweep:
        policy = spec.scheduler.policy

        def fleet_prompts(ids):
            return np.stack(
                [np.asarray(jax.random.randint(jax.random.key(i), (12,), 0, vocab))
                 for i in ids]
            )

        # warm every verify bucket plus the client-side jits (prefill, draft,
        # peek) on a throwaway System; the measured System shares its compiled
        # steps + kit, so its stats cover exactly the measured fleet
        warm = System.build(spec, models=models)
        warm.warmup()
        warm.serve(fleet_prompts(range(n_dev)), max_new=4)
        system = System.build(spec, models=models, steps=warm.steps, kit=warm.kit)
        engine = system.engine
        result = system.serve(fleet_prompts(range(100, 100 + n_dev)))
        st, fleet_stats, wall = result.engine, result.clients, result.wall_seconds

        log = engine.round_log
        committed = sum(r.n_commit for r in log)
        # per-request committed tokens per verify round (sim: 1 + E[m])
        tokens_per_round = committed / max(sum(r.size for r in log), 1)
        step_s = float(np.median([r.step_seconds for r in log]))
        fill = sum(r.size for r in log) / max(len(log), 1)
        qdepth = sum(r.queue_depth for r in log) / max(len(log), 1)
        wstgr_meas = n_dev * max_new / wall
        accept_ratio = st.acceptance_rate

        # the simulator predicts the *dynamics* (batching, RTT overlap,
        # draft-ahead) given the rates we measured on the real runtime
        measured_server = ServerProfile(
            name="measured-cpu", price_usd=0.0, power_w=0.0,
            peak_flops=1e30, hbm_bw=1e30, launch_overhead_s=step_s,
        )
        sim = simulate(
            SimConfig(
                mode="sled", n_devices=n_dev, spec_len=k_max,
                acceptance=_solve_acceptance(tokens_per_round, k_max),
                device_rate=device_rate, server_batch=n_dev,
                batch_policy=policy, max_wait=0.02,
                rtt_mean=net.rtt_mean, rtt_jitter=net.rtt_jitter,
                draft_ahead=k_max, sim_time=30.0, verify_timeout=30.0,
            ),
            measured_server,
        )
        rows.append({
            "policy": policy,
            "wstgr_measured": round(wstgr_meas, 2),
            "wstgr_sim": round(sim.wstgr, 2),
            "wstgr_ratio": round(wstgr_meas / max(sim.wstgr, 1e-9), 3),
            "mean_batch_fill": round(fill, 2),
            "sim_mean_batch_fill": round(sim.mean_batch_fill, 2),
            "mean_queue_depth": round(qdepth, 2),
            "acceptance": round(accept_ratio, 3),
            "device_rate_tok_s": round(device_rate, 1),
            "verify_step_s": round(step_s, 4),
            "pipeline_hits": fleet_stats.pipeline_hits,
            "pipeline_misses": fleet_stats.pipeline_misses,
            "bytes_up": st.bytes_rx,
            "bytes_down": st.bytes_tx,
            "frames": st.frames_rx + st.frames_tx,
            "frames_dropped": st.frames_dropped + fleet_stats.frames_dropped,
            "fallback_tokens": st.fallback_tokens,  # fresh System: this fleet only
            "engine": st.to_json(),
        })
        ok = abs(rows[-1]["wstgr_ratio"] - 1.0) <= 0.15
        print(
            f"[{policy}] measured {wstgr_meas:.2f} tok/s vs sim {sim.wstgr:.2f} "
            f"(ratio {rows[-1]['wstgr_ratio']:.3f}) — {'OK' if ok else 'OUTSIDE 15%'}"
        )
    emit(rows, "transport_wstgr")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="run the real-model continuous-batching engine")
    ap.add_argument("--transport", action="store_true",
                    help="run the async transport runtime over simulated links "
                         "and cross-check against the discrete-event simulator")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    fn = run_transport if a.transport else (run_engine if a.engine else run)
    fn(quick=a.quick)
