"""SLED serving launcher: a thin argparse -> ServeSpec adapter.

All serving now runs through the unified ``repro.api`` front door — this
launcher only translates flags into a :class:`~repro.api.ServeSpec`, builds
a :class:`~repro.api.System`, and prints the run.  The legacy flags are kept
(deprecated; each maps 1:1 onto a spec field — see the README migration
table), and two new flags make runs reproducible from a single artifact:

    --dump-spec      print the resolved ServeSpec as JSON and exit
    --spec PATH      run a ServeSpec JSON from disk (flags that shape the
                     deployment are ignored; --check/--dump-spec/--telemetry
                     still apply)

Backends (``--backend``, or inferred from the legacy ``--transport`` flag):

  reference  lock-step sled_generate loop (algorithmic ground truth)
  engine     in-process ServerEngine driver (PR-1's minimal demo)
  cluster    Router over N engine replicas (``--replicas``); per-replica
             placement (including remote ``repro worker`` processes) is
             spec-only — see examples/specs/cluster_remote.json
  transport  wire-protocol runtime over loopback or simulated links

On lossless links with fixed k every backend must be token-for-token
identical to the reference loop; ``--check`` (default on) verifies it by
running the reference backend on the same built models.

    PYTHONPATH=src python -m repro.launch.serve --devices 6              # loopback
    PYTHONPATH=src python -m repro.launch.serve --transport sim --net wlan
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 --kctl adaptive \
        --transport sim --draft-noise 0.05 --no-check
    repro serve --spec examples/specs/cluster.json --check               # from artifact
"""

import argparse
import dataclasses
from typing import Optional

from repro.api import ServeSpec, SpecError, System
from repro.api.spec import (
    BACKENDS,
    ClusterSpec,
    ModelSpec,
    PLACEMENTS,
    POLICIES,
    QMODES,
    SchedulerSpec,
    TransportSpec,
)
from repro.serving.devices import NETS


def spec_from_args(args) -> ServeSpec:
    """Map the (legacy) flag soup onto the declarative spec, 1:1."""
    if args.backend:
        backend = args.backend
    elif args.transport == "inproc":
        backend = "cluster" if args.replicas > 1 else "engine"
    else:
        backend = "transport"
    return ServeSpec(
        backend=backend,
        model=ModelSpec(
            arch=args.arch,
            vocab_size=256,
            bits=args.bits,
            draft_noise=args.draft_noise,
        ),
        transport=TransportSpec(
            link="sim" if args.transport == "sim" else "loopback",
            net=args.net,
            qmode=args.qmode,
            pipeline=args.pipeline,
            verify_timeout=args.verify_timeout,
            stagger_s=args.stagger_s,
        ),
        cluster=ClusterSpec(replicas=args.replicas, placement=args.placement),
        scheduler=SchedulerSpec(
            policy=args.policy,
            max_wait=args.max_wait,
            slots=args.slots,
            straggler_timeout=args.verify_timeout,
            stagger_ticks=args.stagger,
        ),
        devices=args.devices,
        max_new=args.max_new,
        k_max=args.k_max,
        c_th=args.c_th,
        kctl=args.kctl,
        cctl=args.cctl,
        paged_attention=args.paged_attention,
        telemetry=args.telemetry,
    )


def serve(spec: ServeSpec, *, check: bool = True) -> dict:
    """Build the spec's System, run the fleet, print the run, return the
    uniform ServeResult record."""
    system = System.build(spec)
    if spec.cluster.n_replicas > 1 or spec.cluster.has_remote:
        flavors = [r.flavor for r in spec.cluster.replica_specs]
        sharing = (
            "worker processes on the v3 control plane"
            if spec.cluster.has_remote
            else "shared step bundle"
        )
        print(
            f"cluster: {spec.cluster.n_replicas} replicas "
            f"({', '.join(flavors)}) x {spec.slots_per_replica} slots, "
            f"placement {spec.cluster.placement}, {sharing}"
        )
    if spec.transport.link == "sim" and spec.backend == "transport":
        net = NETS[spec.transport.net]
        print(
            f"simulated links: rtt {net.rtt_mean*1e3:.1f}ms ± {net.rtt_jitter*1e3:.1f}ms, "
            f"{net.bandwidth_bps/1e6:.0f} Mbps, drop {net.drop_prob:.1%}"
        )
    if spec.model.bits < 16:
        print(f"serving int{spec.model.bits} weight-only quantized target")

    try:
        result = system.serve()
    except BaseException:
        system.close()  # reap any spawned workers before surfacing the error
        raise
    st = result.engine
    print(
        f"[{spec.backend}] served {st.streams_served or len(result.sessions)} streams, "
        f"{result.total_tokens} tokens in {st.rounds} rounds / {result.wall_seconds:.1f}s "
        f"({st.wstgr:.1f} tok/s) — mean fill {st.mean_batch_fill:.2f}/{spec.devices}, "
        f"{st.partial_rounds} partial, queue depth {st.mean_queue_depth:.2f}, "
        f"acceptance {st.acceptance_rate:.2f}"
    )
    if result.telemetry:
        snap = result.telemetry.get("snapshot", {})
        print(
            f"telemetry: {len(snap.get('counters', {}))} counters, "
            f"{len(snap.get('gauges', {}))} gauges, "
            f"{len(snap.get('histograms', {}))} histograms, "
            f"{len(result.telemetry.get('flight', []))} flight-recorder rows"
        )
    if result.clients is not None:
        fleet = result.clients
        print(
            f"wire: {st.bytes_rx} B up / {st.bytes_tx} B down in "
            f"{st.frames_rx + st.frames_tx} frames, "
            f"{st.frames_dropped + fleet.frames_dropped} dropped — "
            f"pipeline {fleet.pipeline_hits} hits / {fleet.pipeline_misses} misses, "
            f"{fleet.fallback_rounds} fallback rounds "
            f"({st.fallback_tokens} unverified tokens)"
        )
        if spec.kctl == "adaptive":
            print(f"adaptive k: mean {fleet.k_mean:.2f}, final {fleet.k_final} "
                  f"(k_max {spec.k_max})")
    if spec.cluster.n_replicas > 1:
        print(
            f"cluster: per-replica rounds "
            f"{[s.rounds for s in system.engine.replica_stats()]}, "
            f"{system.engine.migrations} migrations, "
            f"{system.engine.evictions} evictions"
        )
    system.close()  # drain remote workers; reap the ones this run spawned

    if check:
        if spec.backend == "reference":
            pass  # the reference IS the check target
        elif st.fallback_tokens:
            print("skipping equivalence check: fallback released unverified tokens")
        elif spec.kctl != "fixed":
            print("skipping equivalence check: adaptive spec length changes round shapes")
        elif spec.fleet.active:
            # heterogeneous fleet: each class is internally homogeneous, so
            # check every class against its own lock-step reference on the
            # SAME prompt slice the fleet run served (devices lo..hi)
            prompts = system.prompts()
            match = True
            for lo, hi, refspec in spec.fleet_reference_specs():
                ref = System.build(refspec).serve(prompts[lo:hi])
                # the reference slice serves as devices 0..count-1; the
                # fleet run served the same prompts as devices lo..hi-1
                if any(
                    ref.outputs[i] != result.outputs[lo + i]
                    for i in range(hi - lo)
                ):
                    match = False
            n = len(spec.fleet.classes)
            print(f"greedy per-class reference match ({n} classes): "
                  f"{'OK' if match else 'MISMATCH'}")
            assert match, (
                f"{spec.backend} fleet serving must be output-identical to "
                "the per-class lock-step references"
            )
        else:
            ref = System.build(
                spec.with_backend("reference"), models=system.models
            ).serve()
            match = ref.outputs == result.outputs
            print(f"greedy lock-step reference match: {'OK' if match else 'MISMATCH'}")
            assert match, (
                f"{spec.backend} serving must be output-identical to the "
                "lock-step reference"
            )
    return result.to_json()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro serve",
        description="Serve a SLED deployment from a ServeSpec (or legacy flags).",
        epilog="Legacy flags are deprecated: prefer --spec FILE; use "
               "--dump-spec to capture any flag combination as a spec artifact.",
    )
    ap.add_argument("--spec", type=str, default="",
                    help="run a ServeSpec JSON artifact (deployment flags ignored)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved ServeSpec JSON and exit")
    ap.add_argument("--backend", choices=BACKENDS, default="",
                    help="execution backend (default: inferred from --transport)")
    ap.add_argument("--arch", type=str, default="qwen2-1.5b")
    ap.add_argument("--transport", choices=("loopback", "sim", "inproc"), default="loopback",
                    help="[legacy] loopback/sim -> backend=transport; "
                         "inproc -> backend=engine (or cluster with --replicas>1)")
    ap.add_argument("--net", choices=sorted(NETS), default="wlan",
                    help="NetProfile for simulated links")
    ap.add_argument("--devices", type=int, default=6)
    ap.add_argument("--replicas", type=int, default=1,
                    help="server engine replicas behind the cluster router")
    ap.add_argument("--placement", choices=PLACEMENTS, default="least-loaded",
                    help="replica placement policy for new streams")
    ap.add_argument("--kctl", choices=("fixed", "adaptive"), default="fixed",
                    help="spec-length control: fixed k_max, or closed-loop "
                         "AIMD on Verdict acceptance/queue-depth feedback")
    ap.add_argument("--cctl", choices=("fixed", "adaptive"), default="fixed",
                    help="confidence-threshold control: fixed c_th, or "
                         "per-device adaptation on Verdict acceptance "
                         "feedback (transport backend, qmode >= int8)")
    ap.add_argument("--slots", type=int, default=0,
                    help="cache pool rows PER REPLICA (0: ceil(devices/replicas))")
    ap.add_argument("--k-max", type=int, default=4)
    ap.add_argument("--c-th", type=float, default=0.3)
    ap.add_argument("--max-new", "--steps", dest="max_new", type=int, default=24,
                    help="tokens committed per device")
    ap.add_argument("--policy", choices=POLICIES, default="continuous")
    ap.add_argument("--max-wait", type=float, default=0.05)
    ap.add_argument("--qmode", choices=QMODES, default="none",
                    help="draft-probability payload precision on the wire")
    ap.add_argument("--pipeline", action=argparse.BooleanOptionalAction, default=True,
                    help="draft ahead while a verify round is in flight")
    ap.add_argument("--paged-attention", action=argparse.BooleanOptionalAction, default=True,
                    help="slot-indexed verify attention straight out of the KV "
                         "pool (gather/scatter fallback when off or unsupported)")
    ap.add_argument("--verify-timeout", type=float, default=30.0,
                    help="device-side round timeout before §III-A fallback "
                         "(generous default: first rounds pay jit compiles)")
    ap.add_argument("--stagger", type=int, default=3,
                    help="in-process: device i joins i*stagger scheduler ticks in")
    ap.add_argument("--stagger-s", type=float, default=0.2,
                    help="transport: device i joins i*stagger_s seconds in")
    ap.add_argument("--bits", type=int, default=16, choices=(4, 8, 16))
    ap.add_argument("--draft-noise", type=float, default=0.0,
                    help="perturb draft params (random-init models otherwise "
                         "agree greedily -> trivial 1.0 acceptance)")
    ap.add_argument("--check", action=argparse.BooleanOptionalAction, default=True,
                    help="verify output equals the lock-step reference")
    ap.add_argument("--telemetry", action=argparse.BooleanOptionalAction, default=False,
                    help="collect the metrics registry + per-round traces "
                         "(repro.telemetry); observation-only, off by default")
    return ap


def main(argv: Optional[list] = None) -> None:
    args = build_parser().parse_args(argv)
    try:
        if args.spec:
            try:
                with open(args.spec) as f:
                    spec = ServeSpec.from_json(f.read())
            except OSError as e:
                raise SystemExit(f"cannot read spec {args.spec}: {e}")
            print(f"loaded ServeSpec from {args.spec} (backend={spec.backend})")
            if args.telemetry:
                # observation-only, so (like --check) it composes with --spec
                # instead of being ignored with the deployment-shaping flags
                spec = dataclasses.replace(spec, telemetry=True)
        else:
            spec = spec_from_args(args)
    except SpecError as e:
        raise SystemExit(f"invalid ServeSpec: {e}")
    if args.dump_spec:
        print(spec.to_json_str())
        return
    if not args.spec:
        print("note: flag-driven config is deprecated — rerun with --dump-spec "
              "to capture this run as a ServeSpec artifact (repro serve --spec FILE)")
    serve(spec, check=args.check)


if __name__ == "__main__":
    main()
