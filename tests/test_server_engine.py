"""Paged KV cache + continuous-batching engine (core/server_engine.py).

The load-bearing test is equivalence: greedy committed tokens from the
engine under PARTIAL batches (staggered joins, heterogeneous draft lengths,
mid-stream retirement) must equal the lock-step reference loop token-for-
token — continuous batching may change scheduling, never outputs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import verification
from repro.core.engine_loop import sled_generate
from repro.core.server_engine import EdgeDeviceKit, ServerEngine
from repro.models.kvcache import (
    SlotAllocator,
    SlotExhausted,
    gather_slots,
    init_kv_cache,
    scatter_slots,
)
from repro.models.model_zoo import build_model

V = 128


def _models():
    dcfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), vocab_size=V)
    tcfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), name="tgt", vocab_size=V, num_layers=3
    )
    dm, tm = build_model(dcfg), build_model(tcfg)
    return dm, dm.init_params(jax.random.key(1)), tm, tm.init_params(jax.random.key(2))


# ---------------------------------------------------------------------------
# Slot allocator
# ---------------------------------------------------------------------------


def test_slot_allocator_alloc_free_reuse():
    a = SlotAllocator(3)
    s0, s1, s2 = a.alloc(), a.alloc(), a.alloc()
    assert sorted([s0, s1, s2]) == [0, 1, 2]
    assert a.n_free == 0 and a.n_used == 3
    a.free(s1)
    assert a.n_free == 1
    assert a.alloc() == s1  # LIFO reuse
    a.free(s0)
    a.free(s2)
    assert a.n_used == 1 and a.n_free == 2


def test_slot_allocator_exhaustion_and_double_free():
    a = SlotAllocator(1)
    s = a.alloc()
    with pytest.raises(SlotExhausted):
        a.alloc()
    a.free(s)
    with pytest.raises(ValueError):
        a.free(s)
    assert a.alloc() == s


# ---------------------------------------------------------------------------
# Gather/scatter over the pool
# ---------------------------------------------------------------------------


def test_gather_scatter_roundtrip():
    pool = init_kv_cache(num_layers=2, batch=5, max_len=8, num_kv_heads=2, head_dim=4)
    key = jax.random.key(0)
    pool["k"] = jax.random.normal(key, pool["k"].shape, pool["k"].dtype)
    pool["length"] = jnp.arange(5, dtype=jnp.int32)
    slots = jnp.asarray([3, 0], jnp.int32)
    sub = gather_slots(pool, slots)
    assert sub["k"].shape == (2, 2, 8, 2, 4)
    np.testing.assert_array_equal(np.asarray(sub["length"]), [3, 0])
    np.testing.assert_array_equal(np.asarray(sub["k"][:, 0]), np.asarray(pool["k"][:, 3]))

    sub["length"] = sub["length"] + 7
    sub["k"] = sub["k"] + 1.0
    back = scatter_slots(pool, slots, sub)
    np.testing.assert_array_equal(np.asarray(back["length"]), [7, 1, 2, 10, 4])
    np.testing.assert_array_equal(np.asarray(back["k"][:, 3]), np.asarray(sub["k"][:, 0]))
    # untouched rows stay bit-identical
    np.testing.assert_array_equal(np.asarray(back["k"][:, 1]), np.asarray(pool["k"][:, 1]))


def test_paged_verify_subset_matches_dense(rng):
    """One verify round on a gathered row subset == the dense verify step on
    those same rows (the per-row math must not see the pool)."""
    _, _, tm, tp = _models()
    B, P, k_max = 3, 10, 4
    prompts = jax.random.randint(jax.random.key(3), (B, P), 0, V)

    dense_cache = tm.make_cache(B, 64, attn_chunk=32)
    prefill = jax.jit(verification.make_prefill_step(tm, attn_chunk=32))
    _, dense_cache, prev = prefill(tp, dense_cache, prompts)

    pool = tm.make_cache(B + 2, 64, attn_chunk=32)  # B rows + spare + scratch
    slots_all = jnp.arange(B, dtype=jnp.int32)
    pool = scatter_slots(pool, slots_all, dense_cache)

    drafts = jax.random.randint(jax.random.key(4), (B, k_max), 0, V)
    lengths = jnp.asarray([4, 2, 3], jnp.int32)
    sub_ids = [2, 0]  # verify a strict subset, out of order
    batch_sub = verification.make_verify_batch(
        prev[jnp.asarray(sub_ids)], drafts[jnp.asarray(sub_ids)], lengths[jnp.asarray(sub_ids)]
    )
    paged = verification.make_paged_verify_step(tm, scratch_slot=B + 1, attn_chunk=32)
    res_p, pool2 = jax.jit(paged)(tp, pool, jnp.asarray(sub_ids, jnp.int32), batch_sub)

    dense = verification.make_verify_step(tm, greedy=True, attn_chunk=32)
    batch_all = verification.make_verify_batch(prev, drafts, lengths)
    res_d, dense2 = jax.jit(dense)(tp, dense_cache, batch_all)

    for i, row in enumerate(sub_ids):
        assert int(res_p.n_accepted[i]) == int(res_d.n_accepted[row])
        np.testing.assert_array_equal(
            np.asarray(res_p.out_tokens[i]), np.asarray(res_d.out_tokens[row])
        )
        assert int(pool2["length"][row]) == int(dense2["length"][row])
    # rows not in the subset are untouched
    assert int(pool2["length"][1]) == int(dense_cache["length"][1])
    np.testing.assert_array_equal(np.asarray(pool2["k"][:, 1]), np.asarray(pool["k"][:, 1]))
    assert int(pool2["length"][B + 1]) == 0  # scratch row reset


def test_slot_indexed_step_matches_gather_step():
    """The slot-indexed fast path (pool-resident K/V, fresh-row-only writes)
    must be bit-identical to the gather/scatter fallback on every real row —
    including scratch-slot padded entries in the batch."""
    _, _, tm, tp = _models()
    n_slots, k_max, bucket = 4, 4, 4  # 2 real rows + 2 scratch-padded
    prompts = jax.random.randint(jax.random.key(7), (2, 9), 0, V)

    pool = tm.make_cache(n_slots + 1, 64, attn_chunk=32)
    prefill = jax.jit(verification.make_prefill_step(tm, attn_chunk=32))
    row_prev = []
    for i in range(2):
        row = tm.make_cache(1, 64, attn_chunk=32)
        _, row, prev = prefill(tp, row, prompts[i][None, :])
        pool = scatter_slots(pool, jnp.asarray([i + 1], jnp.int32), row)
        row_prev.append(int(prev[0]))

    drafts = jax.random.randint(jax.random.key(8), (bucket, k_max), 0, V)
    lengths = jnp.asarray([4, 3, 0, 0], jnp.int32)
    slots = jnp.asarray([2, 1, n_slots, n_slots], jnp.int32)  # scratch-padded
    batch = verification.make_verify_batch(
        jnp.asarray([row_prev[1], row_prev[0], 0, 0], jnp.int32), drafts, lengths
    )

    paged = verification.make_paged_verify_step(
        tm, scratch_slot=n_slots, attn_chunk=32, paged_attention=True
    )
    gather = verification.make_paged_verify_step(
        tm, scratch_slot=n_slots, attn_chunk=32, paged_attention=False
    )
    assert paged.paged_attention and not gather.paged_attention
    res_p, pool_p = jax.jit(paged)(tp, pool, slots, batch)
    res_g, pool_g = jax.jit(gather)(tp, pool, slots, batch)

    np.testing.assert_array_equal(np.asarray(res_p.n_accepted), np.asarray(res_g.n_accepted))
    np.testing.assert_array_equal(np.asarray(res_p.out_tokens), np.asarray(res_g.out_tokens))
    np.testing.assert_array_equal(
        np.asarray(pool_p["length"][:n_slots]), np.asarray(pool_g["length"][:n_slots])
    )
    for row in (1, 2):
        n = int(pool_p["length"][row])
        np.testing.assert_array_equal(
            np.asarray(pool_p["k"][:, row, : n + 1]), np.asarray(pool_g["k"][:, row, : n + 1])
        )
    # untouched row 3 stays bit-identical in both
    np.testing.assert_array_equal(np.asarray(pool_p["k"][:, 3]), np.asarray(pool["k"][:, 3]))
    assert int(pool_p["length"][n_slots]) == 0  # scratch reset in the fast path


def test_ssm_family_falls_back_to_gather():
    """SSM/hybrid caches carry recurrent state leaves — the factory must
    refuse the slot-indexed path for them even when asked for it."""
    from repro.models.kvcache import supports_paged_attention

    mcfg = dataclasses.replace(get_config("mamba2-370m").reduced(), vocab_size=V, num_layers=2)
    assert not supports_paged_attention(mcfg)
    mm = build_model(mcfg)
    step = verification.make_paged_verify_step(
        mm, scratch_slot=2, attn_chunk=32, paged_attention=True
    )
    assert not step.paged_attention
    engine = ServerEngine(
        mm, mm.init_params(jax.random.key(0)), n_slots=2, max_len=64, k_max=4, attn_chunk=32
    )
    assert not engine.paged_attention


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------


def test_engine_admission_exhaustion_and_readmit():
    _, _, tm, tp = _models()
    engine = ServerEngine(tm, tp, n_slots=1, max_len=64, k_max=4, attn_chunk=32)
    prompt = jnp.zeros((8,), jnp.int32)
    assert engine.admit(0, prompt, 0.0) is not None
    assert engine.admit(1, prompt, 0.0) is None  # pool full -> wait
    engine.retire(0)
    st = engine.admit(1, prompt, 1.0)
    assert st is not None and st.slot == 0  # freed slot is reused
    assert engine.pool.n_free == 0


def test_warmup_bucket_subset_and_compile_log():
    """warmup(buckets=...) compiles only the selected buckets, logs per-
    bucket compile time, and rejects sizes outside the engine's bucket set
    (deployments budget startup instead of paying every bucket eagerly)."""
    _, _, tm, tp = _models()
    engine = ServerEngine(tm, tp, n_slots=4, max_len=64, k_max=4, attn_chunk=32)
    assert engine.buckets == [1, 2, 4]
    times = engine.warmup(buckets=[2])
    assert set(times) == {2} and times[2] > 0
    assert engine.compile_log == times
    with pytest.raises(ValueError, match="unknown warmup buckets"):
        engine.warmup(buckets=[3])
    full = engine.warmup()
    assert set(full) == {1, 2, 4}
    assert set(engine.compile_log) == {1, 2, 4}
    # warmed scratch rounds must leave the pool clean for real admissions
    assert engine.admit(0, jnp.zeros((8,), jnp.int32), 0.0) is not None


def test_engine_rejects_second_inflight_request():
    """Two queued requests from one device would scatter the same cache row
    twice (undefined winner) — the engine must refuse the second."""
    _, _, tm, tp = _models()
    engine = ServerEngine(tm, tp, n_slots=2, max_len=64, k_max=4, attn_chunk=32)
    engine.admit(0, jnp.zeros((8,), jnp.int32), 0.0)
    engine.submit(0, np.asarray([1, 2], np.int32), 0.0)
    with pytest.raises(ValueError, match="in flight"):
        engine.submit(0, np.asarray([3], np.int32), 0.1)
    engine.step(0.2)  # verdict delivered -> a new request is fine again
    engine.submit(0, np.asarray([3], np.int32), 0.3)


def test_engine_static_policy_drains_after_retirement():
    """Static batching caps its fill target at the active stream count so
    the last streams can finish after others retire (closed-loop cap)."""
    dm, dp, tm, tp = _models()
    B, max_new = 2, 6
    prompts = jax.random.randint(jax.random.key(5), (B, 10), 0, V)
    engine = ServerEngine(
        tm, tp, n_slots=B, max_len=128, k_max=4, policy="static", attn_chunk=32
    )
    kit = EdgeDeviceKit(dm, dp, k_max=4, c_th=0.3, greedy=True, attn_chunk=32)
    devices = {
        i: kit.spawn(i, prompts[i], max_len=128, seed=i) for i in range(B)
    }
    for i in range(B):
        engine.admit(i, prompts[i], 0.0)
    outputs, now = {}, 0.0
    for _ in range(200):
        if len(outputs) >= B:
            break
        now += 1.0
        for i, dev in devices.items():
            if i not in outputs and not dev.awaiting:
                engine.submit(i, dev.draft(), now)
        for v in engine.step(now) or []:
            devices[v.device_id].on_verdict(v)
            if len(devices[v.device_id].committed) >= max_new:
                outputs[v.device_id] = devices[v.device_id].committed[:max_new]
                engine.retire(v.device_id)
    assert len(outputs) == B, "static policy deadlocked after first retirement"


def test_engine_partial_batches_match_lockstep_reference():
    """Staggered joins + continuous policy: every round verifies whichever
    subset is queued, devices retire mid-stream, and the greedy output still
    equals sled_generate exactly."""
    dm, dp, tm, tp = _models()
    B, max_new, k_max = 3, 12, 4
    prompts = jax.random.randint(jax.random.key(3), (B, 12), 0, V)

    engine = ServerEngine(
        tm, tp, n_slots=B, max_len=128, k_max=k_max, policy="continuous", attn_chunk=32
    )
    kit = EdgeDeviceKit(dm, dp, k_max=k_max, c_th=0.3, greedy=True, attn_chunk=32)
    devices, outputs, fills = {}, {}, []
    now = 0.0
    while len(outputs) < B:
        now += 1.0
        for i in range(B):
            if i not in devices and i not in outputs and i * 2 < now:
                assert engine.admit(i, prompts[i], now) is not None
                devices[i] = kit.spawn(i, prompts[i], max_len=128, seed=100 + i)
        for i, dev in devices.items():
            if not dev.awaiting:
                engine.submit(i, dev.draft(), now)
        verdicts = engine.step(now)
        if verdicts is None:
            continue
        fills.append(len(verdicts))
        for v in verdicts:
            devices[v.device_id].on_verdict(v)
            if len(devices[v.device_id].committed) >= max_new:
                outputs[v.device_id] = devices[v.device_id].committed[:max_new]
                engine.retire(v.device_id)
                del devices[v.device_id]

    assert min(fills) < B, "staggered arrivals must produce partial batches"
    stats = engine.stats(now)
    assert stats.partial_rounds > 0 and stats.streams_served == B
    assert stats.rounds == len(fills)

    ref, _, _ = sled_generate(
        dm, dp, tm, tp, prompts, max_new=max_new, k_max=k_max, c_th=0.3, greedy=True
    )
    eng = np.array([outputs[i] for i in range(B)])
    np.testing.assert_array_equal(eng, np.asarray(ref))
