"""KV-cache pytree with speculative-rollback semantics.

The cache buffer index IS the absolute token position, and a per-row
``length`` marks how many entries are committed.  Speculative rollback after
verification never moves data: the server just sets
``length = base + n_accepted (+1 for the corrected/bonus token)`` — entries
past ``length`` are masked out of attention and overwritten by the next
verify round.  SSM states can't be masked retroactively, so SSM layers store
per-position state checkpoints during verification instead (see mamba2.py).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp


def init_kv_cache(
    num_layers: int,
    batch: int,
    max_len: int,
    num_kv_heads: int,
    head_dim: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def kv_cache_spec(num_layers, batch, max_len, num_kv_heads, head_dim, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in (dry-run: no allocation)."""
    return {
        "k": jax.ShapeDtypeStruct((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((num_layers, batch, max_len, num_kv_heads, head_dim), dtype),
        "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def rollback(cache: Dict[str, jax.Array], new_length: jax.Array) -> Dict[str, jax.Array]:
    """O(1) rollback: commit only ``new_length`` entries per row."""
    return {**cache, "length": new_length.astype(jnp.int32)}


# ---------------------------------------------------------------------------
# Paged (slot-pool) cache: continuous batching over a fixed row pool
# ---------------------------------------------------------------------------
#
# Every cache family in this repo shares one layout convention: ``length`` is
# (B,) and every other leaf carries the batch on axis 1 (k/v: (L, B, S, H, D),
# ssm: (L, B, H, P, N), conv: (L, B, cw-1, C), cross_k/v: (L, B, F, H, D),
# int8 dequant scales k_scale/v_scale: (L, B, Hkv)).  Because the convention
# covers the scale leaves too, a quantized pool needs no special casing here:
# gather_slots / scatter_slots / write_slot / ExportStream move the int8 rows
# AND their scales together, bit-exactly.
# That makes "a device's cache" a fixed set of rows, so continuous batching
# reduces to a slot allocator over a pool of rows.  Two dispatch modes share
# the pool:
#
#   * slot-indexed (default for attention families): the verify forward runs
#     DIRECTLY against the pool — per-row lengths come from length[slots],
#     fresh K/V rows scatter into pool rows, and attention streams
#     slot-indexed chunks (transformer.decode_forward(slots=...), mirrored
#     on TPU by kernels/verify_attn.verify_attention_paged's
#     scalar-prefetched index maps).  Pool traffic per round is one read of
#     the scheduled rows plus an O(B * (K+1)) fresh-row write.
#   * gather/scatter (fallback): the scheduled subset is materialised into a
#     dense sub-batch, verified, and scattered back.  Still required for
#     SSM/hybrid families whose recurrent state leaves (ssm, conv,
#     checkpoints) are not position-indexed K/V — those leaves are tiny next
#     to the attention pool, so the fallback tax is bounded.
#
# Either way compiled shapes depend only on the bucket size — devices can
# join, leave, or idle without recompiles.


def supports_paged_attention(cfg) -> bool:
    """True when every cache leaf the verify forward touches is attention-
    shaped (k/v/cross buffers + length), so the slot-indexed fast path can
    run against the pool.  SSM and hybrid caches carry recurrent state
    leaves that must still ride the gather/scatter fallback."""
    return getattr(cfg, "family", None) not in ("ssm", "hybrid")


def _batch_axis(leaf: jax.Array) -> int:
    return 0 if leaf.ndim == 1 else 1  # "length" vs stacked per-layer leaves


def gather_slots(cache: Dict[str, jax.Array], slots: jax.Array) -> Dict[str, jax.Array]:
    """Dense sub-cache holding pool rows ``slots`` (jit-traceable)."""
    return jax.tree.map(lambda a: jnp.take(a, slots, axis=_batch_axis(a)), cache)


def scatter_slots(
    pool: Dict[str, jax.Array], slots: jax.Array, sub: Dict[str, jax.Array]
) -> Dict[str, jax.Array]:
    """Write dense sub-cache rows back into pool rows ``slots``.

    Duplicate slot ids are allowed (the verify step pads partial batches with
    the scratch slot); which duplicate wins is undefined, which is fine
    because scratch contents are never read as committed state.
    """

    def put(p, s):
        if p.ndim == 1:
            return p.at[slots].set(s)
        return p.at[:, slots].set(s)

    return jax.tree.map(put, pool, sub)


class SlotExhausted(RuntimeError):
    """No free cache row: admission must wait for a stream to retire."""


class SlotAllocator:
    """Host-side free-list over ``n_slots`` cache rows (LIFO reuse)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._used: set = set()

    def alloc(self) -> int:
        if not self._free:
            raise SlotExhausted(f"all {self.n_slots} cache slots in use")
        slot = self._free.pop()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._used)


class PagedKVCache:
    """Fixed pool of cache rows + slot map: the server-side state behind
    continuous-batching verification.

    The pool holds ``n_slots`` device rows plus ONE scratch row (index
    ``n_slots``) that jitted steps use to pad partial batches up to a bucket
    size — padding rows gather scratch, compute garbage, and scatter it back
    to scratch, so real rows are untouched by fill.

    Works for every model family because it only relies on the shared cache
    layout convention (see module comment); rollback semantics stay the
    model's own (``model.commit`` runs on the gathered dense sub-cache).
    """

    def __init__(self, model: Any, n_slots: int, max_len: int, **cache_kw):
        self.model = model
        self.n_slots = n_slots
        self.scratch_slot = n_slots
        self.max_len = max_len
        self.cache_kw = dict(cache_kw)
        self.cache = model.make_cache(n_slots + 1, max_len, **cache_kw)
        self.allocator = SlotAllocator(n_slots)

    def pool_bytes(self) -> int:
        """Device bytes held by the pool (all leaves, incl. scratch row and
        any int8 dequant-scale leaves) — the capacity-planning number behind
        the ``engine_kv_pool_bytes`` gauge."""
        return sum(
            int(a.size) * a.dtype.itemsize for a in jax.tree.leaves(self.cache)
        )

    def bytes_per_slot(self) -> int:
        """Pool bytes amortised per device slot: with ``kv_dtype=int8`` this
        is ~half the bf16 figure, i.e. ~2x admitted streams per HBM byte."""
        return self.pool_bytes() // (self.n_slots + 1)

    def alloc(self) -> int:
        return self.allocator.alloc()

    def free(self, slot: int) -> None:
        self.allocator.free(slot)

    @property
    def n_free(self) -> int:
        return self.allocator.n_free

    def make_row_cache(self) -> Dict[str, jax.Array]:
        """Fresh dense batch-1 cache shaped to scatter into one pool row
        (prefill target: same max_len, so trailing dims line up)."""
        return self.model.make_cache(1, self.max_len, **self.cache_kw)

    def write_slot(self, slot: int, row_cache: Dict[str, jax.Array]) -> None:
        """Install a prefilled batch-1 cache into pool row ``slot``."""
        self.cache = scatter_slots(self.cache, jnp.asarray([slot], jnp.int32), row_cache)

    def lengths(self) -> jax.Array:
        return self.cache["length"][: self.n_slots]
